//! Minimal property-testing loop (in-tree proptest substitute).
//!
//! [`check`] runs a property over `cases` randomized inputs drawn from a
//! caller-supplied generator; on failure it panics with the case seed so
//! the exact input can be replayed (`PROP_SEED=<seed> cargo test ...`).

use super::rng::Rng;

/// Number of cases per property (override with `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Base seed (override with `PROP_SEED` to replay a failure).
pub fn base_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|v| v.parse().ok()).unwrap_or(0xC0FFEE)
}

/// Run `property(gen(rng))` for `cases` seeds; panic with the failing
/// seed and case description on the first violation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property '{name}' failed (case {case}, PROP_SEED={base}):\n  \
                 input: {input:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 32, |r| (r.range(0, 100), r.range(0, 100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 4, |r| r.range(0, 9), |_| Err("nope".into()));
    }
}
