//! Bench: regenerate the design-choice ablation table (refine on/off,
//! shuffle vs weighted grouping, heterogeneity-blind profiles).
//! Run: cargo bench --bench ablations

use hstorm::experiments::ablation;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| ablation::run(fast).expect("ablation runs"));
    println!("{}", result.render());
    println!("[ablations] regenerated in {dt:?} (fast={fast})");
}
