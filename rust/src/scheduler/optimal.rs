//! The optimal scheduler (paper §3 & §6): an exhaustive search over the
//! task-assignment design space.
//!
//! For every candidate placement (instance counts per component ×
//! distribution over machines) the search computes the largest feasible
//! topology input rate and keeps the placement with the highest
//! throughput.  The paper uses this brute-force comparator to bound how
//! far the heuristic is from optimal (within 4% worst case), and to
//! motivate the heuristic in the first place: the search that took the
//! paper's Xeon server ~18 h for 27,405 possibilities is exactly the
//! loop below, which we make tractable by scoring candidates in batches
//! of 256 through the AOT-compiled evaluation model (L1 Pallas scorer).
//!
//! Scoring uses the linearity of eq. 5 in `R0`: one batched evaluation at
//! `R0 = 1` yields each machine's utilization slope `a_m` (after
//! subtracting the placement's rate-independent MET load `b_m`, computed
//! natively), giving the closed form `R0* = min_m (cap_m - b_m) / a_m`
//! per candidate — one PJRT execution scores 256 placements exactly.

use super::{finish, Schedule, Scheduler};
use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::{Evaluator, Placement};
use crate::runtime::scorer::{NativeScorer, PlacementScorer};
use crate::topology::Topology;
use crate::{Error, Result};

/// How to traverse the design space.
#[derive(Debug, Clone)]
pub enum SearchSpace {
    /// Enumerate every placement (errors above `enumeration_limit`).
    Exhaustive,
    /// Uniformly sample `candidates` placements (for spaces the paper
    /// calls "increased exponentially").
    Sampled { candidates: usize, seed: u64 },
}

/// Exhaustive/sampled optimal search.
#[derive(Debug, Clone)]
pub struct OptimalScheduler {
    /// Max instances per component (`k_j`-style bound on the space).
    pub max_instances_per_component: usize,
    pub space: SearchSpace,
    /// Hard cap on exhaustive enumeration size.
    pub enumeration_limit: u64,
    /// Also score the heuristic schedulers' solutions as candidates, so
    /// the reported optimum upper-bounds them even when they use more
    /// instances than `max_instances_per_component` (the paper's optimal
    /// is by construction >= its heuristic; this keeps that property
    /// while the enumeration stays bounded).
    pub seed_heuristics: bool,
}

impl Default for OptimalScheduler {
    fn default() -> Self {
        OptimalScheduler {
            max_instances_per_component: 3,
            space: SearchSpace::Exhaustive,
            enumeration_limit: 3_000_000,
            seed_heuristics: true,
        }
    }
}

/// Binomial coefficient (u128 to survive Table-4-scale sanity checks).
fn binom(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut r: u128 = 1;
    for i in 0..k {
        r = r * (n - i) as u128 / (i + 1) as u128;
    }
    r
}

/// Number of ways to place `k` identical instances on `m` machines.
fn placements_of(k: u64, m: u64) -> u128 {
    binom(k + m - 1, m - 1)
}

impl OptimalScheduler {
    pub fn sampled(candidates: usize, seed: u64) -> Self {
        OptimalScheduler { space: SearchSpace::Sampled { candidates, seed }, ..Default::default() }
    }

    /// Size of the exhaustive design space for `n_comp` components on
    /// `m` machines with 1..=max instances each — the paper's eq. 1
    /// combinatorics, used by the §3 motivation bench.
    pub fn design_space_size(&self, n_comp: usize, m: usize) -> u128 {
        let per_comp: u128 = (1..=self.max_instances_per_component as u64)
            .map(|k| placements_of(k, m as u64))
            .sum();
        per_comp.pow(n_comp as u32)
    }

    /// Enumerate all distributions of `k` instances over `m` machines.
    fn compositions(k: usize, m: usize, out: &mut Vec<Vec<usize>>) {
        fn rec(rest: usize, slot: usize, m: usize, cur: &mut Vec<usize>, out: &mut Vec<Vec<usize>>) {
            if slot == m - 1 {
                cur.push(rest);
                out.push(cur.clone());
                cur.pop();
                return;
            }
            for take in 0..=rest {
                cur.push(take);
                rec(rest - take, slot + 1, m, cur, out);
                cur.pop();
            }
        }
        rec(k, 0, m, &mut Vec::with_capacity(m), out);
    }

    /// All per-component placement rows (counts 1..=max distributed over
    /// machines).
    fn component_rows(&self, m: usize) -> Vec<Vec<usize>> {
        let mut rows = Vec::new();
        for k in 1..=self.max_instances_per_component {
            Self::compositions(k, m, &mut rows);
        }
        rows
    }

    /// Visit every placement in the cartesian product, streaming into
    /// `sink` (returns Err to stop early).
    fn enumerate(
        &self,
        n_comp: usize,
        rows: &[Vec<usize>],
        sink: &mut dyn FnMut(Placement) -> Result<()>,
    ) -> Result<()> {
        let mut idx = vec![0usize; n_comp];
        loop {
            let p = Placement { x: idx.iter().map(|&i| rows[i].clone()).collect() };
            sink(p)?;
            // odometer increment
            let mut d = 0;
            loop {
                idx[d] += 1;
                if idx[d] < rows.len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
                if d == n_comp {
                    return Ok(());
                }
            }
        }
    }

    /// Score a batch of candidates via one evaluation at `R0 = 1` plus
    /// the native MET load, returning each candidate's `R0*`.
    fn rate_stars(
        &self,
        ev: &Evaluator,
        scorer: &dyn PlacementScorer,
        batch: &[Placement],
    ) -> Result<Vec<f64>> {
        let rows = scorer.score_batch(batch, &vec![1.0; batch.len()])?;
        let mut out = Vec::with_capacity(batch.len());
        for (p, row) in batch.iter().zip(&rows) {
            let mut r_star = f64::INFINITY;
            let mut met_over = false;
            for m in 0..ev.n_machines() {
                let mut b = 0.0;
                for c in 0..ev.n_components() {
                    b += p.x[c][m] as f64 * ev.met_m[c][m];
                }
                if b > ev.cap[m] + 1e-9 {
                    met_over = true;
                    break;
                }
                let a = (row.util[m] - b).max(0.0);
                if a > 1e-12 {
                    r_star = r_star.min((ev.cap[m] - b) / a);
                }
            }
            out.push(if met_over || !r_star.is_finite() { 0.0 } else { r_star });
        }
        Ok(out)
    }

    /// Search with a pluggable scorer (the PJRT path in production).
    pub fn schedule_with_scorer(
        &self,
        top: &Topology,
        cluster: &Cluster,
        profiles: &ProfileDb,
        scorer: &dyn PlacementScorer,
    ) -> Result<Schedule> {
        let ev = Evaluator::new(top, cluster, profiles)?;
        let n_comp = top.n_components();
        let m = cluster.n_machines();

        let mut best: Option<(Placement, f64)> = None;
        let mut buf: Vec<Placement> = Vec::with_capacity(256);
        let flush = |buf: &mut Vec<Placement>, best: &mut Option<(Placement, f64)>| -> Result<()> {
            if buf.is_empty() {
                return Ok(());
            }
            let stars = self.rate_stars(&ev, scorer, buf)?;
            for (p, r) in buf.drain(..).zip(stars) {
                if best.as_ref().map_or(true, |(_, br)| r > *br) {
                    *best = Some((p, r));
                }
            }
            Ok(())
        };

        if self.seed_heuristics {
            // include the heuristics' solutions in the candidate set
            use crate::scheduler::default_rr::DefaultScheduler;
            use crate::scheduler::hetero::HeteroScheduler;
            if let Ok(h) = HeteroScheduler::default().schedule(top, cluster, profiles) {
                let etg = crate::topology::Etg { counts: h.placement.counts() };
                if let Ok(rr) = DefaultScheduler::assign(top, cluster, &etg) {
                    buf.push(rr);
                }
                buf.push(h.placement);
                flush(&mut buf, &mut best)?;
            }
        }

        match &self.space {
            SearchSpace::Exhaustive => {
                let size = self.design_space_size(n_comp, m);
                if size > self.enumeration_limit as u128 {
                    return Err(Error::Schedule(format!(
                        "design space has {size} placements (> limit {}); use SearchSpace::Sampled",
                        self.enumeration_limit
                    )));
                }
                let rows = self.component_rows(m);
                self.enumerate(n_comp, &rows, &mut |p| {
                    buf.push(p);
                    if buf.len() == 256 {
                        flush(&mut buf, &mut best)?;
                    }
                    Ok(())
                })?;
                flush(&mut buf, &mut best)?;
            }
            SearchSpace::Sampled { candidates, seed } => {
                let mut rng = crate::util::rng::Rng::new(*seed);
                for _ in 0..*candidates {
                    let mut p = Placement::empty(n_comp, m);
                    for c in 0..n_comp {
                        let k = rng.range(1, self.max_instances_per_component);
                        for _ in 0..k {
                            p.x[c][rng.range(0, m - 1)] += 1;
                        }
                    }
                    buf.push(p);
                    if buf.len() == 256 {
                        flush(&mut buf, &mut best)?;
                    }
                }
                flush(&mut buf, &mut best)?;
            }
        }

        let (placement, r_star) = best.ok_or_else(|| Error::Schedule("empty design space".into()))?;
        if r_star <= 0.0 {
            return Err(Error::Schedule("no feasible placement in the design space".into()));
        }
        finish(&ev, placement)
    }
}

impl Scheduler for OptimalScheduler {
    fn name(&self) -> &'static str {
        "optimal"
    }

    fn schedule(&self, top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Schedule> {
        let scorer = NativeScorer::new(top, cluster, profiles)?;
        self.schedule_with_scorer(top, cluster, profiles, &scorer)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::hetero::HeteroScheduler;
    use crate::topology::benchmarks;

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(3, 0), 1);
        assert_eq!(binom(2, 5), 0);
        // the paper's §3 example: C(30, 4) = 27,405
        assert_eq!(binom(30, 4), 27_405);
    }

    #[test]
    fn compositions_count() {
        let mut out = Vec::new();
        OptimalScheduler::compositions(3, 3, &mut out);
        // C(3+2, 2) = 10 ways
        assert_eq!(out.len(), 10);
        for row in &out {
            assert_eq!(row.iter().sum::<usize>(), 3);
        }
    }

    #[test]
    fn design_space_size_matches_rows() {
        let o = OptimalScheduler::default();
        let rows = o.component_rows(3);
        let per_comp = rows.len() as u128;
        assert_eq!(o.design_space_size(4, 3), per_comp.pow(4));
    }

    #[test]
    fn optimal_at_least_as_good_as_hetero() {
        let (cluster, db) = presets::paper_cluster();
        for top in benchmarks::micro() {
            // max 2 instances keeps the debug-mode enumeration small; the
            // >= property is guaranteed by heuristic seeding regardless.
            let opt = OptimalScheduler { max_instances_per_component: 2, ..Default::default() }
                .schedule(&top, &cluster, &db)
                .unwrap();
            let het = HeteroScheduler::default().schedule(&top, &cluster, &db).unwrap();
            assert!(
                opt.eval.throughput >= het.eval.throughput * 0.999,
                "{}: optimal {} < hetero {}",
                top.name,
                opt.eval.throughput,
                het.eval.throughput
            );
            assert!(opt.eval.feasible);
        }
    }

    #[test]
    fn oversize_space_rejected() {
        let (cluster, db) = presets::homogeneous_cluster(8);
        let top = benchmarks::diamond();
        let o = OptimalScheduler {
            max_instances_per_component: 6,
            enumeration_limit: 1000,
            ..Default::default()
        };
        assert!(o.schedule(&top, &cluster, &db).is_err());
    }

    #[test]
    fn sampled_mode_returns_feasible() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let o = OptimalScheduler::sampled(500, 42);
        let s = o.schedule(&top, &cluster, &db).unwrap();
        assert!(s.eval.feasible);
        assert!(s.rate > 0.0);
    }

    #[test]
    fn sampled_deterministic_by_seed() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let a = OptimalScheduler::sampled(200, 7).schedule(&top, &cluster, &db).unwrap();
        let b = OptimalScheduler::sampled(200, 7).schedule(&top, &cluster, &db).unwrap();
        assert_eq!(a.placement, b.placement);
    }
}
