//! Fig. 6: predicted vs measured CPU utilization of the `highCompute`
//! bolt, on each machine type, in each Micro-Benchmark topology, over an
//! input-rate sweep — plus the §6.2 headline prediction accuracy.
//!
//! Per the paper's setup the gray (`highCompute`) bolt is placed alone on
//! the target machine and its upstream components on machines powerful
//! enough to saturate it; the rate starts at 8 tuple/s and is raised by a
//! random increment in U(20, 80) until over-utilization.  Measured TCU is
//! the target machine's engine utilization (the bolt is its only load);
//! predicted TCU is eq. 5.

use crate::cluster::profile::{ProfileDb, TaskProfile};
use crate::cluster::{presets, Cluster};
use crate::engine::{self, EngineConfig};
use crate::predict::{Evaluator, Placement};
use crate::topology::benchmarks;
use crate::topology::Topology;
use crate::util::rng::Rng;
use crate::Result;

use super::{f1, ExperimentResult};

/// The probe cluster: one target machine of `machine_type` + beefy
/// helper hosts for everything upstream/downstream of the gray bolt.
fn probe_cluster(machine_type: &str, description: &str) -> (Cluster, &'static str) {
    let mut c = Cluster::new(format!("fig6-{machine_type}"));
    let t = c.add_type(machine_type, description);
    let h = c.add_type("helper", "synthetic strong host");
    c.add_machines(t, 1, "target");
    c.add_machines(h, 4, "helper");
    (c, "helper")
}

/// Profile DB for the probe: real numbers for the target type, near-free
/// helpers (they must never be the bottleneck).
fn probe_db(truth: &ProfileDb, top: &Topology, machine_type: &str) -> Result<ProfileDb> {
    let mut db = ProfileDb::new();
    for comp in &top.components {
        let real = truth.get(&comp.task_type, machine_type)?;
        db.insert(&comp.task_type, machine_type, real);
        db.insert(&comp.task_type, "helper", TaskProfile { e: real.e / 50.0, met: 0.2 });
    }
    Ok(db)
}

/// One sweep: returns rows of (rate, predicted, measured).
fn sweep(
    top: &Topology,
    machine_type: &str,
    description: &str,
    truth: &ProfileDb,
    cfg: &EngineConfig,
    rng: &mut Rng,
) -> Result<Vec<(f64, f64, f64)>> {
    let (cluster, _) = probe_cluster(machine_type, description);
    let db = probe_db(truth, top, machine_type)?;
    let ev = Evaluator::new(top, &cluster, &db)?;

    // gray bolt alone on the target (machine 0), everything else on helpers
    let gray = top
        .components
        .iter()
        .position(|c| c.task_type == "highCompute")
        .expect("micro topologies contain highCompute");
    let mut placement = Placement::empty(top.n_components(), cluster.n_machines());
    let mut h = 1;
    for c in 0..top.n_components() {
        if c == gray {
            placement.x[c][0] = 1;
        } else {
            placement.x[c][h] = 1;
            h = 1 + (h % 4);
        }
    }

    let mut rows = Vec::new();
    let mut rate = 8.0f64;
    let met = db.get("highCompute", machine_type)?.met;
    let e = db.get("highCompute", machine_type)?.e;
    for _ in 0..32 {
        let pred_nominal = ev.evaluate(&placement, rate)?;
        if pred_nominal.util[0] > 100.0 {
            break;
        }
        let rep = engine::run(top, &cluster, &db, &placement, rate, cfg)?;
        // Compare the prediction at the *achieved* bolt input rate (the
        // paper measures the real rate too): host-noise emission deficits
        // then do not masquerade as model error.
        let achieved = rep.comp_rate[gray];
        let pred = e * achieved + met;
        rows.push((rate, pred, rep.util[0]));
        rate += rng.range_f64(20.0, 80.0);
    }
    Ok(rows)
}

pub fn run(fast: bool) -> Result<ExperimentResult> {
    let (paper_cluster, truth) = presets::paper_cluster();
    let cfg = if fast {
        EngineConfig {
            duration: std::time::Duration::from_millis(500),
            warmup: std::time::Duration::from_millis(200),
            time_scale: 0.15,
            ..Default::default()
        }
    } else {
        EngineConfig::default()
    };
    let mut out = ExperimentResult::new(
        "fig6",
        "predicted vs measured TCU of highCompute (percent)",
        &["topology", "machine", "rate", "predicted", "measured", "|err|"],
    );
    let mut rng = Rng::new(0xF16_6);
    let mut abs_errs: Vec<f64> = Vec::new();
    for top in benchmarks::micro() {
        for (mt, desc) in paper_cluster
            .types
            .iter()
            .map(|t| (t.name.clone(), t.description.clone()))
        {
            let rows = sweep(&top, &mt, &desc, &truth, &cfg, &mut rng)?;
            for (rate, pred, meas) in rows {
                let err = (pred - meas).abs();
                abs_errs.push(err);
                out.row(vec![
                    top.name.clone(),
                    mt.clone(),
                    f1(rate),
                    f1(pred),
                    f1(meas),
                    f1(err),
                ]);
            }
        }
    }
    let max_err = abs_errs.iter().cloned().fold(0.0, f64::max);
    let mean_err = abs_errs.iter().sum::<f64>() / abs_errs.len().max(1) as f64;
    out.note(format!(
        "prediction accuracy: mean |err| = {mean_err:.2} pp, max |err| = {max_err:.2} pp \
         over {} points",
        abs_errs.len()
    ));
    out.note(format!(
        "paper: accuracy > 92%, worst-case diff < 8 pp; here mean accuracy = {:.1}%",
        100.0 - mean_err
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn prediction_accuracy_holds() {
        let r = super::run(true).unwrap();
        assert!(r.rows.len() >= 9, "want sweeps for 3 topologies x 3 machines");
        // every row's error below 15 pp even in the fast noisy mode
        for row in &r.rows {
            let err: f64 = row[5].parse().unwrap();
            assert!(err < 15.0, "{row:?}");
        }
    }
}
