//! Small statistics helpers shared by the simulators: nearest-rank
//! percentiles over sorted samples and plain means.  Kept tiny and
//! dependency-free (the usual stats crates are not in the vendor set —
//! see [`crate::util`]).

/// Nearest-rank percentile of an **ascending-sorted** slice.
///
/// `p` is in percent (`50.0` = median); the empty slice returns 0.0 so
/// callers can render "no samples" rows without special-casing NaN.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Arithmetic mean; 0.0 on the empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 95.0), 10.0);
        assert_eq!(percentile(&xs, 99.0), 10.0);
        assert_eq!(percentile(&xs, 10.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn percentile_extremes_and_empty() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.5], 1.0), 7.5);
        assert_eq!(percentile(&[7.5], 99.0), 7.5);
        // p = 0 clamps to the first element instead of underflowing
        assert_eq!(percentile(&[3.0, 4.0], 0.0), 3.0);
    }

    #[test]
    fn percentile_is_monotone_in_p() {
        let xs = [0.5, 1.0, 2.5, 4.0, 9.0];
        let mut last = f64::NEG_INFINITY;
        for p in [1.0, 25.0, 50.0, 75.0, 95.0, 99.0] {
            let v = percentile(&xs, p);
            assert!(v >= last, "p{p}: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
