//! Fleet-scale control harness: many tenants on a synthetic rack-built
//! cluster, driven through failure storms at a decision-latency budget.
//!
//! [`run_fleet`] replays a [`traces::fleet_storm`] world — correlated
//! rack outages, a flapping machine, plus util-band autoscaling — over a
//! [`crate::cluster::scenarios::fleet`] cluster, with each tenant
//! running one of the five benchmark topologies under its own diurnal
//! offered-load profile.  Two control regimes are compared:
//!
//! * [`FleetMode::Incremental`] — the dirty-tenant control plane: only
//!   tenants that are damaged (lost instances to an outage), breached
//!   (offered > capacity) or individually outside the hysteresis band
//!   are re-planned, each against the *residual* capacity left by every
//!   other tenant's reserved load, warm-started from the incumbent
//!   placement, bounded by [`ControllerConfig::replan_budget`] and the
//!   per-step migration budget [`ControllerConfig::max_moves_per_step`].
//! * [`FleetMode::FullReplan`] — the quality comparator: every placed
//!   tenant is re-planned from scratch every step with an unlimited
//!   search budget and no migration cap (the pre-incremental regime).
//!
//! World evolution is shared machinery with the single-tenant
//! controller: rack outages go through
//! [`Problem::apply_machine_leaves_fleet`] (one batched column-drop
//! across every tenant's evaluator), joins and drifts through
//! [`Problem::apply_delta_fleet`] (one copy-on-write clone of the
//! cluster, adopted by the whole fleet), and tenant placements/util
//! vectors are patched with the same
//! [`crate::predict::drop_indices`] kernel — so a 1000-machine step that
//! changes nothing costs O(tenants) and a rack outage costs one pass
//! over the affected columns, never a `Problem::new` rebuild.
//!
//! ## Autoscaling
//!
//! The util-band autoscaler compares a **trace-derived load proxy** (the
//! weighted mean of the tenants' offered-rate multipliers) against fixed
//! thresholds and enqueues a `scale-{k}` machine join above the high
//! mark or drains the most recent scale machine below the low mark.
//! Deriving the signal from trace data alone keeps the *world* identical
//! across both modes, so the delivered-throughput gap measures control
//! quality, not diverging cluster histories.
//!
//! ## Measurement
//!
//! Per-step decision latency (event absorption + dirty detection +
//! re-planning) is observed into both the global `control.step_s`
//! histogram and a run-local one that feeds the report's p50/p95/p99
//! (milliseconds).  Latency is wall-clock and therefore excluded from
//! the deterministic surface: everything else in a [`FleetReport`] is a
//! pure function of (spec, config, mode).  With `verify` set,
//! [`crate::check::validate_fleet`] audits every step — clean tenants
//! must keep bit-identical placements and total instance starts must
//! respect the migration budget — at the cost of per-step placement
//! snapshots (use it on small configs; it inflates measured latency).

use std::sync::Arc;

use crate::cluster::presets::CORE_I5;
use crate::cluster::scenarios;
use crate::cluster::Cluster;
use crate::obs::{Histogram, Span};
use crate::predict::{drop_indices, Placement};
use crate::scheduler::{
    Constraints, Problem, ProblemDelta, Schedule, ScheduleRequest, Scheduler, SearchBudget,
};
use crate::topology::benchmarks;
use crate::util::json::{self, Value};
use crate::{Error, Result};

use super::traces::{self, ClusterEvent};
use super::workload::started_tasks;
use super::ControllerConfig;

/// Offered-load-proxy threshold above which the autoscaler enqueues a
/// scale-out join (the diurnal profiles peak near 1.3×).
const AUTOSCALE_HI: f64 = 1.1;
/// Proxy threshold below which the most recent scale machine drains.
const AUTOSCALE_LO: f64 = 0.55;

/// Control regime for one fleet run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetMode {
    /// Dirty-tenant residual re-plans under search + migration budgets.
    Incremental,
    /// Every placed tenant re-planned from scratch every step
    /// (unlimited budget) — the quality baseline.
    FullReplan,
}

impl FleetMode {
    pub fn name(&self) -> &'static str {
        match self {
            FleetMode::Incremental => "incremental",
            FleetMode::FullReplan => "full-replan",
        }
    }
}

/// Shape of one synthetic fleet run.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// Machines in the day-zero cluster.
    pub machines: usize,
    /// Tenants admitted at day zero (benchmark topologies, round-robin).
    pub tenants: usize,
    /// Virtual steps to replay.
    pub steps: usize,
    /// Seed for the storm trace and the per-tenant load profiles.
    pub seed: u64,
    /// Machines per rack (outages take whole racks).
    pub rack_size: usize,
    /// Audit every step with [`crate::check::validate_fleet`] (placement
    /// snapshots land inside the measured step, so keep this off for
    /// latency runs).
    pub verify: bool,
}

impl FleetSpec {
    pub fn new(machines: usize, tenants: usize) -> Self {
        FleetSpec { machines, tenants, steps: 120, seed: 42, rack_size: 20, verify: false }
    }
}

/// Aggregates of one fleet run under one mode.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub mode: &'static str,
    pub machines: usize,
    pub tenants: usize,
    /// Tenants that received a day-zero schedule (the rest are denied
    /// admission and sit out the whole run).
    pub admitted: usize,
    pub steps: usize,
    pub seed: u64,
    /// Cluster events absorbed (storm + autoscale).
    pub events: usize,
    /// Accepted tenant re-plans.
    pub replans: usize,
    /// Steps on which at least one re-plan was accepted.
    pub replan_steps: usize,
    /// Re-plans rejected because they would exceed the migration budget.
    pub deferred: usize,
    /// Task instances newly started or moved by re-plans.
    pub tasks_moved: usize,
    /// Fleet-invariant violations found by the per-step audit (0 unless
    /// the spec's `verify` flag is set and something is broken).
    pub violations: usize,
    /// ∫ Σ_i weight_i · offered_i dt — weighted tuples offered.
    pub offered_volume: f64,
    /// ∫ Σ_i weight_i · delivered_i dt — weighted tuples delivered.
    pub delivered_volume: f64,
    /// Per-step decision-latency percentiles, milliseconds (wall-clock;
    /// 0 when telemetry is disabled).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl FleetReport {
    /// Weighted delivered share of weighted offered load, percent.
    pub fn delivered_pct(&self) -> f64 {
        if self.offered_volume > 0.0 {
            self.delivered_volume / self.offered_volume * 100.0
        } else {
            100.0
        }
    }

    /// One-block terminal summary.
    pub fn render(&self) -> String {
        format!(
            "\n=== fleet — {} machines, {}/{} tenants admitted, {} steps (seed {}) \
             mode '{}' ===\n\
             events: {}   re-plans: {} (on {} steps)   deferred: {}   moved: {}   \
             violations: {}\n\
             weighted delivered: {:.1}% of offered\n\
             step latency ms  p50 {:.3}   p95 {:.3}   p99 {:.3}   max {:.3}\n",
            self.machines,
            self.admitted,
            self.tenants,
            self.steps,
            self.seed,
            self.mode,
            self.events,
            self.replans,
            self.replan_steps,
            self.deferred,
            self.tasks_moved,
            self.violations,
            self.delivered_pct(),
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.max_ms,
        )
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("mode", json::s(self.mode)),
            ("machines", json::num(self.machines as f64)),
            ("tenants", json::num(self.tenants as f64)),
            ("admitted", json::num(self.admitted as f64)),
            ("steps", json::num(self.steps as f64)),
            ("seed", json::num(self.seed as f64)),
            ("events", json::num(self.events as f64)),
            ("replans", json::num(self.replans as f64)),
            ("replan_steps", json::num(self.replan_steps as f64)),
            ("deferred", json::num(self.deferred as f64)),
            ("tasks_moved", json::num(self.tasks_moved as f64)),
            ("violations", json::num(self.violations as f64)),
            ("offered_volume", json::num(self.offered_volume)),
            ("delivered_volume", json::num(self.delivered_volume)),
            ("delivered_pct", json::num(self.delivered_pct())),
            ("p50_ms", json::num(self.p50_ms)),
            ("p95_ms", json::num(self.p95_ms)),
            ("p99_ms", json::num(self.p99_ms)),
            ("max_ms", json::num(self.max_ms)),
        ])
    }
}

/// Weighted-throughput gap of `incremental` vs `full`, percent (positive
/// when the full re-planner delivered more; negative when incremental
/// won, e.g. by avoiding migration downtime).
pub fn quality_gap_pct(incremental: &FleetReport, full: &FleetReport) -> f64 {
    if full.delivered_volume > 0.0 {
        (full.delivered_volume - incremental.delivered_volume) / full.delivered_volume * 100.0
    } else {
        0.0
    }
}

/// Residual-capacity constraint: every machine's already-spoken-for load.
fn reserve(cluster: &Cluster, load: &[f64]) -> Constraints {
    let mut c = Constraints::new();
    for (m, l) in load.iter().enumerate() {
        if *l > 1e-9 {
            c = c.reserve_machine_load(cluster.machines[m].name.clone(), *l);
        }
    }
    c
}

/// Placements aligned for the per-step audit (denied tenants as empty).
fn snapshot(placements: &[Option<Placement>], problems: &[Problem]) -> Vec<Placement> {
    placements
        .iter()
        .zip(problems)
        .map(|(p, pb)| {
            p.clone().unwrap_or_else(|| {
                Placement::empty(pb.topology().n_components(), pb.cluster().n_machines())
            })
        })
        .collect()
}

/// Current closed-form capacity + util vector of a placement (capacity 0
/// when a component has no instances left; an unbounded rate keeps the
/// previous certified rate).
fn recertify(problem: &Problem, pl: &Placement, prev_rate: f64) -> Result<(f64, Vec<f64>)> {
    let rate = match problem.evaluator().max_stable_rate(pl) {
        Ok(r) if r.is_finite() => r,
        Ok(_) => prev_rate,
        Err(_) => 0.0,
    };
    let util = problem.evaluator().evaluate(pl, rate)?.util;
    Ok((rate, util))
}

/// Replay one fleet run.  See the module docs for the control model;
/// everything but the latency percentiles is deterministic in
/// (spec, cfg, mode).
pub fn run_fleet(spec: &FleetSpec, cfg: &ControllerConfig, mode: FleetMode) -> Result<FleetReport> {
    if spec.machines == 0 || spec.tenants == 0 || spec.steps == 0 {
        return Err(Error::Config("fleet spec needs machines, tenants and steps >= 1".into()));
    }
    let (cluster, db) = scenarios::fleet(spec.machines, spec.rack_size);
    let storm = traces::fleet_storm(&cluster, spec.steps, spec.seed);
    let cluster = Arc::new(cluster);
    let db = Arc::new(db);
    let sched = cfg.scheduler()?;

    // tenants: benchmark topologies round-robin, weights striped, each
    // with its own diurnal offered profile (events of which are ignored
    // — the storm trace owns the world)
    let bench = benchmarks::all();
    let t = spec.tenants;
    let mut names: Vec<String> = Vec::with_capacity(t);
    let mut weights: Vec<f64> = Vec::with_capacity(t);
    let mut mult: Vec<Vec<f64>> = Vec::with_capacity(t);
    let mut problems: Vec<Problem> = Vec::with_capacity(t);
    for i in 0..t {
        let top = bench[i % bench.len()].clone();
        names.push(format!("t{i:03}"));
        weights.push([1.0, 1.5, 2.0][i % 3]);
        let tenant_seed = spec.seed.wrapping_add(1000 + i as u64);
        let profile = traces::diurnal(&top, &cluster, spec.steps, tenant_seed);
        mult.push(profile.steps.iter().map(|st| st.offered).collect());
        problems.push(Problem::from_shared(Arc::new(top), cluster.clone(), db.clone())?);
    }

    // mode-independent autoscale signal: weighted mean offered multiplier
    let wsum: f64 = weights.iter().sum();
    let proxies: Vec<f64> = (0..spec.steps)
        .map(|s| weights.iter().zip(&mult).map(|(w, mi)| w * mi[s]).sum::<f64>() / wsum)
        .collect();
    let max_scale = (spec.machines / 50).max(1);

    // day zero: sequential residual admission (identical in both modes)
    let n_m0 = cluster.n_machines();
    let mut total_util = vec![0.0f64; n_m0];
    let mut placements: Vec<Option<Placement>> = vec![None; t];
    let mut rates = vec![0.0f64; t];
    let mut base = vec![0.0f64; t];
    let mut utils: Vec<Vec<f64>> = vec![vec![0.0; n_m0]; t];
    let mut admitted = 0usize;
    for i in 0..t {
        let req = ScheduleRequest::max_throughput()
            .with_constraints(reserve(problems[i].cluster(), &total_util));
        if let Ok(s) = sched.schedule(&problems[i], &req) {
            if s.rate > 0.0 {
                let Schedule { placement, rate, eval, .. } = s;
                for (m, u) in eval.util.iter().enumerate() {
                    total_util[m] += u;
                }
                utils[i] = eval.util;
                rates[i] = rate;
                base[i] = rate;
                placements[i] = Some(placement);
                admitted += 1;
            }
        }
    }

    let step_local = Arc::new(Histogram::new());
    let step_global = crate::obs::global().histogram("control.step_s");
    let replan_hist = crate::obs::global().histogram("control.replan_s");

    let mut rep = FleetReport {
        mode: mode.name(),
        machines: spec.machines,
        tenants: t,
        admitted,
        steps: spec.steps,
        seed: spec.seed,
        events: 0,
        replans: 0,
        replan_steps: 0,
        deferred: 0,
        tasks_moved: 0,
        violations: 0,
        offered_volume: 0.0,
        delivered_volume: 0.0,
        p50_ms: 0.0,
        p95_ms: 0.0,
        p99_ms: 0.0,
        max_ms: 0.0,
    };

    let mut pending: Vec<ClusterEvent> = Vec::new();
    let mut scale_live: Vec<String> = Vec::new();
    let mut scale_counter = 0usize;
    let mut cooldowns = vec![0usize; t];

    for s in 0..spec.steps {
        let mut events: Vec<ClusterEvent> = storm.steps[s].events.clone();
        events.extend(pending.drain(..));
        rep.events += events.len();

        let mut dirty = vec![false; t];
        let mut moved_tenant = vec![0usize; t];
        let mut before: Option<Vec<Placement>> = None;
        let mut replans_step = 0usize;
        {
            let _g = Span::start(step_global.clone());
            let _l = Span::start(step_local.clone());

            // --- 1. absorb this step's world changes, fleet-wide
            let mut leave_names: Vec<String> = Vec::new();
            let mut joins: Vec<(String, String)> = Vec::new();
            let mut drifted = false;
            for ev in &events {
                match ev {
                    ClusterEvent::Leave { machine } => leave_names.push(machine.clone()),
                    ClusterEvent::Join { machine, machine_type } => {
                        joins.push((machine.clone(), machine_type.clone()));
                    }
                    ClusterEvent::Drift { task_type, machine_type, factor } => {
                        Problem::apply_delta_fleet(
                            &mut problems,
                            &ProblemDelta::ProfileDrift {
                                task_type: task_type.clone(),
                                machine_type: machine_type.clone(),
                                factor: *factor,
                            },
                        )?;
                        drifted = true;
                    }
                }
            }
            leave_names
                .retain(|n| problems[0].cluster().machines.iter().any(|m| &m.name == n));
            if !leave_names.is_empty() {
                let mut ms: Vec<usize> = leave_names
                    .iter()
                    .filter_map(|n| {
                        problems[0].cluster().machines.iter().position(|m| &m.name == n)
                    })
                    .collect();
                ms.sort_unstable();
                ms.dedup();
                Problem::apply_machine_leaves_fleet(&mut problems, &leave_names)?;
                for i in 0..t {
                    if let Some(pl) = placements[i].as_mut() {
                        let lost: usize = ms.iter().map(|&m| pl.tasks_on(m)).sum();
                        for row in pl.x.iter_mut() {
                            drop_indices(row, &ms);
                        }
                        if lost > 0 {
                            dirty[i] = true;
                        }
                    }
                    drop_indices(&mut utils[i], &ms);
                }
            }
            for (name, ty) in joins {
                if problems[0].cluster().machines.iter().any(|m| m.name == name) {
                    continue;
                }
                Problem::apply_delta_fleet(
                    &mut problems,
                    &ProblemDelta::MachineJoin { name, machine_type: ty, cap: 100.0 },
                )?;
                for i in 0..t {
                    if let Some(pl) = placements[i].as_mut() {
                        for row in pl.x.iter_mut() {
                            row.push(0);
                        }
                    }
                    utils[i].push(0.0);
                }
                total_util.push(0.0);
            }
            // re-certify tenants whose capacity may have changed, then
            // rebuild the reserved-load ledger once
            if !leave_names.is_empty() || drifted {
                for i in 0..t {
                    if !(drifted || dirty[i]) {
                        continue;
                    }
                    if let Some(pl) = placements[i].as_ref() {
                        let (r, u) = recertify(&problems[i], pl, rates[i])?;
                        rates[i] = r;
                        utils[i] = u;
                    }
                }
                let n_m = problems[0].cluster().n_machines();
                total_util = vec![0.0; n_m];
                for u in &utils {
                    for (m, v) in u.iter().enumerate() {
                        total_util[m] += v;
                    }
                }
            }

            if spec.verify {
                before = Some(snapshot(&placements, &problems));
            }

            // --- 2. dirty detection
            match mode {
                FleetMode::FullReplan => {
                    for (i, p) in placements.iter().enumerate() {
                        if p.is_some() {
                            dirty[i] = true;
                        }
                    }
                }
                FleetMode::Incremental => {
                    for i in 0..t {
                        if placements[i].is_none() || dirty[i] {
                            continue;
                        }
                        let offered = base[i] * mult[i][s];
                        let cap = rates[i];
                        let breach = offered > cap * (1.0 + 1e-9);
                        let ratio = if cap > 0.0 { offered / cap } else { f64::INFINITY };
                        let band = ratio < cfg.band_lo || ratio > cfg.band_hi;
                        if breach || (band && cooldowns[i] == 0) {
                            dirty[i] = true;
                        }
                    }
                }
            }

            // --- 3. re-plans
            match mode {
                FleetMode::Incremental => {
                    let mut moves_left = cfg.max_moves_per_step;
                    for i in 0..t {
                        if !dirty[i] {
                            cooldowns[i] = cooldowns[i].saturating_sub(1);
                            continue;
                        }
                        let Some(old_pl) = placements[i].clone() else { continue };
                        if moves_left == 0 && cfg.max_moves_per_step > 0 {
                            // budget exhausted mid-step: don't pay for
                            // searches whose result could not be adopted
                            rep.deferred += 1;
                            continue;
                        }
                        let n_m = problems[i].cluster().n_machines();
                        let mut residual = vec![0.0f64; n_m];
                        for m in 0..n_m {
                            residual[m] = (total_util[m] - utils[i][m]).max(0.0);
                        }
                        let req = ScheduleRequest::max_throughput()
                            .with_constraints(reserve(problems[i].cluster(), &residual))
                            .with_budget(cfg.replan_budget)
                            .with_warm_start(old_pl.clone());
                        let result = {
                            let _r = Span::start(replan_hist.clone());
                            sched.schedule(&problems[i], &req)
                        };
                        if let Ok(snew) = result {
                            let moved = started_tasks(&old_pl, &snew.placement);
                            if moved > moves_left {
                                rep.deferred += 1;
                                continue;
                            }
                            moves_left -= moved;
                            let Schedule { placement, rate, eval, .. } = snew;
                            for (m, u) in eval.util.iter().enumerate() {
                                total_util[m] += u - utils[i][m];
                            }
                            utils[i] = eval.util;
                            rates[i] = rate;
                            placements[i] = Some(placement);
                            moved_tenant[i] = moved;
                            replans_step += 1;
                            cooldowns[i] = cfg.cooldown_steps;
                        }
                    }
                }
                FleetMode::FullReplan => {
                    let n_m = problems[0].cluster().n_machines();
                    let mut new_total = vec![0.0f64; n_m];
                    for i in 0..t {
                        let Some(old_pl) = placements[i].clone() else { continue };
                        let req = ScheduleRequest::max_throughput()
                            .with_constraints(reserve(problems[i].cluster(), &new_total))
                            .with_budget(SearchBudget::unlimited());
                        let result = {
                            let _r = Span::start(replan_hist.clone());
                            sched.schedule(&problems[i], &req)
                        };
                        match result {
                            Ok(snew) => {
                                let moved = started_tasks(&old_pl, &snew.placement);
                                let Schedule { placement, rate, eval, .. } = snew;
                                for (m, u) in eval.util.iter().enumerate() {
                                    new_total[m] += u;
                                }
                                utils[i] = eval.util;
                                rates[i] = rate;
                                placements[i] = Some(placement);
                                moved_tenant[i] = moved;
                                replans_step += 1;
                            }
                            Err(_) => {
                                // keep the incumbent and its reservation
                                for (m, u) in utils[i].iter().enumerate() {
                                    new_total[m] += u;
                                }
                            }
                        }
                    }
                    total_util = new_total;
                }
            }

            // --- 4. util-band autoscaling (world change lands next step)
            if proxies[s] > AUTOSCALE_HI && scale_live.len() < max_scale {
                let name = format!("scale-{scale_counter}");
                scale_counter += 1;
                pending.push(ClusterEvent::Join {
                    machine: name.clone(),
                    machine_type: CORE_I5.into(),
                });
                scale_live.push(name);
            } else if proxies[s] < AUTOSCALE_LO {
                if let Some(name) = scale_live.pop() {
                    pending.push(ClusterEvent::Leave { machine: name });
                }
            }
        }

        // --- 5. audit (outside the measured step)
        if let Some(before) = before {
            let after = snapshot(&placements, &problems);
            let budget = match mode {
                FleetMode::Incremental => cfg.max_moves_per_step,
                FleetMode::FullReplan => usize::MAX,
            };
            let audit = crate::check::validate_fleet(&names, &before, &after, &dirty, budget);
            rep.violations += audit.violations.len();
        }

        // --- 6. weighted delivery accounting with migration downtime
        let dt = cfg.step_seconds;
        let mut moved_step = 0usize;
        for i in 0..t {
            if placements[i].is_none() {
                continue;
            }
            let offered = base[i] * mult[i][s];
            let downtime = (cfg.migration_cost * moved_tenant[i] as f64).min(dt);
            let delivered = offered.min(rates[i]) * (1.0 - downtime / dt);
            rep.offered_volume += weights[i] * offered * dt;
            rep.delivered_volume += weights[i] * delivered * dt;
            moved_step += moved_tenant[i];
        }
        rep.tasks_moved += moved_step;
        rep.replans += replans_step;
        if replans_step > 0 {
            rep.replan_steps += 1;
        }
    }

    rep.p50_ms = step_local.quantile(0.5) * 1e3;
    rep.p95_ms = step_local.quantile(0.95) * 1e3;
    rep.p99_ms = step_local.quantile(0.99) * 1e3;
    rep.max_ms = step_local.max() * 1e3;
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> FleetSpec {
        FleetSpec { machines: 30, tenants: 6, steps: 40, seed: 3, rack_size: 5, verify: true }
    }

    fn fingerprint(r: &FleetReport) -> (usize, usize, usize, usize, u64, u64) {
        (
            r.events,
            r.replans,
            r.deferred,
            r.tasks_moved,
            r.offered_volume.to_bits(),
            r.delivered_volume.to_bits(),
        )
    }

    #[test]
    fn fleet_run_is_deterministic() {
        let spec = small_spec();
        let cfg = ControllerConfig::default();
        let a = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        let b = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "replay must be bit-identical");
        assert_eq!(a.admitted, 6, "30 machines fit all 6 small tenants");
        assert!(a.events > 0, "the storm trace must perturb the world");
        assert_eq!(a.violations, 0, "clean tenants moved or budget exceeded");
    }

    #[test]
    fn zero_migration_budget_freezes_every_placement() {
        let spec = small_spec();
        let cfg = ControllerConfig { max_moves_per_step: 0, ..Default::default() };
        let r = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        assert_eq!(r.tasks_moved, 0, "budget 0 must never start an instance");
        assert_eq!(r.violations, 0);
    }

    #[test]
    fn full_replan_comparator_replans_everything_and_bounds_the_gap() {
        let spec = small_spec();
        let cfg = ControllerConfig::default();
        let inc = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        let full = run_fleet(&spec, &cfg, FleetMode::FullReplan).unwrap();
        // every placed tenant, every step, minus the occasional step an
        // outage leaves a tenant with no feasible from-scratch placement
        assert!(
            full.replans >= full.admitted * spec.steps * 3 / 4,
            "full mode must re-plan nearly every tenant every step ({} < {})",
            full.replans,
            full.admitted * spec.steps * 3 / 4
        );
        assert!(
            inc.replans < full.replans,
            "incremental must take fewer decisions ({} vs {})",
            inc.replans,
            full.replans
        );
        for r in [&inc, &full] {
            assert!(
                r.delivered_volume <= r.offered_volume * (1.0 + 1e-9),
                "{}: delivered exceeds offered",
                r.mode
            );
            let pct = r.delivered_pct();
            assert!(pct > 50.0, "{}: delivered only {pct:.1}%", r.mode);
        }
        assert!(
            inc.delivered_volume >= 0.7 * full.delivered_volume,
            "incremental lost too much throughput: gap {:.1}%",
            quality_gap_pct(&inc, &full)
        );
    }

    #[test]
    fn oversubscribed_fleet_denies_admission_but_stays_sound() {
        let spec = FleetSpec {
            machines: 6,
            tenants: 30,
            steps: 25,
            seed: 9,
            rack_size: 3,
            verify: true,
        };
        let cfg = ControllerConfig::default();
        let r = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        assert!(r.admitted > 0, "some tenant must fit");
        assert!(r.admitted < 30, "6 machines cannot hold 30 tenants");
        assert_eq!(r.violations, 0);
        assert!(r.delivered_volume <= r.offered_volume * (1.0 + 1e-9));
    }

    #[test]
    fn report_renders_and_roundtrips() {
        let spec = FleetSpec { steps: 20, ..FleetSpec::new(10, 2) };
        let cfg = ControllerConfig::default();
        let r = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
        let text = r.render();
        assert!(text.contains("incremental"), "{text}");
        assert!(text.contains("p99"), "{text}");
        let back = json::parse(&json::to_string_pretty(&r.to_json())).unwrap();
        assert_eq!(back.str_field("mode").unwrap(), "incremental");
        assert_eq!(back.num_field("machines").unwrap(), 10.0);
        assert_eq!(back.num_field("steps").unwrap(), 20.0);
    }

    #[test]
    fn rejects_empty_spec() {
        let cfg = ControllerConfig::default();
        assert!(run_fleet(&FleetSpec::new(0, 5), &cfg, FleetMode::Incremental).is_err());
        assert!(run_fleet(&FleetSpec::new(5, 0), &cfg, FleetMode::Incremental).is_err());
    }
}
