//! Large-scale simulation (paper §6.3 / Fig. 10): run the proposed and
//! default schedulers on the three Table-4 cluster scenarios and report
//! throughput and weighted utilization gains per topology.
//!
//! ```bash
//! cargo run --release --example large_scale
//! ```

use hstorm::experiments::fig10;

fn main() -> hstorm::Result<()> {
    println!("== hstorm large-scale scenarios (Table 4) ==");
    let fig = fig10::run(false)?;
    println!("{}", fig.render());
    let t5 = fig10::table5(false)?;
    println!("{}", t5.render());

    // headline summary, paper-style
    let cells = fig10::cells(false)?;
    for sid in 1..=3 {
        let gains: Vec<f64> =
            cells.iter().filter(|c| c.scenario == sid).map(|c| c.thpt_gain()).collect();
        let lo = gains.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = gains.iter().cloned().fold(0.0, f64::max);
        println!("scenario {sid}: throughput gain {lo:+.0}%..{hi:+.0}% over default");
    }
    println!("(paper: +26..49% small, +36..48% medium, +27..31% large)");
    Ok(())
}
