//! Multi-tenant control loop: admit, drain and re-plan many topologies
//! on one shared cluster over virtual time.
//!
//! Where [`super::run_policy`] drives a single topology against cluster
//! churn, this loop drives a [`WorkloadProblem`]'s tenant set against
//! **tenant** churn:
//!
//! * **Per-tenant traces** — each tenant replays its own offered-rate
//!   profile (a named [`super::traces`] generator seeded per tenant, so
//!   tenants peak at different times).  The cluster itself stays fixed;
//!   machine churn remains the single-tenant controller's domain.
//! * **Admission** ("admit tenant at step t") — tenants present at
//!   step 0 are co-planned **jointly** (each certified at its weighted
//!   share of the day-zero scale); a tenant arriving later is admitted
//!   through [`WorkloadProblem::admit`]: scheduled against the
//!   residual capacity residents leave, residents untouched (no
//!   migration).  A denied tenant retries every following step until
//!   capacity frees up or its drain point passes.
//! * **Eviction** ("drain tenant") — the tenant's placement is dropped
//!   at its drain step; the freed capacity is redistributed at the
//!   next joint re-plan.
//! * **Per-tenant breach detection** — a tenant whose offered rate
//!   exceeds its certified rate is breached.  Re-planning is only
//!   useful when the active set changed since the last plan (the
//!   scheduler is deterministic), so breaches force a re-plan when the
//!   set is **stale** (an admission or drain happened), overriding
//!   cooldown; the utilization band (`Σ offered / Σ certified` outside
//!   `[band_lo, band_hi]`) triggers the same re-plan cooldown-gated.
//!
//! Re-plans are **dirty-tenant residual re-plans**: only the tenants
//! the event actually touched (individually breached or individually
//! outside the band) are re-planned, each through the same
//! [`WorkloadProblem::admit`] path admissions take — scheduled against
//! the residual capacity every *other* resident leaves, warm-started
//! from its incumbent placement, and bounded by the controller's
//! [`replan_budget`](super::ControllerConfig::replan_budget).  Clean
//! residents are never moved, so per-step decision cost scales with
//! what changed, not with fleet size.  A per-step migration budget
//! ([`max_moves_per_step`](super::ControllerConfig::max_moves_per_step))
//! caps how many instances re-plans may start in one step: a re-plan
//! that would exceed the remaining budget is deferred (the tenant keeps
//! its incumbent and retries next step).  Moves charge migration
//! downtime exactly like the single-tenant loop: newly started
//! instances cost `migration_cost` virtual seconds of spout downtime,
//! capped at the step length.  Only day zero still co-plans jointly —
//! everyone present at t=0 is certified at its weighted share via
//! [`WorkloadProblem::schedule_joint`].

use crate::predict::Placement;
use crate::scheduler::workload::{TenantSchedule, WorkloadProblem};
use crate::scheduler::ScheduleRequest;
use crate::util::json::{self, Value};
use crate::{Error, Result};

use super::traces;
use super::ControllerConfig;

/// When a tenant enters and leaves the shared cluster.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantPlan {
    /// First step the tenant asks to run (0 = present from the start).
    pub admit_at: usize,
    /// Step the tenant is drained, if any.
    pub drain_at: Option<usize>,
}

/// One virtual step of one tenant's run.
#[derive(Debug, Clone)]
pub struct TenantStepRow {
    pub t: f64,
    /// Offered rate, tuples/s (the tenant's own stream).
    pub offered: f64,
    /// Certified rate of the tenant's current placement, tuples/s.
    pub capacity: f64,
    /// Delivered after capacity clipping and migration downtime.
    pub delivered: f64,
    /// An admission or joint re-plan changed this tenant's placement.
    pub rescheduled: bool,
    pub migrated: usize,
}

impl TenantStepRow {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("t", json::num(self.t)),
            ("offered", json::num(self.offered)),
            ("capacity", json::num(self.capacity)),
            ("delivered", json::num(self.delivered)),
            ("rescheduled", Value::Bool(self.rescheduled)),
            ("migrated", json::num(self.migrated as f64)),
        ])
    }
}

/// One tenant's aggregates over the whole run.
#[derive(Debug, Clone)]
pub struct TenantControlReport {
    pub name: String,
    pub weight: f64,
    pub admit_at: usize,
    pub drain_at: Option<usize>,
    /// Step the tenant actually entered (admission may be delayed by
    /// denials); `None` when it never got in.
    pub admitted_at: Option<usize>,
    /// Admission attempts that were denied for lack of capacity.
    pub denied_attempts: usize,
    /// Certified rate at admission — the base its trace multiples
    /// scale by.
    pub base_rate: f64,
    pub offered_volume: f64,
    pub delivered_volume: f64,
    pub slo_violation_secs: f64,
    pub tasks_migrated: usize,
    pub rows: Vec<TenantStepRow>,
}

impl TenantControlReport {
    /// Delivered share of offered load, percent.
    pub fn delivered_pct(&self) -> f64 {
        if self.offered_volume > 0.0 {
            self.delivered_volume / self.offered_volume * 100.0
        } else {
            100.0
        }
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("tenant", json::s(&self.name)),
            ("weight", json::num(self.weight)),
            ("admit_at", json::num(self.admit_at as f64)),
            (
                "drain_at",
                self.drain_at.map_or(Value::Null, |d| json::num(d as f64)),
            ),
            (
                "admitted_at",
                self.admitted_at.map_or(Value::Null, |d| json::num(d as f64)),
            ),
            ("denied_attempts", json::num(self.denied_attempts as f64)),
            ("base_rate", json::num(self.base_rate)),
            ("offered_volume", json::num(self.offered_volume)),
            ("delivered_volume", json::num(self.delivered_volume)),
            ("delivered_pct", json::num(self.delivered_pct())),
            ("slo_violation_secs", json::num(self.slo_violation_secs)),
            ("tasks_migrated", json::num(self.tasks_migrated as f64)),
            ("rows", json::arr(self.rows.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

/// The whole multi-tenant run.
#[derive(Debug, Clone)]
pub struct WorkloadControlReport {
    pub workload: String,
    pub trace: String,
    pub seed: u64,
    pub steps: usize,
    /// Re-plan steps after day zero (each step re-plans only the dirty
    /// tenants, against the residual the clean residents leave).
    pub reschedules: usize,
    pub admissions: usize,
    pub drains: usize,
    pub tenants: Vec<TenantControlReport>,
}

impl WorkloadControlReport {
    pub fn tenant(&self, name: &str) -> Option<&TenantControlReport> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Render the aggregate comparison for the terminal.
    pub fn render(&self) -> String {
        let mut out = format!(
            "\n=== workload control — '{}' on trace '{}' ({} steps, seed {}) ===\n",
            self.workload, self.trace, self.steps, self.seed
        );
        out.push_str(&format!(
            "{:<12} {:>7} {:>8} {:>10} {:>10} {:>8} {:>8} {:>9}\n",
            "tenant", "admit", "drain", "base", "deliv %", "SLO-s", "denied", "migrated"
        ));
        out.push_str(&"-".repeat(80));
        out.push('\n');
        for t in &self.tenants {
            out.push_str(&format!(
                "{:<12} {:>7} {:>8} {:>10.1} {:>9.1}% {:>8.0} {:>8} {:>9}\n",
                t.name,
                t.admitted_at.map_or("-".to_string(), |s| s.to_string()),
                t.drain_at.map_or("-".to_string(), |s| s.to_string()),
                t.base_rate,
                t.delivered_pct(),
                t.slo_violation_secs,
                t.denied_attempts,
                t.tasks_migrated
            ));
        }
        out.push_str(&format!(
            "re-plans: {}   admissions: {}   drains: {}\n",
            self.reschedules, self.admissions, self.drains
        ));
        out
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("workload", json::s(&self.workload)),
            ("trace", json::s(&self.trace)),
            ("seed", json::num(self.seed as f64)),
            ("steps", json::num(self.steps as f64)),
            ("reschedules", json::num(self.reschedules as f64)),
            ("admissions", json::num(self.admissions as f64)),
            ("drains", json::num(self.drains as f64)),
            ("tenants", json::arr(self.tenants.iter().map(|t| t.to_json()).collect())),
        ])
    }
}

/// Task instances newly started going `old → new` (same machine list).
/// Shared with the fleet runner's migration accounting and the
/// [`crate::check::validate_fleet`] budget invariant.
pub(crate) fn started_tasks(old: &Placement, new: &Placement) -> usize {
    let mut n = 0usize;
    for (row_old, row_new) in old.x.iter().zip(&new.x) {
        for (k_old, k_new) in row_old.iter().zip(row_new) {
            n += k_new.saturating_sub(*k_old);
        }
    }
    n
}

/// Replay per-tenant offered-rate traces against the workload over
/// `steps` virtual steps.  `plans` is index-aligned with the
/// workload's tenants; `trace_name` picks the rate-profile shape (each
/// tenant seeded `seed + index`, cluster events ignored — the cluster
/// is fixed).
pub fn run_workload(
    wp: &WorkloadProblem,
    plans: &[TenantPlan],
    trace_name: &str,
    steps: usize,
    seed: u64,
    cfg: &ControllerConfig,
) -> Result<WorkloadControlReport> {
    let n = wp.n_tenants();
    if plans.len() != n {
        return Err(Error::Config(format!(
            "{} tenant plans for {} tenants",
            plans.len(),
            n
        )));
    }
    let sched = cfg.scheduler()?;
    let req = ScheduleRequest::max_throughput();

    // per-tenant offered-rate profiles (cluster events are ignored)
    let mut offered_profiles: Vec<Vec<f64>> = Vec::with_capacity(n);
    for (i, tp) in wp.tenants().iter().enumerate() {
        let trace = traces::by_name(
            trace_name,
            tp.problem.topology(),
            wp.cluster(),
            steps,
            seed + i as u64,
        )
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown trace '{trace_name}' (valid: {})",
                traces::NAMES.join("|")
            ))
        })?;
        offered_profiles.push(trace.steps.iter().map(|s| s.offered).collect());
    }

    let mut reports: Vec<TenantControlReport> = wp
        .tenants()
        .iter()
        .zip(plans)
        .map(|(tp, plan)| TenantControlReport {
            name: tp.name.clone(),
            weight: tp.weight,
            admit_at: plan.admit_at,
            drain_at: plan.drain_at,
            admitted_at: None,
            denied_attempts: 0,
            base_rate: 0.0,
            offered_volume: 0.0,
            delivered_volume: 0.0,
            slo_violation_secs: 0.0,
            tasks_migrated: 0,
            rows: Vec::new(),
        })
        .collect();

    let mut schedules: Vec<Option<TenantSchedule>> = vec![None; n];
    let mut reschedules = 0usize;
    let mut admissions = 0usize;
    let mut drains = 0usize;
    let mut cooldown = 0usize;
    let mut stale = false;

    // day zero: co-plan everyone present at t=0 jointly (fair weighted
    // shares); when the joint bound is exceeded the step-0 admission
    // path below picks them up one by one instead
    let day_zero: Vec<usize> = (0..n)
        .filter(|&i| plans[i].admit_at == 0 && plans[i].drain_at != Some(0))
        .collect();
    if !day_zero.is_empty() {
        let sub = wp.subset(&day_zero)?;
        if let Ok(ws) = sub.schedule_joint(sched.as_ref(), &req) {
            for (slot, &i) in day_zero.iter().enumerate() {
                let ts = ws.tenants[slot].clone();
                reports[i].admitted_at = Some(0);
                reports[i].base_rate = ts.schedule.rate;
                schedules[i] = Some(ts);
            }
        }
    }

    let step_hist = crate::obs::global().histogram("workload.step_s");
    for step in 0..steps {
        let _step_span = crate::obs::Span::start(step_hist.clone());
        let dt = cfg.step_seconds;
        let mut migrated: Vec<usize> = vec![0; n];
        let mut touched: Vec<bool> = vec![false; n];
        let mut replanned = false;

        // 1. drains scheduled for this step
        for i in 0..n {
            if plans[i].drain_at == Some(step) && schedules[i].is_some() {
                schedules[i] = None;
                drains += 1;
                stale = true;
            }
        }

        // 2. admissions (first attempt at admit_at, retried on denial)
        for i in 0..n {
            let wants_in = schedules[i].is_none()
                && reports[i].admitted_at.is_none()
                && step >= plans[i].admit_at
                && plans[i].drain_at.map_or(true, |d| step < d);
            if !wants_in {
                continue;
            }
            let residents: Vec<TenantSchedule> =
                schedules.iter().flatten().cloned().collect();
            match wp.admit(&residents, i, sched.as_ref(), &req) {
                Ok(ts) => {
                    migrated[i] += ts.schedule.placement.total_tasks();
                    reports[i].admitted_at = Some(step);
                    reports[i].base_rate = ts.schedule.rate;
                    schedules[i] = Some(ts);
                    touched[i] = true;
                    admissions += 1;
                    stale = true;
                    cooldown = cfg.cooldown_steps;
                    if crate::obs::enabled() {
                        let journal = crate::obs::global().journal();
                        journal.record(crate::obs::Event::AdmissionGranted {
                            tenant: reports[i].name.clone(),
                            step,
                        });
                    }
                }
                Err(_) => {
                    reports[i].denied_attempts += 1;
                    if crate::obs::enabled() {
                        let journal = crate::obs::global().journal();
                        journal.record(crate::obs::Event::AdmissionDenied {
                            tenant: reports[i].name.clone(),
                            step,
                            reason: "capacity".into(),
                        });
                    }
                }
            }
        }

        // 3. offered rates + breach detection over the active set
        let mut offered: Vec<f64> = vec![0.0; n];
        let mut sum_offered = 0.0;
        let mut sum_capacity = 0.0;
        let mut breach = false;
        for i in 0..n {
            let Some(ts) = &schedules[i] else { continue };
            offered[i] = offered_profiles[i][step] * reports[i].base_rate;
            sum_offered += offered[i];
            sum_capacity += ts.schedule.rate;
            if offered[i] > ts.schedule.rate * (1.0 + 1e-9) {
                breach = true;
            }
        }
        let load = if sum_capacity > 0.0 { sum_offered / sum_capacity } else { 0.0 };
        let band = sum_capacity > 0.0 && (load > cfg.band_hi || load < cfg.band_lo);

        // 4. dirty-tenant residual re-plans: only useful when the set
        // changed since the last plan (deterministic scheduler);
        // breaches override cooldown, the band is cooldown-gated.
        // Only the tenants the event touched — individually breached or
        // individually out of band — are re-planned, each against the
        // residual every other resident leaves (the admission path),
        // warm-started from its incumbent and within the per-decision
        // search budget; clean residents never move.
        if stale && (breach || (band && cooldown == 0)) {
            let dirty: Vec<usize> = (0..n)
                .filter(|&i| {
                    let Some(ts) = &schedules[i] else { return false };
                    let cap = ts.schedule.rate;
                    offered[i] > cap * (1.0 + 1e-9)
                        || (cap > 0.0
                            && (offered[i] / cap < cfg.band_lo
                                || offered[i] / cap > cfg.band_hi))
                })
                .collect();
            if !dirty.is_empty() {
                let replan_hist = crate::obs::global().histogram("control.replan_s");
                let _replan_span = crate::obs::Span::start(replan_hist);
                let mut moves_left = cfg.max_moves_per_step;
                let mut deferred = false;
                let mut any = false;
                for &i in &dirty {
                    let Some(old) = schedules[i].clone() else { continue };
                    let residents: Vec<TenantSchedule> = schedules
                        .iter()
                        .enumerate()
                        .filter(|&(j, _)| j != i)
                        .filter_map(|(_, s)| s.clone())
                        .collect();
                    let tenant_req = req
                        .clone()
                        .with_warm_start(old.schedule.placement.clone())
                        .with_budget(cfg.replan_budget);
                    // a residual the incumbent cannot be improved in
                    // (admission denied) keeps the incumbent untouched
                    if let Ok(ts) = wp.admit(&residents, i, sched.as_ref(), &tenant_req) {
                        let moved =
                            started_tasks(&old.schedule.placement, &ts.schedule.placement);
                        if moved > moves_left {
                            // migration budget exhausted: keep the
                            // incumbent, retry next step
                            deferred = true;
                            continue;
                        }
                        moves_left -= moved;
                        if moved > 0 {
                            migrated[i] += moved;
                            touched[i] = true;
                        }
                        schedules[i] = Some(ts);
                        any = true;
                    }
                }
                if any {
                    if crate::obs::enabled() {
                        let journal = crate::obs::global().journal();
                        journal.record(crate::obs::Event::Replanned {
                            policy: "workload".into(),
                            step,
                            cause: if breach { "infeasible".into() } else { "band".into() },
                        });
                    }
                    reschedules += 1;
                    replanned = true;
                    cooldown = cfg.cooldown_steps;
                }
                // budget-deferred tenants keep the set stale so the
                // next step (fresh migration budget) retries them
                stale = deferred;
            } else {
                stale = false;
            }
        } else if !touched.iter().any(|&t| t) {
            // tick the cooldown only on quiet steps (no admission, and
            // this branch is mutually exclusive with the re-plan above),
            // so scheduling actions get their full suppression window
            cooldown = cooldown.saturating_sub(1);
        }

        // 5. delivery accounting per active tenant
        for i in 0..n {
            let Some(ts) = &schedules[i] else { continue };
            let capacity = ts.schedule.rate;
            let downtime = (cfg.migration_cost * migrated[i] as f64).min(dt);
            let delivered = offered[i].min(capacity) * (1.0 - downtime / dt);
            reports[i].offered_volume += offered[i] * dt;
            reports[i].delivered_volume += delivered * dt;
            if delivered + 1e-9 < offered[i] {
                reports[i].slo_violation_secs += dt;
            }
            reports[i].tasks_migrated += migrated[i];
            reports[i].rows.push(TenantStepRow {
                t: step as f64,
                offered: offered[i],
                capacity,
                delivered,
                rescheduled: touched[i] || replanned,
                migrated: migrated[i],
            });
        }
    }

    Ok(WorkloadControlReport {
        workload: wp.workload().name.clone(),
        trace: trace_name.to_string(),
        seed,
        steps,
        reschedules,
        admissions,
        drains,
        tenants: reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::workload::Workload;
    use crate::topology::benchmarks;
    use std::sync::Arc;

    fn duo(scenario: bool) -> WorkloadProblem {
        let (cluster, db) = if scenario {
            crate::cluster::scenarios::by_id(1).unwrap().build()
        } else {
            presets::paper_cluster()
        };
        let db = Arc::new(db);
        let w = Workload::new("duo")
            .tenant("search", benchmarks::linear(), db.clone(), 1.0)
            .tenant("ads", benchmarks::rolling_count(), db.clone(), 1.0);
        WorkloadProblem::new(w, &cluster).unwrap()
    }

    fn cfg() -> ControllerConfig {
        ControllerConfig::default()
    }

    #[test]
    fn plans_must_align_with_tenants() {
        let wp = duo(false);
        let err = run_workload(&wp, &[TenantPlan::default()], "constant", 10, 1, &cfg())
            .unwrap_err()
            .to_string();
        assert!(err.contains("tenant plans"), "{err}");
        assert!(run_workload(
            &wp,
            &[TenantPlan::default(), TenantPlan::default()],
            "nope",
            10,
            1,
            &cfg()
        )
        .is_err());
    }

    #[test]
    fn day_zero_tenants_are_jointly_planned() {
        let wp = duo(true);
        let plans = [TenantPlan::default(), TenantPlan::default()];
        let rep = run_workload(&wp, &plans, "constant", 40, 7, &cfg()).unwrap();
        // co-planned at t=0: no incremental admissions, no denials
        assert_eq!(rep.admissions, 0);
        assert_eq!(rep.drains, 0);
        for t in &rep.tenants {
            assert_eq!(t.admitted_at, Some(0), "{}", t.name);
            assert_eq!(t.denied_attempts, 0, "{}", t.name);
            assert_eq!(t.rows.len(), 40, "{}", t.name);
            assert!(t.base_rate > 0.0);
            // constant 0.8x load on a fresh joint plan is always served
            assert!(t.delivered_pct() > 95.0, "{}: {:.1}%", t.name, t.delivered_pct());
        }
        // equal weights: the day-zero joint plan certifies equal rates
        let a = rep.tenants[0].base_rate;
        let b = rep.tenants[1].base_rate;
        assert!((a - b).abs() < 1e-6, "joint day zero must split {a} vs {b} evenly");
    }

    #[test]
    fn late_admission_never_migrates_residents() {
        let wp = duo(true);
        let plans = [
            TenantPlan::default(),
            TenantPlan { admit_at: 10, drain_at: None },
        ];
        let rep = run_workload(&wp, &plans, "constant", 30, 3, &cfg()).unwrap();
        let ads = rep.tenant("ads").unwrap();
        let search = rep.tenant("search").unwrap();
        assert_eq!(search.rows.len(), 30);
        match ads.admitted_at {
            Some(t_admit) => {
                // admitted into the residual the resident left: the
                // resident's row at that step shows zero migration
                assert!(t_admit >= 10);
                assert_eq!(
                    search.rows[t_admit].migrated, 0,
                    "admission must not move resident tasks"
                );
                assert_eq!(ads.rows.len(), 30 - t_admit);
                assert!(ads.base_rate > 0.0);
            }
            None => {
                // the resident saturated the cluster: every attempt
                // from step 10 on was denied, resident untouched
                assert_eq!(ads.denied_attempts, 20);
                assert!(ads.rows.is_empty());
                assert_eq!(search.tasks_migrated, 0);
            }
        }
    }

    #[test]
    fn drain_frees_capacity_and_breach_replans() {
        let wp = duo(true);
        // both tenants share the cluster at day zero (equal joint
        // shares); ads leaves at step 15, and when search's ramping
        // demand later exceeds its old share, the stale active set is
        // re-planned jointly and search absorbs the freed machines
        let plans = [
            TenantPlan::default(),
            TenantPlan { admit_at: 0, drain_at: Some(15) },
        ];
        let mut c = cfg();
        c.cooldown_steps = 2;
        let rep = run_workload(&wp, &plans, "ramp", 120, 11, &c).unwrap();
        assert_eq!(rep.drains, 1);
        let ads = rep.tenant("ads").unwrap();
        assert_eq!(ads.rows.len(), 15, "drained tenant stops accruing rows");
        let search = rep.tenant("search").unwrap();
        assert_eq!(search.rows.len(), 120);
        // the ramp breaches search's day-zero share -> joint re-plan
        assert!(rep.reschedules >= 1, "stale active set never re-planned");
        // capacity after the re-plan clearly exceeds the shared slice
        let before = search.rows[..15].iter().map(|r| r.capacity).fold(0.0, f64::max);
        let after = search.rows[40..].iter().map(|r| r.capacity).fold(0.0, f64::max);
        assert!(
            after > before * 1.05,
            "freed capacity not redistributed: {after} vs {before}"
        );
    }

    #[test]
    fn zero_migration_budget_never_moves_tasks() {
        let wp = duo(true);
        let plans = [
            TenantPlan::default(),
            TenantPlan { admit_at: 0, drain_at: Some(15) },
        ];
        let mut c = cfg();
        c.cooldown_steps = 2;
        c.max_moves_per_step = 0;
        let rep = run_workload(&wp, &plans, "ramp", 120, 11, &c).unwrap();
        // re-plans that would start instances are deferred forever under
        // a zero budget: nothing migrates after the day-zero co-plan
        for t in &rep.tenants {
            assert_eq!(t.tasks_migrated, 0, "{} moved tasks past a zero budget", t.name);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let wp = duo(true);
        let plans = [
            TenantPlan::default(),
            TenantPlan { admit_at: 5, drain_at: Some(60) },
        ];
        let a = run_workload(&wp, &plans, "diurnal", 80, 42, &cfg()).unwrap();
        let b = run_workload(&wp, &plans, "diurnal", 80, 42, &cfg()).unwrap();
        assert_eq!(
            json::to_string_pretty(&a.to_json()),
            json::to_string_pretty(&b.to_json())
        );
    }

    #[test]
    fn render_names_every_tenant() {
        let wp = duo(false);
        let plans = [TenantPlan::default(), TenantPlan::default()];
        let rep = run_workload(&wp, &plans, "constant", 10, 1, &cfg()).unwrap();
        let text = rep.render();
        assert!(text.contains("search"), "{text}");
        assert!(text.contains("ads"), "{text}");
        assert!(text.contains("re-plans"), "{text}");
    }
}
