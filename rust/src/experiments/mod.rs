//! Experiment harness: one regenerator per paper figure/table.
//!
//! Every function returns an [`ExperimentResult`] (title, headers, rows,
//! notes) that the CLI (`hstorm bench <id>`) and the `benches/*` targets
//! render.  The DESIGN.md experiment index maps each paper artifact to
//! its function here:
//!
//! | id       | paper artifact      | function           |
//! |----------|---------------------|--------------------|
//! | fig3     | Fig. 3              | [`fig3::run`]      |
//! | fig6     | Fig. 6 (+92%)       | [`fig6::run`]      |
//! | fig7     | Fig. 7              | [`fig7::run`]      |
//! | fig8     | Fig. 8              | [`fig8::run`]      |
//! | fig9     | Fig. 9              | [`fig9::run`]      |
//! | fig10    | Fig. 10 + T4        | [`fig10::run`]     |
//! | table5   | Table 5             | [`fig10::table5`]  |
//! | space    | §3 complexity       | [`complexity::run`]|
//! | ablation | design choices      | [`ablation::run`]  |
//! | elastic  | control plane       | [`elastic::run`]   |
//! | accuracy | §6.2 (event-sim)    | [`accuracy::run`]  |
//! | sched-perf | search-engine perf | [`sched_perf::run`]|
//! | tenancy  | multi-tenant modes  | [`tenancy::run`]   |
//! | dataplane | executed throughput | [`dataplane::run`] |
//! | fleet    | fleet control plane | [`fleet::run`]     |
//!
//! `fast: true` shrinks engine windows/design spaces so the whole suite
//! runs in seconds (used by tests); benches use `fast: false`.  Running
//! `sched-perf` / `tenancy` / `fleet` through the CLI additionally
//! writes `BENCH_sched.json` / `BENCH_tenancy.json` / `BENCH_fleet.json`
//! (machine-readable candidates/s + wall time per scenario,
//! joint-vs-incremental-vs-isolated numbers per tenant mix, and
//! per-step decision-latency percentiles + quality gap per fleet
//! configuration, respectively).

pub mod ablation;
pub mod accuracy;
pub mod complexity;
pub mod dataplane;
pub mod elastic;
pub mod fig10;
pub mod fig3;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fleet;
pub mod sched_perf;
pub mod tenancy;

use crate::util::json::{self, Value};

/// A rendered experiment: a table plus free-text notes.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    pub id: &'static str,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl ExperimentResult {
    pub fn new(id: &'static str, title: impl Into<String>, headers: &[&str]) -> Self {
        ExperimentResult {
            id,
            title: title.into(),
            headers: headers.iter().map(|h| h.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn note(&mut self, n: impl Into<String>) {
        self.notes.push(n.into());
    }

    /// Render for the terminal.
    pub fn render(&self) -> String {
        let mut out = format!("\n=== {} — {} ===\n", self.id, self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(cell.len());
                } else {
                    widths.push(cell.len());
                }
            }
        }
        let fmt = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt(row));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    /// JSON form (EXPERIMENTS.md provenance + machine-readable output).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("id", json::s(self.id)),
            ("title", json::s(&self.title)),
            ("headers", json::arr(self.headers.iter().map(|h| json::s(h)).collect())),
            (
                "rows",
                json::arr(
                    self.rows
                        .iter()
                        .map(|r| json::arr(r.iter().map(|c| json::s(c)).collect()))
                        .collect(),
                ),
            ),
            ("notes", json::arr(self.notes.iter().map(|n| json::s(n)).collect())),
        ])
    }
}

/// Format helpers shared by the figure modules.
pub(crate) fn f1(v: f64) -> String {
    format!("{v:.1}")
}

pub(crate) fn f2(v: f64) -> String {
    format!("{v:.2}")
}

pub(crate) fn pct(v: f64) -> String {
    format!("{v:+.1}%")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_everything() {
        let mut r = ExperimentResult::new("figX", "demo", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        r.note("hello");
        let text = r.render();
        assert!(text.contains("figX"));
        assert!(text.contains("demo"));
        assert!(text.contains("hello"));
    }

    #[test]
    fn json_roundtrips() {
        let mut r = ExperimentResult::new("figY", "demo2", &["x"]);
        r.row(vec!["v".into()]);
        let v = r.to_json();
        assert_eq!(v.str_field("id").unwrap(), "figY");
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
