//! The [`MeanStat`] core: its atomics and reset gate imported through
//! `super::sync_shim`, so the identical source file compiles against
//! `std::sync` here and against `loom::sync` inside the `tools/loom`
//! model-checking crate (which re-includes this file by `#[path]`).
//! Keep this file free of `crate::`/`std::sync` paths — the registry
//! plumbing and the unit tests live in the parent module.

use super::sync_shim::{AtomicU64, Ordering, RwLock};

/// Accumulates (sum, count) pairs for mean statistics, e.g. per-tuple
/// service time — the engine-side `e_ij` measurement.
///
/// `sum_ns` and `count` live in two atomics, so a bare two-store
/// `reset` could interleave with a concurrent `observe` (sum cleared,
/// then the observation's add lands, then count cleared — the next
/// mean is skewed by a half-applied sample).  A `RwLock<()>` keeps the
/// pairs coherent: observers and readers share the read side (two
/// relaxed atomic ops under an uncontended read lock), `reset` takes
/// the write side and clears both fields with no observer in flight.
#[derive(Debug)]
pub struct MeanStat {
    sum_ns: AtomicU64,
    count: AtomicU64,
    reset_gate: RwLock<()>,
}

impl Default for MeanStat {
    fn default() -> Self {
        Self::new()
    }
}

impl MeanStat {
    pub fn new() -> Self {
        MeanStat {
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            reset_gate: RwLock::new(()),
        }
    }

    /// Record one observation in seconds.  Accumulated in nanoseconds,
    /// rounded to nearest: the old micro-unit truncation dropped
    /// sub-microsecond observations entirely while still incrementing
    /// `count`, biasing the measured mean (the engine-side `e_ij`)
    /// downward.
    pub fn observe(&self, seconds: f64) {
        let _gate = self.reset_gate.read().unwrap();
        self.sum_ns.fetch_add((seconds * 1e9).round() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in seconds, or `None` with no observations.
    pub fn mean(&self) -> Option<f64> {
        let _gate = self.reset_gate.read().unwrap();
        let n = self.count.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        Some(self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / n as f64)
    }

    /// Clear both accumulators coherently: no concurrent `observe` can
    /// land between the two stores (regression-tested in the parent
    /// module, model-checked exhaustively under `tools/loom`).
    pub fn reset(&self) {
        let _gate = self.reset_gate.write().unwrap();
        self.sum_ns.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}
