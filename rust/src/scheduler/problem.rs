//! The scheduling [`Problem`]: one validated (topology, cluster,
//! profiles) triple, owning the expensive derived state every policy
//! needs — the expanded [`Evaluator`] tables and, optionally, a
//! PJRT-backed batch scorer.
//!
//! Building a `Problem` validates the triple exactly once (topology
//! structure, cluster shape, profile coverage); every subsequent
//! [`Scheduler::schedule`](super::Scheduler::schedule) call reuses the
//! cached tables instead of re-expanding profiles — which is the whole
//! life of the online controller: many requests, one world.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::profile::ProfileDb;
use crate::cluster::{Cluster, Machine};
use crate::predict::Evaluator;
use crate::runtime::scorer::PlacementScorer;
use crate::topology::Topology;
use crate::{Error, Result};

use super::request::Constraints;

/// Borrowed-or-owned constructor inputs: [`Problem::new`] accepts `&T`
/// (cloned exactly once, the classic call shape), `T` (moved in, no
/// copy) or an explicit [`Cow`].  `std` has no blanket
/// `From<&T> for Cow<T>`, so this small local trait supplies the
/// conversion without breaking existing `Problem::new(&top, ...)` calls.
pub trait IntoCow<'a, T: Clone + 'a> {
    fn into_cow(self) -> Cow<'a, T>;
}

impl<'a, T: Clone + 'a> IntoCow<'a, T> for &'a T {
    fn into_cow(self) -> Cow<'a, T> {
        Cow::Borrowed(self)
    }
}

impl<'a, T: Clone + 'a> IntoCow<'a, T> for T {
    fn into_cow(self) -> Cow<'a, T> {
        Cow::Owned(self)
    }
}

impl<'a, T: Clone + 'a> IntoCow<'a, T> for Cow<'a, T> {
    fn into_cow(self) -> Cow<'a, T> {
        self
    }
}

/// One incremental world change a [`Problem`] can absorb in place via
/// [`Problem::apply_delta`] — the copy-on-write alternative to
/// rebuilding the problem per cluster event.
#[derive(Debug, Clone, PartialEq)]
pub enum ProblemDelta {
    /// A machine joins the cluster (named, of an already-known type).
    MachineJoin { name: String, machine_type: String, cap: f64 },
    /// A machine leaves the cluster (drain, failure, scale-down).
    MachineLeave { name: String },
    /// The per-tuple cost of `task_type` on `machine_type` scales by
    /// `factor` (clamped below at `1e-9`, matching the controller's
    /// drift semantics).
    ProfileDrift { task_type: String, machine_type: String, factor: f64 },
}

/// A validated scheduling problem with cached evaluation state.
///
/// The triple is held behind [`Arc`]s so many problems can share one
/// world without copies — the multi-tenant path
/// ([`super::workload::WorkloadProblem`]) builds one `Arc<Cluster>` and
/// M tenant problems against it ([`Problem::from_shared`]).
pub struct Problem {
    top: Arc<Topology>,
    cluster: Arc<Cluster>,
    profiles: Arc<ProfileDb>,
    evaluator: Evaluator,
    scorer: Option<Box<dyn PlacementScorer>>,
    /// Bumped by every applied [`ProblemDelta`]; freshly built problems
    /// start at 0.  Caches keyed on problem identity use this.
    version: u64,
}

impl Problem {
    /// Validate the triple once and cache the expanded profile tables.
    /// Accepts borrowed or owned values ([`IntoCow`]): a borrowed input
    /// is cloned exactly once here, an owned input moves in without a
    /// copy.
    pub fn new<'a>(
        top: impl IntoCow<'a, Topology>,
        cluster: impl IntoCow<'a, Cluster>,
        profiles: impl IntoCow<'a, ProfileDb>,
    ) -> Result<Self> {
        Self::from_shared(
            Arc::new(top.into_cow().into_owned()),
            Arc::new(cluster.into_cow().into_owned()),
            Arc::new(profiles.into_cow().into_owned()),
        )
    }

    /// [`new`](Self::new) over already-shared parts: M problems built
    /// from the same `Arc<Cluster>`/`Arc<ProfileDb>` share one copy of
    /// the world (only the per-problem [`Evaluator`] tables are owned).
    pub fn from_shared(
        top: Arc<Topology>,
        cluster: Arc<Cluster>,
        profiles: Arc<ProfileDb>,
    ) -> Result<Self> {
        // Evaluator::new validates topology + cluster + coverage.
        let evaluator = Evaluator::new(&top, &cluster, &profiles)?;
        Ok(Problem { top, cluster, profiles, evaluator, scorer: None, version: 0 })
    }

    /// Monotonic delta counter: 0 for a freshly built problem, +1 per
    /// applied [`ProblemDelta`].  Two problems with the same construction
    /// inputs and version have identical evaluator state.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Absorb one cluster event as an in-place delta: the shared
    /// cluster/profile `Arc`s are copy-on-write (`Arc::make_mut` clones
    /// only when another problem still shares them) and the cached
    /// [`Evaluator`] is column-patched in `O(C)` per machine event
    /// instead of re-expanded in `O(C·M)` with full re-validation.  The
    /// patched state is bit-identical to a full
    /// [`from_shared`](Self::from_shared) rebuild on the mutated inputs
    /// (pinned by the fleet equivalence suite).  A failed delta leaves
    /// the problem unchanged.  Any attached batch scorer is dropped —
    /// its compiled tables describe the pre-delta world.
    pub fn apply_delta(&mut self, delta: &ProblemDelta) -> Result<()> {
        match delta {
            ProblemDelta::MachineJoin { name, machine_type, cap } => {
                if self.cluster.machines.iter().any(|m| m.name == *name) {
                    return Err(Error::Cluster(format!(
                        "join of '{name}': machine already present"
                    )));
                }
                let type_id = self
                    .cluster
                    .types
                    .iter()
                    .position(|t| t.name == *machine_type)
                    .ok_or_else(|| {
                        Error::Cluster(format!(
                            "join of '{name}': unknown machine type '{machine_type}'"
                        ))
                    })?;
                if !(0.0..=100.0).contains(cap) {
                    return Err(Error::Cluster(format!(
                        "join of '{name}': capacity {cap} outside [0,100]"
                    )));
                }
                Arc::make_mut(&mut self.cluster).machines.push(Machine {
                    name: name.clone(),
                    type_id,
                    cap: *cap,
                });
                if let Err(e) =
                    self.evaluator.patch_machine_join(&self.top, &self.cluster, &self.profiles)
                {
                    // roll the push back (profile coverage gap for the
                    // new machine's type) so the problem stays coherent
                    Arc::make_mut(&mut self.cluster).machines.pop();
                    return Err(e);
                }
            }
            ProblemDelta::MachineLeave { name } => {
                let m = self.machine_index(name)?;
                if self.cluster.n_machines() == 1 {
                    return Err(Error::Cluster(format!(
                        "leave of '{name}' would empty the cluster"
                    )));
                }
                Arc::make_mut(&mut self.cluster).machines.remove(m);
                self.evaluator.patch_machine_leave(m)?;
            }
            ProblemDelta::ProfileDrift { task_type, machine_type, factor } => {
                let profiles = Arc::make_mut(&mut self.profiles);
                let mut p = profiles.get(task_type, machine_type)?;
                p.e *= factor.max(1e-9);
                profiles.insert(task_type, machine_type, p);
                self.evaluator.patch_profile_drift(
                    &self.top,
                    &self.cluster,
                    &self.profiles,
                    task_type,
                    machine_type,
                )?;
            }
        }
        self.scorer = None;
        self.version += 1;
        Ok(())
    }

    /// Apply one delta to a whole fleet of problems that share the same
    /// cluster and profile db (different topologies — one problem per
    /// tenant, built via [`from_shared`](Self::from_shared) on common
    /// `Arc`s).  The first problem absorbs the delta through
    /// [`apply_delta`](Self::apply_delta) — paying the single
    /// copy-on-write clone of the shared parts — and every other
    /// problem adopts the first's updated `Arc`s and column-patches its
    /// own evaluator: `O(C)` per tenant per event, **one** `O(M)`
    /// cluster clone per event for the entire fleet.
    ///
    /// The first problem's failed delta leaves the whole fleet
    /// unchanged.  A failure on a later problem (a profile-coverage gap
    /// for one tenant's task types) leaves the fleet split across
    /// versions — callers should treat that as fatal for the run.
    pub fn apply_delta_fleet(problems: &mut [Problem], delta: &ProblemDelta) -> Result<()> {
        let Some((first, rest)) = problems.split_first_mut() else {
            return Ok(());
        };
        first.apply_delta(delta)?;
        let cluster = first.cluster.clone();
        let profiles = first.profiles.clone();
        for p in rest {
            match delta {
                ProblemDelta::MachineJoin { .. } => {
                    p.evaluator.patch_machine_join(&p.top, &cluster, &profiles)?;
                }
                ProblemDelta::MachineLeave { name } => {
                    let m = p.machine_index(name)?;
                    p.evaluator.patch_machine_leave(m)?;
                }
                ProblemDelta::ProfileDrift { task_type, machine_type, .. } => {
                    p.evaluator.patch_profile_drift(
                        &p.top,
                        &cluster,
                        &profiles,
                        task_type,
                        machine_type,
                    )?;
                }
            }
            p.cluster = cluster.clone();
            p.profiles = profiles.clone();
            p.scorer = None;
            p.version += 1;
        }
        Ok(())
    }

    /// Batched machine-leave across a fleet: remove several machines in
    /// one pass — how a correlated rack outage (every member leaving in
    /// the same step) stays `O(C·M)` per tenant for the whole rack
    /// instead of `O(C·M)` per machine.  Counts as one applied delta
    /// per removed machine for [`version`](Self::version).  Same
    /// sharing contract as [`apply_delta_fleet`](Self::apply_delta_fleet);
    /// a failure partway leaves the fleet split across versions.
    pub fn apply_machine_leaves_fleet(problems: &mut [Problem], names: &[String]) -> Result<()> {
        if names.is_empty() {
            return Ok(());
        }
        let Some(first) = problems.first() else {
            return Ok(());
        };
        let mut ms = Vec::with_capacity(names.len());
        for n in names {
            ms.push(first.machine_index(n)?);
        }
        ms.sort_unstable();
        ms.dedup();
        if ms.len() != names.len() {
            return Err(Error::Cluster("leave batch names a machine twice".into()));
        }
        if ms.len() >= first.cluster.n_machines() {
            return Err(Error::Cluster("leave batch would empty the cluster".into()));
        }
        let mut cluster = (*first.cluster).clone();
        crate::predict::drop_indices(&mut cluster.machines, &ms);
        let cluster = Arc::new(cluster);
        let bump = ms.len() as u64;
        for p in problems {
            p.evaluator.patch_machine_leave_batch(&ms)?;
            p.cluster = cluster.clone();
            p.scorer = None;
            p.version += bump;
        }
        Ok(())
    }

    /// Attach a placement scorer (typically the PJRT AOT scorer built by
    /// [`crate::runtime::scorer::PjRtScorer::new`]); schedulers that
    /// support batch scoring will use it instead of the native mirror.
    pub fn with_scorer(mut self, scorer: Box<dyn PlacementScorer>) -> Self {
        self.scorer = Some(scorer);
        self
    }

    pub fn topology(&self) -> &Topology {
        &self.top
    }

    /// Clone out the shared construction `Arc`s — how the control plane
    /// spawns a copy-on-write world from a day-zero problem without
    /// copying the topology, cluster or profile tables
    /// ([`from_shared`](Self::from_shared) on the returned parts).
    pub fn shared_parts(&self) -> (Arc<Topology>, Arc<Cluster>, Arc<ProfileDb>) {
        (self.top.clone(), self.cluster.clone(), self.profiles.clone())
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn profiles(&self) -> &ProfileDb {
        &self.profiles
    }

    /// The cached evaluation tables (unconstrained capacities).
    pub fn evaluator(&self) -> &Evaluator {
        &self.evaluator
    }

    /// The attached batch scorer, if any.
    pub fn scorer(&self) -> Option<&dyn PlacementScorer> {
        self.scorer.as_deref()
    }

    /// Name of the scoring backend requests will run through.
    pub fn scoring_backend(&self) -> &'static str {
        self.scorer.as_deref().map_or("native", |s| s.backend())
    }

    fn machine_index(&self, name: &str) -> Result<usize> {
        self.cluster
            .machines
            .iter()
            .position(|m| m.name == name)
            .ok_or_else(|| self.unknown_machine(name))
    }

    fn unknown_machine(&self, name: &str) -> Error {
        Error::Schedule(format!(
            "constraint references unknown machine '{name}' (cluster '{}' has: {})",
            self.cluster.name,
            self.cluster
                .machines
                .iter()
                .map(|m| m.name.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        ))
    }

    fn component_index(&self, name: &str) -> Result<usize> {
        self.top
            .components
            .iter()
            .position(|c| c.name == name)
            .ok_or_else(|| {
                Error::Schedule(format!(
                    "constraint references unknown component '{name}' (topology '{}' has: {})",
                    self.top.name,
                    self.top
                        .components
                        .iter()
                        .map(|c| c.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// Resolve name-keyed [`Constraints`] into index form, rejecting
    /// unknown names, non-positive instance caps, out-of-range headroom,
    /// and constraint sets that leave some component with no allowed
    /// machine.
    pub fn resolve(&self, c: &Constraints) -> Result<ResolvedConstraints> {
        let n_comp = self.top.n_components();
        let n_machines = self.cluster.n_machines();
        let mut rc = ResolvedConstraints::unconstrained(n_comp, n_machines);

        if !(0.0..100.0).contains(&c.headroom_pct) {
            return Err(Error::Schedule(format!(
                "reserved headroom must be in [0, 100); got {}",
                c.headroom_pct
            )));
        }
        rc.headroom_pct = c.headroom_pct;

        // Residual-capacity requests at fleet scale carry one entry per
        // occupied machine, so an O(M) name scan per entry would make
        // resolution quadratic in the cluster size; large batches go
        // through a name→index map instead.
        let reserved_idx: Option<BTreeMap<&str, usize>> = (c.reserved_loads.len() >= 16)
            .then(|| {
                self.cluster
                    .machines
                    .iter()
                    .enumerate()
                    .map(|(m, mach)| (mach.name.as_str(), m))
                    .collect()
            });
        for (name, pct) in &c.reserved_loads {
            if !(pct.is_finite() && *pct >= 0.0) {
                return Err(Error::Schedule(format!(
                    "reserved load on '{name}' must be finite and >= 0; got {pct}"
                )));
            }
            let m = match &reserved_idx {
                Some(idx) => *idx
                    .get(name.as_str())
                    .ok_or_else(|| self.unknown_machine(name))?,
                None => self.machine_index(name)?,
            };
            rc.reserved[m] += pct;
        }

        for name in &c.excluded_machines {
            let m = self.machine_index(name)?;
            rc.excluded[m] = true;
        }
        if rc.excluded.iter().all(|&e| e) && n_machines > 0 {
            return Err(Error::Schedule("every machine is excluded".into()));
        }

        for (comp, machines) in &c.pins {
            let ci = self.component_index(comp)?;
            let mut allowed = vec![false; n_machines];
            for mname in machines {
                allowed[self.machine_index(mname)?] = true;
            }
            for (m, slot) in rc.pinned[ci].iter_mut().enumerate() {
                *slot = *slot && allowed[m];
            }
        }

        for (comp, n) in &c.max_instances {
            let ci = self.component_index(comp)?;
            if *n == 0 {
                return Err(Error::Schedule(format!(
                    "max_instances for component '{comp}' must be >= 1 (every \
                     component keeps an instance)"
                )));
            }
            rc.max_instances[ci] = rc.max_instances[ci].min(*n);
        }

        for (ci, comp) in self.top.components.iter().enumerate() {
            if (0..n_machines).all(|m| !rc.allows(ci, m)) {
                return Err(Error::Schedule(format!(
                    "constraints leave component '{}' with no allowed machine \
                     (pins ∩ non-excluded = ∅)",
                    comp.name
                )));
            }
        }
        Ok(rc)
    }

    /// The evaluator the request actually schedules against: capacities
    /// shrunk by the reserved headroom and by any per-machine reserved
    /// loads (excluded machines keep their budget — they simply host
    /// nothing, enforced by the search).  Per-machine reservations are
    /// how incremental tenant admission sees residents: the load the
    /// already-scheduled tenants put on each machine is reserved, so
    /// every closed-form rate the kernels derive reads
    /// `(cap_m − resident_m − b_m)/a_m` — the residual-capacity view.
    /// Without headroom or reservations this borrows the cached tables;
    /// only a capacity-modifying request pays for a clone.
    pub fn constrained_evaluator(&self, rc: &ResolvedConstraints) -> Cow<'_, Evaluator> {
        if rc.headroom_pct <= 0.0 && rc.reserved.iter().all(|&r| r <= 0.0) {
            return Cow::Borrowed(&self.evaluator);
        }
        let mut ev = self.evaluator.clone();
        for (m, cap) in ev.cap.iter_mut().enumerate() {
            *cap = (*cap - rc.headroom_pct - rc.reserved[m]).max(0.0);
        }
        Cow::Owned(ev)
    }
}

/// [`Constraints`] resolved to component/machine indices.
#[derive(Debug, Clone)]
pub struct ResolvedConstraints {
    /// Machines that must host zero instances.
    pub excluded: Vec<bool>,
    /// Per component: machines its instances may use (`true` = allowed
    /// by pinning; exclusion is applied on top in [`Self::allows`]).
    pinned: Vec<Vec<bool>>,
    /// Per component: instance-count ceiling.
    pub max_instances: Vec<usize>,
    /// CPU percentage points reserved on every machine.
    pub headroom_pct: f64,
    /// Per-machine CPU percentage points already spoken for (resident
    /// tenants' load in incremental admission).
    pub reserved: Vec<f64>,
}

impl ResolvedConstraints {
    /// No restrictions: everything allowed, unbounded counts.
    pub fn unconstrained(n_comp: usize, n_machines: usize) -> Self {
        ResolvedConstraints {
            excluded: vec![false; n_machines],
            pinned: vec![vec![true; n_machines]; n_comp],
            max_instances: vec![usize::MAX; n_comp],
            headroom_pct: 0.0,
            reserved: vec![0.0; n_machines],
        }
    }

    /// May component `c` place an instance on machine `m`?
    #[inline]
    pub fn allows(&self, c: usize, m: usize) -> bool {
        !self.excluded[m] && self.pinned[c][m]
    }

    /// Indices of excluded machines (for reporting).
    pub fn excluded_indices(&self) -> Vec<usize> {
        self.excluded
            .iter()
            .enumerate()
            .filter_map(|(m, &e)| e.then_some(m))
            .collect()
    }

    /// True when the constraints restrict nothing.
    pub fn is_trivial(&self) -> bool {
        self.headroom_pct == 0.0
            && self.reserved.iter().all(|&r| r == 0.0)
            && self.excluded.iter().all(|&e| !e)
            && self.pinned.iter().all(|row| row.iter().all(|&a| a))
            && self.max_instances.iter().all(|&n| n == usize::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    #[test]
    fn new_validates_and_caches() {
        let p = problem();
        assert_eq!(p.evaluator().n_components(), p.topology().n_components());
        assert_eq!(p.scoring_backend(), "native");
    }

    #[test]
    fn resolve_trivial() {
        let p = problem();
        let rc = p.resolve(&Constraints::new()).unwrap();
        assert!(rc.is_trivial());
        for c in 0..p.topology().n_components() {
            for m in 0..p.cluster().n_machines() {
                assert!(rc.allows(c, m));
            }
        }
    }

    #[test]
    fn resolve_exclusion_and_pins() {
        let p = problem();
        let rc = p
            .resolve(
                &Constraints::new()
                    .exclude_machine("i3-0")
                    .pin_component("spout", ["pentium-0", "i3-0"])
                    .max_instances("spout", 2),
            )
            .unwrap();
        assert!(!rc.is_trivial());
        let i3 = p.cluster().machines.iter().position(|m| m.name == "i3-0").unwrap();
        let pent = p.cluster().machines.iter().position(|m| m.name == "pentium-0").unwrap();
        let spout = p.topology().components.iter().position(|c| c.name == "spout").unwrap();
        assert!(rc.excluded[i3]);
        assert_eq!(rc.excluded_indices(), vec![i3]);
        // pinned to {pentium-0, i3-0}, but i3-0 is excluded
        assert!(rc.allows(spout, pent));
        assert!(!rc.allows(spout, i3));
        assert_eq!(rc.max_instances[spout], 2);
        // other components untouched by the pin
        for m in 0..p.cluster().n_machines() {
            if m != i3 {
                assert!(rc.allows(1, m));
            }
        }
    }

    #[test]
    fn resolve_rejects_unknown_names() {
        let p = problem();
        let err = p.resolve(&Constraints::new().exclude_machine("ghost")).unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
        assert!(err.to_string().contains("pentium-0"), "error should list valid machines: {err}");
        assert!(p.resolve(&Constraints::new().pin_component("nope", ["pentium-0"])).is_err());
        assert!(p.resolve(&Constraints::new().max_instances("spout", 0)).is_err());
        assert!(p.resolve(&Constraints::new().reserve_headroom(100.0)).is_err());
        assert!(p.resolve(&Constraints::new().reserve_headroom(-1.0)).is_err());
    }

    #[test]
    fn resolve_rejects_unsatisfiable_sets() {
        let p = problem();
        // pin a component onto an excluded machine only
        let c =
            Constraints::new().exclude_machine("pentium-0").pin_component("spout", ["pentium-0"]);
        assert!(p.resolve(&c).is_err());
        // exclude everything
        let c = Constraints::new().exclude_machines(["pentium-0", "i3-0", "i5-0"]);
        match p.resolve(&c) {
            Err(e) => assert!(e.to_string().contains("excluded"), "{e}"),
            Ok(_) => panic!("excluding every machine must be rejected"),
        }
    }

    #[test]
    fn construction_takes_borrowed_owned_and_shared() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        // borrowed (the classic shape): inputs cloned once
        let a = Problem::new(&top, &cluster, &db).unwrap();
        // owned: moved in without a copy
        let b = Problem::new(top.clone(), cluster.clone(), db.clone()).unwrap();
        assert_eq!(a.evaluator().cap, b.evaluator().cap);
        // shared: two problems over one Arc'd cluster — no world copies
        let cluster = std::sync::Arc::new(cluster);
        let db = std::sync::Arc::new(db);
        let c = Problem::from_shared(
            std::sync::Arc::new(benchmarks::linear()),
            cluster.clone(),
            db.clone(),
        )
        .unwrap();
        let d = Problem::from_shared(
            std::sync::Arc::new(benchmarks::diamond()),
            cluster.clone(),
            db.clone(),
        )
        .unwrap();
        assert!(std::ptr::eq(c.cluster(), d.cluster()), "cluster must be shared, not copied");
        assert!(std::ptr::eq(c.profiles(), d.profiles()));
    }

    fn assert_same_tables(a: &Problem, b: &Problem) {
        let (ea, eb) = (a.evaluator(), b.evaluator());
        assert_eq!(ea.n_machines(), eb.n_machines());
        assert_eq!(ea.e_m, eb.e_m);
        assert_eq!(ea.met_m, eb.met_m);
        for (x, y) in ea.cap.iter().zip(&eb.cap) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn apply_delta_matches_full_rebuild() {
        let mut p = problem();
        assert_eq!(p.version(), 0);
        let deltas = [
            ProblemDelta::MachineJoin {
                name: "fresh-0".into(),
                machine_type: "core-i5".into(),
                cap: 100.0,
            },
            ProblemDelta::ProfileDrift {
                task_type: "midCompute".into(),
                machine_type: "core-i3".into(),
                factor: 1.25,
            },
            ProblemDelta::MachineLeave { name: "pentium-0".into() },
        ];
        for (i, d) in deltas.iter().enumerate() {
            p.apply_delta(d).unwrap();
            assert_eq!(p.version(), i as u64 + 1);
            let rebuilt = Problem::new(p.topology(), p.cluster(), p.profiles()).unwrap();
            assert_same_tables(&p, &rebuilt);
        }
    }

    #[test]
    fn apply_delta_rejects_bad_events_untouched() {
        let mut p = problem();
        let before = p.evaluator().cap.clone();
        assert!(p
            .apply_delta(&ProblemDelta::MachineLeave { name: "ghost".into() })
            .is_err());
        assert!(p
            .apply_delta(&ProblemDelta::MachineJoin {
                name: "x-0".into(),
                machine_type: "no-such-type".into(),
                cap: 100.0,
            })
            .is_err());
        assert!(p
            .apply_delta(&ProblemDelta::MachineJoin {
                name: "pentium-0".into(), // duplicate name
                machine_type: "core-i5".into(),
                cap: 100.0,
            })
            .is_err());
        assert!(p
            .apply_delta(&ProblemDelta::ProfileDrift {
                task_type: "ghostTask".into(),
                machine_type: "core-i5".into(),
                factor: 1.1,
            })
            .is_err());
        assert_eq!(p.version(), 0, "failed deltas must not bump the version");
        assert_eq!(p.evaluator().cap, before);
    }

    #[test]
    fn apply_delta_cow_leaves_sharers_unchanged() {
        let (cluster, db) = presets::paper_cluster();
        let cluster = std::sync::Arc::new(cluster);
        let db = std::sync::Arc::new(db);
        let top = std::sync::Arc::new(benchmarks::linear());
        let mut a = Problem::from_shared(top.clone(), cluster.clone(), db.clone()).unwrap();
        let b = Problem::from_shared(top, cluster.clone(), db).unwrap();
        a.apply_delta(&ProblemDelta::MachineLeave { name: "i3-0".into() }).unwrap();
        assert_eq!(a.cluster().n_machines(), 2);
        // b still sees the original shared world
        assert_eq!(b.cluster().n_machines(), 3);
        assert_eq!(cluster.n_machines(), 3);
    }

    #[test]
    fn apply_delta_fleet_keeps_problems_in_lockstep() {
        let (cluster, db) = presets::paper_cluster();
        let cluster = std::sync::Arc::new(cluster);
        let db = std::sync::Arc::new(db);
        let mut fleet: Vec<Problem> = [benchmarks::linear(), benchmarks::diamond()]
            .into_iter()
            .map(|t| {
                Problem::from_shared(std::sync::Arc::new(t), cluster.clone(), db.clone()).unwrap()
            })
            .collect();
        let deltas = [
            ProblemDelta::MachineJoin {
                name: "fresh-0".into(),
                machine_type: "core-i5".into(),
                cap: 100.0,
            },
            ProblemDelta::ProfileDrift {
                task_type: "midCompute".into(),
                machine_type: "core-i3".into(),
                factor: 1.2,
            },
            ProblemDelta::MachineLeave { name: "i3-0".into() },
        ];
        for (i, d) in deltas.iter().enumerate() {
            Problem::apply_delta_fleet(&mut fleet, d).unwrap();
            // one shared post-delta world, not one clone per tenant
            assert!(
                std::ptr::eq(fleet[0].cluster(), fleet[1].cluster()),
                "fleet clusters diverged after delta {i}"
            );
            assert!(std::ptr::eq(fleet[0].profiles(), fleet[1].profiles()));
            for p in &fleet {
                assert_eq!(p.version(), i as u64 + 1);
                let rebuilt = Problem::new(p.topology(), p.cluster(), p.profiles()).unwrap();
                assert_same_tables(p, &rebuilt);
            }
        }
        // the original day-zero Arc is untouched
        assert_eq!(cluster.n_machines(), 3);
    }

    #[test]
    fn machine_leave_batch_matches_sequential_deltas() {
        let (cluster, db) = presets::paper_cluster();
        let cluster = std::sync::Arc::new(cluster);
        let db = std::sync::Arc::new(db);
        let build = || -> Vec<Problem> {
            [benchmarks::linear(), benchmarks::diamond()]
                .into_iter()
                .map(|t| {
                    Problem::from_shared(std::sync::Arc::new(t), cluster.clone(), db.clone())
                        .unwrap()
                })
                .collect()
        };
        let mut batched = build();
        let mut sequential = build();
        // unsorted input on purpose — the batch sorts internally
        Problem::apply_machine_leaves_fleet(
            &mut batched,
            &["i5-0".to_string(), "pentium-0".to_string()],
        )
        .unwrap();
        for name in ["pentium-0", "i5-0"] {
            Problem::apply_delta_fleet(
                &mut sequential,
                &ProblemDelta::MachineLeave { name: name.into() },
            )
            .unwrap();
        }
        for (a, b) in batched.iter().zip(&sequential) {
            assert_eq!(a.version(), 2);
            assert_eq!(a.version(), b.version());
            let names = |p: &Problem| -> Vec<String> {
                p.cluster().machines.iter().map(|m| m.name.clone()).collect()
            };
            assert_eq!(names(a), names(b));
            assert_same_tables(a, b);
        }
        // rejects duplicates and emptying batches
        let mut f = build();
        assert!(Problem::apply_machine_leaves_fleet(
            &mut f,
            &["i3-0".to_string(), "i3-0".to_string()]
        )
        .is_err());
        assert!(Problem::apply_machine_leaves_fleet(
            &mut f,
            &["pentium-0".to_string(), "i3-0".to_string(), "i5-0".to_string()]
        )
        .is_err());
    }

    #[test]
    fn reserved_load_shrinks_named_machine_budget() {
        let p = problem();
        let rc = p
            .resolve(
                &Constraints::new()
                    .reserve_machine_load("pentium-0", 40.0)
                    .reserve_machine_load("i3-0", 15.0)
                    .reserve_machine_load("i3-0", 5.0),
            )
            .unwrap();
        assert!(!rc.is_trivial());
        let ev = p.constrained_evaluator(&rc);
        assert!(matches!(ev, Cow::Owned(_)));
        assert!((ev.cap[0] - (p.evaluator().cap[0] - 40.0)).abs() < 1e-12);
        // repeated reservations on one machine accumulate
        assert!((ev.cap[1] - (p.evaluator().cap[1] - 20.0)).abs() < 1e-12);
        assert_eq!(ev.cap[2], p.evaluator().cap[2]);
        // over-reservation clamps at zero rather than going negative
        let rc = p.resolve(&Constraints::new().reserve_machine_load("i5-0", 500.0)).unwrap();
        assert_eq!(p.constrained_evaluator(&rc).cap[2], 0.0);
        // invalid inputs rejected
        assert!(p.resolve(&Constraints::new().reserve_machine_load("ghost", 1.0)).is_err());
        assert!(p.resolve(&Constraints::new().reserve_machine_load("i3-0", -1.0)).is_err());
        assert!(p
            .resolve(&Constraints::new().reserve_machine_load("i3-0", f64::NAN))
            .is_err());
    }

    #[test]
    fn constrained_evaluator_applies_headroom() {
        let p = problem();
        let rc = p.resolve(&Constraints::new().reserve_headroom(25.0)).unwrap();
        let ev = p.constrained_evaluator(&rc);
        assert!(matches!(ev, Cow::Owned(_)));
        for (m, cap) in ev.cap.iter().enumerate() {
            assert!((cap - (p.evaluator().cap[m] - 25.0)).abs() < 1e-12);
        }
        // trivial constraints share the cached tables, capacities intact
        let rc0 = p.resolve(&Constraints::new()).unwrap();
        let ev0 = p.constrained_evaluator(&rc0);
        assert!(matches!(ev0, Cow::Borrowed(_)));
        assert_eq!(ev0.cap, p.evaluator().cap);
    }
}
