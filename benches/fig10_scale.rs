//! Bench: regenerate Fig. 10 (large-scale simulation over the Table-4
//! scenarios) and time the scheduler at each cluster size — the paper's
//! point that the heuristic stays fast where exhaustive search explodes.
//! Run: cargo bench --bench fig10_scale  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::scenarios::SCENARIOS;
use hstorm::experiments::fig10;
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::topology::benchmarks;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig10::run(fast).expect("fig10 runs"));
    println!("{}", result.render());
    println!("[fig10_scale] regenerated in {dt:?} (fast={fast})\n");

    // scheduler latency per scenario size (small/medium/large)
    let hetero = registry::create("hetero", &PolicyParams::default()).expect("hetero registered");
    let req = ScheduleRequest::max_throughput();
    for s in SCENARIOS.iter().take(if fast { 2 } else { 3 }) {
        let (cluster, db) = s.build();
        let top = benchmarks::diamond();
        let problem = Problem::new(&top, &cluster, &db).expect("problem");
        let iters = if s.total_machines() > 100 { 3 } else { 10 };
        bench::run(
            &format!("hetero schedule, scenario {} ({} machines)", s.id, s.total_machines()),
            1,
            iters,
            || {
                hetero.schedule(&problem, &req).expect("schedules");
            },
        );
    }
}
