"""L1 Pallas kernel: batched placement scoring (the evaluation hot-spot).

For a batch of candidate placements ``X[b, c, m]`` (number of instances of
component ``c`` assigned to machine ``m``) and per-task input rates
``ir_task[b, c]``, computes the predicted CPU utilization of every machine
(paper eq. 5 summed per machine):

    util[b, m] = sum_c X[b,c,m] * (e_m[c,m] * ir_task[b,c] + met_m[c,m])

``e_m``/``met_m`` are the profile tables already gathered per *machine*
(the Rust side expands ``e[c, type]`` by each machine's type, so the kernel
sees a dense [C, M] table and needs no gather).

Kernel structure (the TPU mapping documented in DESIGN.md §Hardware
adaptation): grid over the batch axis; each grid step loads one
``[BLOCK_B, C, M]`` candidate tile plus the tiny resident ``[C, M]``
profile tables into VMEM and contracts over ``C`` — an MXU-shaped
reduction.  ``interpret=True`` everywhere on CPU; on a real TPU the same
BlockSpec schedule double-buffers candidate tiles HBM->VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..dims import BLOCK_B


def _score_kernel(x_ref, ir_ref, em_ref, met_ref, util_ref):
    x = x_ref[...]            # [bB, C, M]  instance counts
    ir = ir_ref[...]          # [bB, C]     per-task input rate
    em = em_ref[...]          # [C, M]      e_ij expanded per machine
    met = met_ref[...]        # [C, M]      MET_ij expanded per machine
    # TCU of one instance of component c on machine m, per candidate:
    per_task = em[None, :, :] * ir[:, :, None] + met[None, :, :]
    # Machine utilization: contract over the component axis.
    util_ref[...] = jnp.sum(x * per_task, axis=1)


def score_utilization(x, ir_task, e_m, met_m, *, block_b=None, interpret=True):
    """Predicted per-machine CPU utilization for a batch of placements.

    Args:
      x:       f32[B, C, M] instance counts (0 for padding).
      ir_task: f32[B, C]    input rate of one instance of each component.
      e_m:     f32[C, M]    per-tuple execution cost of c on machine m.
      met_m:   f32[C, M]    per-instance miscellaneous overhead.
    Returns:
      f32[B, M] predicted utilization (percent of MAC budget).
    """
    B, C, M = x.shape
    bb = block_b or min(BLOCK_B, B)
    assert B % bb == 0, f"batch {B} not divisible by block {bb}"
    return pl.pallas_call(
        _score_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C, M), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, C), lambda i: (i, 0)),
            pl.BlockSpec((C, M), lambda i: (0, 0)),
            pl.BlockSpec((C, M), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, M), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, M), x.dtype),
        interpret=interpret,
    )(x, ir_task, e_m, met_m)
