"""AOT lowering tests: the HLO-text artifacts must be loadable-shaped
(entry layout matches what rust/src/runtime expects) and the lowered
module must be numerically identical to the eager model."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import dims
from compile.aot import lower_scorer, lower_work, to_hlo_text
from compile.kernels.ref import evaluate_placements_ref
from compile.model import bolt_work, evaluate_placements

jax.config.update("jax_platform_name", "cpu")


def case(b):
    rng = np.random.default_rng(7)
    C, M = dims.C, dims.M
    x = rng.integers(0, 3, size=(b, C, M)).astype(np.float32)
    adj = np.zeros((C, C), np.float32)
    for i in range(4):
        adj[i, i + 1] = 1.0
    alpha = np.ones(C, np.float32)
    src = np.zeros(C, np.float32)
    src[0] = 1.0
    r0 = np.full(b, 25.0, np.float32)
    e_m = (rng.random((C, M)) * 0.2).astype(np.float32)
    met_m = (rng.random((C, M)) * 3).astype(np.float32)
    cap = np.full(M, 100.0, np.float32)
    active = np.zeros(C, np.float32)
    active[:5] = 1.0
    return (x, adj, alpha, src, r0, e_m, met_m, cap, active)


class TestLowering:
    @pytest.mark.parametrize("b", [dims.B_ONE, dims.B_BATCH])
    def test_scorer_hlo_entry_layout(self, b):
        text = lower_scorer(b)
        assert "HloModule" in text
        # entry layout: x is [b, C, M] f32, 4-tuple result
        assert f"f32[{b},{dims.C},{dims.M}]" in text
        assert f"f32[{b},{dims.M}]" in text  # util output

    def test_work_hlo_shape(self):
        text = lower_work()
        assert f"f32[{dims.WORK_N}]" in text

    def test_scorer_cpu_executable(self):
        """The artifact must run on the CPU PJRT client: Pallas kernels
        lowered with interpret=True produce plain HLO (while-loops over
        the grid), never a Mosaic/TPU custom-call."""
        text = lower_scorer(dims.B_BATCH)
        assert "custom-call" not in text.lower(), "TPU-only lowering leaked in"
        # propagation is unrolled at trace time (EXPERIMENTS.md §Perf):
        # DEPTH pallas dispatch loops, not DEPTH x grid many
        assert text.count("while(") <= 8 * dims.DEPTH


class TestModelSemantics:
    def test_jit_matches_ref_both_batch_sizes(self):
        for b in (dims.B_ONE, dims.B_BATCH):
            args = case(b)
            fn = jax.jit(functools.partial(evaluate_placements,
                                           depth=dims.DEPTH, interpret=True))
            got = fn(*(jnp.array(a) for a in args))
            want = evaluate_placements_ref(*args, depth=dims.DEPTH)
            for g, w in zip(got, want):
                assert_allclose(np.asarray(g), np.asarray(w),
                                rtol=1e-4, atol=1e-4)

    def test_depth_exactness(self):
        """Any depth >= longest path gives the identical fixed point."""
        args = case(8)
        a = evaluate_placements_ref(*args, depth=5)
        b = evaluate_placements_ref(*args, depth=dims.DEPTH)
        for g, w in zip(a, b):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)

    def test_work_kernel_burns_deterministically(self):
        x = jnp.linspace(-1.0, 1.0, dims.WORK_N)
        (y1,) = jax.jit(bolt_work)(x)
        (y2,) = jax.jit(bolt_work)(x)
        assert_allclose(np.asarray(y1), np.asarray(y2))


class TestDimsConsistency:
    def test_dims_match_rust_constants(self):
        """python/compile/dims.py and rust/src/runtime/dims.rs must agree;
        this parses the Rust source so drift fails the Python suite too."""
        import re
        import pathlib

        rust = pathlib.Path(__file__).resolve().parents[2] / "rust/src/runtime/dims.rs"
        text = rust.read_text()

        def rust_const(name):
            mm = re.search(rf"pub const {name}: \w+ = (\d+)", text)
            assert mm, f"missing const {name}"
            return int(mm.group(1))

        assert rust_const("MAX_COMPONENTS") == dims.C
        assert rust_const("MAX_MACHINES") == dims.M
        assert rust_const("DEPTH") == dims.DEPTH
        assert rust_const("B_BATCH") == dims.B_BATCH
        assert rust_const("B_ONE") == dims.B_ONE
        assert rust_const("WORK_N") == dims.WORK_N

    def test_roundtrip_helper_rejects_bad_module(self):
        with pytest.raises(Exception):
            to_hlo_text(None)
