//! Shared name → object resolvers for every user-facing entry point.
//!
//! The CLI (`hstorm schedule --scheduler ... --topology ... --scenario
//! ...`) and the JSON config runner (`"scheduler": ...`) used to each
//! carry their own lookup-and-error code, which drifted independently.
//! Both now resolve through this module: topology names via
//! [`crate::topology::benchmarks`], cluster scenarios via
//! [`crate::cluster::scenarios`], and scheduler policies via
//! [`crate::scheduler::registry`] — one spelling of every name, one
//! error message listing the valid options.

use crate::cluster::profile::ProfileDb;
use crate::cluster::{presets, scenarios, Cluster};
use crate::scheduler::{registry, PolicyParams, Scheduler};
use crate::topology::{benchmarks, Topology};
use crate::{Error, Result};

/// Resolve a benchmark topology by name.
pub fn topology(name: &str) -> Result<Topology> {
    benchmarks::by_name(name).ok_or_else(|| {
        Error::Config(format!(
            "unknown topology '{name}' (valid: {})",
            benchmarks::NAMES.join("|")
        ))
    })
}

/// Resolve a cluster: `Some(scenario_id)` picks a Table-4 scenario,
/// `None` the paper's Table-2 cluster.
pub fn cluster(scenario: Option<&str>) -> Result<(Cluster, ProfileDb)> {
    match scenario {
        Some(s) => {
            let id: usize = s.parse().map_err(|_| {
                Error::Config(format!(
                    "--scenario: '{s}' is not a number (valid: {})",
                    scenarios::describe_all()
                ))
            })?;
            let sc = scenarios::by_id(id).ok_or_else(|| {
                Error::Config(format!(
                    "unknown scenario '{id}' (valid: {})",
                    scenarios::describe_all()
                ))
            })?;
            Ok(sc.build())
        }
        None => Ok(presets::paper_cluster()),
    }
}

/// Resolve a scheduler policy by registry name (or alias).
pub fn policy(name: &str, params: &PolicyParams) -> Result<Box<dyn Scheduler>> {
    registry::create(name, params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_by_name_or_listed_error() {
        assert_eq!(topology("linear").unwrap().name, "linear");
        let err = topology("moebius").unwrap_err().to_string();
        assert!(err.contains("linear"), "{err}");
    }

    #[test]
    fn cluster_default_and_scenarios() {
        let (c, _) = cluster(None).unwrap();
        assert_eq!(c.n_machines(), 3);
        let (c1, _) = cluster(Some("1")).unwrap();
        assert!(c1.n_machines() > 3);
        assert!(cluster(Some("99")).is_err());
        assert!(cluster(Some("one")).is_err());
    }

    #[test]
    fn policy_resolves_via_registry() {
        let p = policy("hetero", &PolicyParams::default()).unwrap();
        assert_eq!(p.name(), "hetero");
        assert!(policy("bogus", &PolicyParams::default()).is_err());
    }
}
