//! Control-loop micro-benchmark: steps/sec of the online control plane
//! over virtual time.  The loop is purely analytic — no wall-clock
//! sleeping — so thousand-step traces must run in milliseconds; this
//! bench keeps that property honest across cluster scales and policies.
//! Run: cargo bench --bench controller  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::scenarios;
use hstorm::controller::{self, traces, ControllerConfig, Policy};
use hstorm::topology::benchmarks;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let iters = if fast { 3 } else { 20 };
    let steps = 1000usize;
    let top = benchmarks::linear();

    for scenario_id in [1usize, 2] {
        let (cluster, db) = scenarios::by_id(scenario_id).expect("scenario").build();
        let cfg = ControllerConfig::default();
        for (policy, label) in [
            (Policy::Static, "static"),
            (Policy::Reactive, "reactive"),
            (Policy::Oracle, "oracle"),
        ] {
            let trace = traces::diurnal(&top, &cluster, steps, 42);
            let m = bench::run(
                &format!("control loop {steps} steps, scenario {scenario_id}, {label}"),
                1,
                iters,
                || {
                    controller::run_policy(&top, &cluster, &db, &trace, policy, &cfg)
                        .expect("control loop runs");
                },
            );
            println!(
                "  -> {:.0} virtual steps/sec",
                m.throughput(steps as f64)
            );
        }
    }
}
