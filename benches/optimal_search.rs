//! Bench: the §3 complexity story — design-space sizes, candidate
//! scoring rate through the batched AOT scorer (PJRT) vs the native
//! mirror, and the measured wall time of a full bounded optimal search
//! (the paper's comparator needed ~18 h on its server).
//! Run: cargo bench --bench optimal_search  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::presets;
use hstorm::experiments::complexity;
use hstorm::predict::Placement;
use hstorm::runtime::scorer::{NativeScorer, PlacementScorer};
use hstorm::scheduler::optimal::OptimalScheduler;
use hstorm::scheduler::{Problem, ScheduleRequest, Scheduler};
use hstorm::topology::benchmarks;
use hstorm::util::bench;
use hstorm::util::rng::Rng;

fn random_batch(n: usize, n_comp: usize, m: usize, seed: u64) -> Vec<Placement> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut p = Placement::empty(n_comp, m);
            for c in 0..n_comp {
                for _ in 0..rng.range(1, 3) {
                    p.x[c][rng.range(0, m - 1)] += 1;
                }
            }
            p
        })
        .collect()
}

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, _) = bench::time_once(|| complexity::run(fast).expect("complexity runs"));
    println!("{}", result.render());

    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::linear();
    let n = top.n_components();
    let m = cluster.n_machines();
    let batch = random_batch(256, n, m, 0xBEEF);
    let rates = vec![1.0; batch.len()];

    // scoring backends head-to-head, 256 candidates per call
    let native = NativeScorer::new(&top, &cluster, &db).expect("native scorer");
    let mn = bench::run("score 256 candidates (native)", 3, if fast { 20 } else { 100 }, || {
        native.score_batch(&batch, &rates).expect("scores");
    });
    println!("  native: {:.0} candidates/s", mn.throughput(256.0));

    #[cfg(feature = "pjrt")]
    {
        use hstorm::runtime::scorer::PjRtScorer;
        use hstorm::runtime::PjRtRuntime;
        match PjRtRuntime::cpu_default() {
            Ok(rt) => {
                let pjrt = PjRtScorer::new(&rt, &top, &cluster, &db).expect("pjrt scorer");
                let iters = if fast { 20 } else { 100 };
                let mp = bench::run("score 256 candidates (pjrt AOT)", 3, iters, || {
                    pjrt.score_batch(&batch, &rates).expect("scores");
                });
                println!("  pjrt:   {:.0} candidates/s", mp.throughput(256.0));
            }
            Err(e) => println!("  (pjrt scorer skipped: {e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("  (pjrt scorer skipped: built without the `pjrt` feature)");

    // the full bounded optimal search, end to end: naive batched engine
    // vs the incremental kernel, single-threaded and sharded
    let max_inst = if fast { 2 } else { 3 };
    let os = OptimalScheduler {
        max_instances_per_component: max_inst,
        threads: 1,
        ..Default::default()
    };
    let space = os.design_space_size(n, m);
    let problem = Problem::new(&top, &cluster, &db).expect("problem");
    let req = ScheduleRequest::max_throughput();

    let (naive, dt_naive) =
        bench::time_once(|| os.schedule_naive(&problem, &req).expect("naive engine schedules"));
    let (incr, dt_incr) =
        bench::time_once(|| os.schedule(&problem, &req).expect("kernel engine schedules"));
    let par_os = OptimalScheduler { threads: 0, ..os.clone() };
    let (par, dt_par) =
        bench::time_once(|| par_os.schedule(&problem, &req).expect("parallel kernel schedules"));

    let cps = |s: &hstorm::scheduler::Schedule| {
        s.provenance.placements_evaluated as f64 / s.provenance.wall.as_secs_f64().max(1e-9)
    };
    println!("full optimal search over {space} placements (paper's comparator: hours):");
    println!(
        "  naive batched engine       : {dt_naive:?} -> rate {:.1} t/s ({:.0} candidates/s)",
        naive.rate,
        cps(&naive)
    );
    println!(
        "  incremental kernel, 1 thr  : {dt_incr:?} -> rate {:.1} t/s ({:.0} candidates/s, {:.1}x)",
        incr.rate,
        cps(&incr),
        cps(&incr) / cps(&naive)
    );
    println!(
        "  incremental kernel, N thr  : {dt_par:?} -> rate {:.1} t/s ({:.0} candidates/s, {:.1}x)",
        par.rate,
        cps(&par),
        cps(&par) / cps(&naive)
    );
    assert_eq!(naive.placement, incr.placement, "engines must select the same schedule");
    assert_eq!(incr.placement, par.placement, "sharding must not change the schedule");
}
