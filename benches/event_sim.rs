//! Bench: discrete-event simulator throughput — how many virtual tuples
//! per wall second the event loop sustains across cluster scales and
//! service models — plus the `accuracy` experiment end to end.
//! Run: cargo bench --bench event_sim  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::{presets, scenarios};
use hstorm::experiments::accuracy;
use hstorm::predict::Placement;
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::simulator::event::{self, EventSimConfig, ServiceModel};
use hstorm::topology::benchmarks;
use hstorm::util::bench;

fn sim_case(
    name: &str,
    problem: &Problem,
    placement: &Placement,
    rate: f64,
    service: ServiceModel,
    horizon: f64,
) {
    let cfg = EventSimConfig { horizon, warmup: horizon / 5.0, service, ..Default::default() };
    let (rep, dt) =
        bench::time_once(|| event::simulate(problem, placement, rate, &cfg).expect("event sim"));
    let tuples = rep.throughput * (rep.horizon - rep.warmup);
    let per_wall_s = tuples / dt.as_secs_f64().max(1e-9);
    println!(
        "{name:<52} {tuples:>9.0} tuples in {dt:>10.1?}  ({per_wall_s:>9.0} tuples/wall-s)  {}",
        rep.verdict()
    );
}

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let horizon = if fast { 10.0 } else { 40.0 };

    let top = benchmarks::linear();
    let (cluster, db) = presets::paper_cluster();
    let problem = Problem::new(&top, &cluster, &db).expect("problem");
    let hetero = registry::create("hetero", &PolicyParams::default()).expect("policy");
    let s = hetero.schedule(&problem, &ScheduleRequest::max_throughput()).expect("schedule");
    let p9 = s.rate * 0.9;
    let det = ServiceModel::Deterministic;
    let exp = ServiceModel::Exponential;
    sim_case("paper / linear / deterministic @0.9x", &problem, &s.placement, p9, det, horizon);
    sim_case("paper / linear / exponential   @0.9x", &problem, &s.placement, p9, exp, horizon);
    sim_case(
        "paper / linear / deterministic @1.3x (overload)",
        &problem,
        &s.placement,
        s.rate * 1.3,
        det,
        horizon,
    );

    let (cluster2, db2) = scenarios::by_id(2).expect("scenario 2").build();
    let top2 = benchmarks::diamond();
    let problem2 = Problem::new(&top2, &cluster2, &db2).expect("problem");
    let s2 = hetero.schedule(&problem2, &ScheduleRequest::max_throughput()).expect("schedule");
    sim_case(
        "scenario-2 (30 machines) / diamond / exponential @0.9x",
        &problem2,
        &s2.placement,
        s2.rate * 0.9,
        ServiceModel::Exponential,
        horizon,
    );

    let (r, dt) = bench::time_once(|| accuracy::run(fast).expect("accuracy experiment"));
    println!("{}", r.render());
    println!("accuracy experiment wall time: {dt:?}");
}
