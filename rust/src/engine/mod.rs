//! The stream-processing engine: the "real heterogeneous cluster"
//! substitute (DESIGN.md §5 substitutions).
//!
//! The paper measures its schedulers on four physical machines running
//! Apache Storm.  This engine reproduces the mechanism that matters for
//! the paper's claims — heterogeneous per-tuple CPU cost and machine
//! capacity saturation — with real queueing and real time:
//!
//! * every worker **machine** is a thread modeling one Storm worker
//!   process: a single-server queue with a CPU budget of 100 %·s per
//!   second (the paper's `MAC`);
//! * every **task** (executor) is pinned to its machine per the
//!   placement; the machine serially processes tuples addressed to its
//!   tasks, spending `e_ij` percent-seconds of budget per tuple (drawn
//!   from the same profile DB the schedulers read, plus optional noise —
//!   the engine is the ground truth the prediction model is judged
//!   against, Fig. 6);
//! * per-instance **MET** overhead is burned as periodic background work;
//! * **spout pacing** threads inject the topology input rate `R0`,
//!   shedding load when a downstream queue passes the pending bound
//!   (Storm's `max.spout.pending` analogue), so over-scheduled placements
//!   saturate instead of deadlocking;
//! * routing uses **shuffle grouping**: each producer task round-robins
//!   over the consumer component's instances; α > 1 fan-out is produced
//!   with a deterministic fractional accumulator (eq. 6 semantics);
//! * in [`ComputeMode::Pjrt`] the service time is burned by executing the
//!   AOT work kernel (`work.hlo.txt`) instead of sleeping — real compute
//!   through PJRT on the data path.
//!
//! Throughput is the sum of tuples processed per second over all tasks
//! (the paper's eq. 2 objective); utilization is busy-time / wall-time
//! per machine.  Both are measured only inside the post-warmup window.

mod worker;

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::metrics::Registry;
use crate::predict::Placement;
use crate::topology::Topology;
use crate::util::rng::Rng;
use crate::{Error, Result};

pub use worker::ComputeMode;

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Measurement window.
    pub duration: Duration,
    /// Warmup before measurement starts.
    pub warmup: Duration,
    /// Time compression: one wall-clock second simulates `1/time_scale`
    /// virtual (cluster) seconds.  Service times shrink by `time_scale`
    /// and emission rates grow by `1/time_scale`, so machines saturate at
    /// exactly the modeled capacity and utilization ratios are preserved;
    /// 1.0 = real time, 0.25 = 4x faster (test suite).
    pub time_scale: f64,
    /// Spout sheds load once a target machine's pending queue passes
    /// this depth (Storm `max.spout.pending` analogue).
    pub max_pending: i64,
    /// Multiplicative service-time noise amplitude (0.05 = ±5%).
    pub noise: f64,
    pub seed: u64,
    pub compute: ComputeMode,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            duration: Duration::from_secs(4),
            warmup: Duration::from_millis(800),
            time_scale: 1.0,
            max_pending: 2048,
            noise: 0.0,
            seed: 0x5EED,
            compute: ComputeMode::Simulated,
        }
    }
}

impl EngineConfig {
    /// Fast settings for unit/integration tests.
    pub fn fast_test() -> Self {
        EngineConfig {
            duration: Duration::from_millis(900),
            warmup: Duration::from_millis(300),
            time_scale: 0.25,
            ..Default::default()
        }
    }
}

/// One tuple in flight: which component's task must process it.
#[derive(Debug, Clone, Copy)]
pub(crate) struct WorkItem {
    pub comp: usize,
    /// Task index within the component.  Routing already resolved the
    /// hosting machine; the slot is carried for trace/debug output.
    #[allow(dead_code)]
    pub slot: usize,
}

/// Measured results of an engine run.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Measurement window length (s).
    pub window: f64,
    /// Overall throughput: tuples processed per second summed over all
    /// tasks (same definition as the predictor's objective).
    pub throughput: f64,
    /// Measured CPU utilization per machine (%), busy / wall.
    pub util: Vec<f64>,
    /// Tuples processed per second per component.
    pub comp_rate: Vec<f64>,
    /// Mean measured service time per (component, machine) where
    /// observed, in profile units (seconds of budget per tuple; the
    /// engine's `time_scale` is already divided out).
    pub service: Vec<Vec<Option<f64>>>,
    /// Tuples shed at the spouts (backpressure drops) in the window.
    pub shed: u64,
    /// Effective spout emission rate achieved (tuples/s).
    pub emitted_rate: f64,
}

/// Run `placement` on the engine at topology input rate `r0`.
pub fn run(
    top: &Topology,
    cluster: &Cluster,
    profiles: &ProfileDb,
    placement: &Placement,
    r0: f64,
    cfg: &EngineConfig,
) -> Result<EngineReport> {
    top.validate()?;
    cluster.validate()?;
    profiles.check_coverage(top, cluster)?;
    let n_comp = top.n_components();
    let n_machines = cluster.n_machines();
    if placement.n_components() != n_comp || placement.n_machines() != n_machines {
        return Err(Error::Engine("placement shape mismatch".into()));
    }
    if placement.counts().iter().any(|&c| c == 0) {
        return Err(Error::Engine("every component needs >= 1 instance".into()));
    }
    let (e_m, met_m) = profiles.expand(top, cluster)?;

    // ---- task table: tasks[c][slot] = hosting machine --------------------
    let mut tasks: Vec<Vec<usize>> = vec![Vec::new(); n_comp];
    for c in 0..n_comp {
        for m in 0..n_machines {
            for _ in 0..placement.x[c][m] {
                tasks[c].push(m);
            }
        }
    }

    // ---- shared state -----------------------------------------------------
    let recording = Arc::new(AtomicBool::new(false));
    let stop = Arc::new(AtomicBool::new(false));
    let pending: Arc<Vec<AtomicI64>> =
        Arc::new((0..n_machines).map(|_| AtomicI64::new(0)).collect());
    let shed = Arc::new(AtomicU64::new(0));
    let emitted = Arc::new(AtomicU64::new(0));
    let metrics = Registry::new();

    // one unbounded channel per machine (backpressure is enforced at the
    // spouts via the `pending` depth counters)
    let mut senders: Vec<Sender<WorkItem>> = Vec::with_capacity(n_machines);
    let mut receivers = Vec::with_capacity(n_machines);
    for _ in 0..n_machines {
        let (tx, rx) = channel::<WorkItem>();
        senders.push(tx);
        receivers.push(rx);
    }

    // ---- machine worker threads --------------------------------------------
    let mut joins = Vec::new();
    for (m, rx) in receivers.into_iter().enumerate() {
        let ctx = worker::MachineCtx {
            machine: m,
            tasks: tasks.clone(),
            e_m: e_m.clone(),
            met_m: met_m.clone(),
            alpha: top.components.iter().map(|c| c.alpha).collect(),
            downstream: (0..n_comp).map(|c| top.downstream(c)).collect(),
            senders: senders.clone(),
            pending: pending.clone(),
            recording: recording.clone(),
            stop: stop.clone(),
            metrics: metrics.clone(),
            time_scale: cfg.time_scale,
            noise: cfg.noise,
            rng: Rng::new(cfg.seed ^ ((m as u64) << 17)),
            compute: cfg.compute.clone(),
        };
        joins.push(std::thread::spawn(move || worker::machine_loop(ctx, rx)));
    }

    // ---- spout pacing threads ------------------------------------------------
    let spouts = top.spouts();
    let mut spout_joins = Vec::new();
    for &c in &spouts {
        let n_inst = tasks[c].len();
        // wall-clock emission rate: virtual rate compressed by time_scale
        // (weighted spouts receive `weight · R0` — see Component::weight)
        let rate_per_inst = r0 * top.components[c].weight / n_inst as f64 / cfg.time_scale;
        for slot in 0..n_inst {
            let machine = tasks[c][slot];
            let tx = senders[machine].clone();
            let pending = pending.clone();
            let stop = stop.clone();
            let shed = shed.clone();
            let emitted = emitted.clone();
            let recording = recording.clone();
            let max_pending = cfg.max_pending;
            spout_joins.push(std::thread::spawn(move || {
                let tick = Duration::from_millis(5);
                let mut carry = 0.0f64;
                // elapsed-based pacing: sleep overshoot (large on busy
                // single-core hosts) self-corrects instead of silently
                // lowering the emission rate
                let mut last = Instant::now();
                // token bucket with a bounded burst (~50 ms of rate): a
                // transient CPU stall must not flood the queues with the
                // whole backlog at once and trigger spurious shedding
                let burst_cap = (rate_per_inst * 0.05).max(2.0);
                while !stop.load(Ordering::Relaxed) {
                    let now = Instant::now();
                    carry = (carry + rate_per_inst * (now - last).as_secs_f64()).min(burst_cap);
                    last = now;
                    let n = carry as u64;
                    carry -= n as f64;
                    for _ in 0..n {
                        if pending[machine].load(Ordering::Relaxed) > max_pending {
                            if recording.load(Ordering::Relaxed) {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            continue;
                        }
                        if tx.send(WorkItem { comp: c, slot }).is_err() {
                            return;
                        }
                        pending[machine].fetch_add(1, Ordering::Relaxed);
                        if recording.load(Ordering::Relaxed) {
                            emitted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    std::thread::sleep(tick);
                }
            }));
        }
    }
    drop(senders);

    // ---- warmup, measure, stop -------------------------------------------------
    std::thread::sleep(cfg.warmup);
    recording.store(true, Ordering::SeqCst);
    let t0 = Instant::now();
    std::thread::sleep(cfg.duration);
    recording.store(false, Ordering::SeqCst);
    let window = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::SeqCst);
    for j in spout_joins {
        j.join().map_err(|_| Error::Engine("spout thread panicked".into()))?;
    }
    for j in joins {
        j.join().map_err(|_| Error::Engine("machine thread panicked".into()))?;
    }

    // ---- collect ------------------------------------------------------------------
    // rates are reported in *virtual* tuples/s: `window` wall seconds
    // simulate `window / time_scale` virtual seconds
    let vwindow = window / cfg.time_scale;
    let mut comp_rate = vec![0.0f64; n_comp];
    for (c, rate) in comp_rate.iter_mut().enumerate() {
        let processed = metrics.counter(&format!("comp.{c}.processed")).get();
        *rate = processed as f64 / vwindow;
    }
    let mut util = vec![0.0f64; n_machines];
    for (m, u) in util.iter_mut().enumerate() {
        let busy_us = metrics.counter(&format!("machine.{m}.busy_us")).get();
        // under time compression both busy time and the budget are wall
        // quantities, so utilization is a plain wall ratio
        *u = busy_us as f64 / 1e6 / window * 100.0;
    }
    let mut service = vec![vec![None; n_machines]; n_comp];
    for c in 0..n_comp {
        for m in 0..n_machines {
            let stat = metrics.mean(&format!("svc.{c}.{m}"));
            if stat.count() > 0 {
                // report in profile units: undo time_scale
                service[c][m] = stat.mean().map(|s| s / cfg.time_scale);
            }
        }
    }
    Ok(EngineReport {
        window,
        throughput: comp_rate.iter().sum(),
        util,
        comp_rate,
        service,
        shed: shed.load(Ordering::Relaxed),
        emitted_rate: emitted.load(Ordering::Relaxed) as f64 / vwindow,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::benchmarks;
    use crate::cluster::presets;

    fn place_spread(top: &Topology, cluster: &Cluster) -> Placement {
        let mut p = Placement::empty(top.n_components(), cluster.n_machines());
        for c in 0..top.n_components() {
            p.x[c][c % cluster.n_machines()] = 1;
        }
        p
    }

    #[test]
    fn linear_low_rate_runs_clean() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 40.0, &EngineConfig::fast_test()).unwrap();
        for (c, r) in rep.comp_rate.iter().enumerate() {
            assert!((r - 40.0).abs() < 12.0, "comp {c}: rate {r}");
        }
        assert!(rep.shed == 0, "shed {} at low rate", rep.shed);
        assert!(rep.throughput > 110.0 && rep.throughput < 210.0, "{}", rep.throughput);
    }

    #[test]
    fn utilization_tracks_prediction() {
        use crate::predict::Evaluator;
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let r0 = 120.0;
        let rep = run(&top, &cluster, &db, &p, r0, &EngineConfig::fast_test()).unwrap();
        let ev = Evaluator::new(&top, &cluster, &db).unwrap();
        let pred = ev.evaluate(&p, r0).unwrap();
        for m in 0..cluster.n_machines() {
            let err = (rep.util[m] - pred.util[m]).abs();
            assert!(
                err < 12.0,
                "machine {m}: measured {:.1}% vs predicted {:.1}%",
                rep.util[m],
                pred.util[m]
            );
        }
    }

    #[test]
    fn overload_sheds_and_saturates() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let mut p = Placement::empty(top.n_components(), cluster.n_machines());
        for c in 0..top.n_components() {
            p.x[c][0] = 1; // everything on the Pentium worker
        }
        let cfg = EngineConfig { max_pending: 128, ..EngineConfig::fast_test() };
        let rep = run(&top, &cluster, &db, &p, 4000.0, &cfg).unwrap();
        assert!(rep.shed > 0, "expected shedding under overload");
        assert!(rep.util[0] > 75.0, "util {}", rep.util[0]);
        assert!(rep.util[1] < 5.0 && rep.util[2] < 5.0);
    }

    #[test]
    fn alpha_fanout_amplifies_downstream() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::rolling_count(); // split has alpha 1.5
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 40.0, &EngineConfig::fast_test()).unwrap();
        let counter_rate = rep.comp_rate[2];
        assert!((counter_rate - 60.0).abs() < 18.0, "rate {counter_rate}");
    }

    #[test]
    fn multi_instance_divides_load() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let mut p = place_spread(&top, &cluster);
        p.x[3] = vec![0, 1, 1]; // high bolt: 2 instances on i3 + i5
        let rep = run(&top, &cluster, &db, &p, 100.0, &EngineConfig::fast_test()).unwrap();
        assert!((rep.comp_rate[3] - 100.0).abs() < 28.0, "{}", rep.comp_rate[3]);
    }

    #[test]
    fn missing_instance_rejected() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = Placement::empty(top.n_components(), cluster.n_machines());
        assert!(run(&top, &cluster, &db, &p, 10.0, &EngineConfig::fast_test()).is_err());
    }

    #[test]
    fn measured_service_matches_profile() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 60.0, &EngineConfig::fast_test()).unwrap();
        // placement c%3 puts component 3 (highCompute) on machine 0 (pentium)
        let svc = rep.service[3][0].expect("no service samples for highCompute");
        let e = db.get("highCompute", "pentium").unwrap().e;
        let want = e / 100.0; // %·s -> s of budget per tuple
        let rel = (svc - want).abs() / want;
        assert!(rel < 0.25, "measured {svc}, want {want}");
    }
}

