//! End-to-end acceptance of the online control plane: the CLI-shaped
//! diurnal replay on the Table-4 small scenario must (a) run a >= 500
//! step trace in analytic virtual time (no sleeping — wall-clock far
//! under the trace's 500 virtual seconds), (b) have the reactive policy
//! deliver strictly more total load than the static schedule, and
//! (c) take fewer scheduling decisions than the clairvoyant oracle.

use std::time::Instant;

use hstorm::cluster::scenarios;
use hstorm::controller::{self, traces, ControllerConfig, Policy};
use hstorm::topology::benchmarks;

#[test]
fn diurnal_scenario1_head_to_head() {
    let top = benchmarks::linear();
    let (cluster, db) = scenarios::by_id(1).unwrap().build();
    let trace = traces::by_name("diurnal", &top, &cluster, 500, 42).unwrap();
    assert!(trace.n_steps() >= 500);

    let started = Instant::now();
    let cfg = ControllerConfig::default();
    let report =
        controller::run_trace(&top, &cluster, &db, &trace, &Policy::ALL, &cfg).unwrap();
    let elapsed = started.elapsed();
    // 500 virtual seconds of trace; any wall-clock sleeping would blow
    // this bound by orders of magnitude even in debug builds
    assert!(elapsed.as_secs_f64() < 30.0, "control loop slept? took {elapsed:?}");

    let stat = report.policy("static").unwrap();
    let reac = report.policy("reactive").unwrap();
    let orac = report.policy("oracle").unwrap();

    assert!(
        reac.delivered_volume > stat.delivered_volume,
        "reactive ({:.0}) must deliver strictly more than static ({:.0})",
        reac.delivered_volume,
        stat.delivered_volume
    );
    assert!(
        reac.reschedules < orac.reschedules,
        "reactive ({}) must decide less often than the oracle ({})",
        reac.reschedules,
        orac.reschedules
    );
    // the oracle replans every step
    assert!(orac.reschedules >= trace.n_steps());
    // nobody outdelivers what was offered
    for p in &report.policies {
        assert!(p.delivered_volume <= p.offered_volume * (1.0 + 1e-9), "{}", p.policy);
    }
}

#[test]
fn bursty_flash_crowds_expose_static_on_every_topology() {
    // churn + flash crowds on the paper's 3-machine cluster: the reactive
    // controller must keep its edge on every benchmark topology
    use hstorm::cluster::presets;
    let (cluster, db) = presets::paper_cluster();
    let cfg = ControllerConfig::default();
    for top in benchmarks::micro() {
        let trace = traces::by_name("bursty", &top, &cluster, 240, 7).unwrap();
        let report =
            controller::run_trace(&top, &cluster, &db, &trace, &Policy::ALL, &cfg).unwrap();
        let stat = report.policy("static").unwrap();
        let reac = report.policy("reactive").unwrap();
        assert!(
            reac.delivered_volume > stat.delivered_volume,
            "{}: reactive {:.0} <= static {:.0}",
            top.name,
            reac.delivered_volume,
            stat.delivered_volume
        );
    }
}
