//! Control-plane reporting: per-step rows and per-policy aggregates, so
//! a static schedule, the reactive controller and the clairvoyant oracle
//! can be compared head-to-head on the same trace.
//!
//! Volumes are integrals over virtual time (tuples = tuples/s × s).  The
//! headline comparison is **delivered vs offered load**; secondary
//! columns quantify the cost of elasticity: SLO-violation seconds (any
//! step where some offered load was not delivered), scheduling decisions
//! taken, and tasks migrated (each charged as spout downtime by the
//! controller's migration-cost model).

use crate::util::json::{self, Value};

/// One step of one policy's run.
#[derive(Debug, Clone)]
pub struct StepRow {
    /// Virtual time (s).
    pub t: f64,
    /// Offered topology input rate (tuples/s, denormalized).
    pub offered: f64,
    /// Max stable rate of the policy's current placement on the current
    /// world (tuples/s).
    pub capacity: f64,
    /// Rate actually delivered this step (tuples/s), after clipping to
    /// capacity and charging migration downtime.
    pub delivered: f64,
    /// Whether a scheduling decision changed the placement this step.
    pub rescheduled: bool,
    /// Tasks migrated this step.
    pub migrated: usize,
    /// Cluster events that fired this step.
    pub events: usize,
}

impl StepRow {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("t", json::num(self.t)),
            ("offered", json::num(self.offered)),
            ("capacity", json::num(self.capacity)),
            ("delivered", json::num(self.delivered)),
            ("rescheduled", Value::Bool(self.rescheduled)),
            ("migrated", json::num(self.migrated as f64)),
            ("events", json::num(self.events as f64)),
        ])
    }
}

/// Aggregates for one policy over a whole trace.
#[derive(Debug, Clone)]
pub struct PolicyReport {
    pub policy: &'static str,
    pub steps: usize,
    /// ∫ offered dt, tuples.
    pub offered_volume: f64,
    /// ∫ delivered dt, tuples.
    pub delivered_volume: f64,
    /// Virtual seconds during which delivered < offered.
    pub slo_violation_secs: f64,
    /// Scheduling decisions taken (the oracle takes one per step).
    pub reschedules: usize,
    /// Total task instances newly started or moved by reschedules.
    pub tasks_migrated: usize,
    pub rows: Vec<StepRow>,
}

impl PolicyReport {
    pub fn new(policy: &'static str) -> Self {
        PolicyReport {
            policy,
            steps: 0,
            offered_volume: 0.0,
            delivered_volume: 0.0,
            slo_violation_secs: 0.0,
            reschedules: 0,
            tasks_migrated: 0,
            rows: Vec::new(),
        }
    }

    /// Delivered share of offered load, percent.
    pub fn delivered_pct(&self) -> f64 {
        if self.offered_volume > 0.0 {
            self.delivered_volume / self.offered_volume * 100.0
        } else {
            100.0
        }
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("policy", json::s(self.policy)),
            ("steps", json::num(self.steps as f64)),
            ("offered_volume", json::num(self.offered_volume)),
            ("delivered_volume", json::num(self.delivered_volume)),
            ("delivered_pct", json::num(self.delivered_pct())),
            ("slo_violation_secs", json::num(self.slo_violation_secs)),
            ("reschedules", json::num(self.reschedules as f64)),
            ("tasks_migrated", json::num(self.tasks_migrated as f64)),
            ("rows", json::arr(self.rows.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

/// The head-to-head comparison for one (trace, topology, cluster).
#[derive(Debug, Clone)]
pub struct ControlReport {
    pub trace: String,
    pub seed: u64,
    pub steps: usize,
    pub topology: String,
    pub cluster: String,
    /// Initial certified rate the trace's normalized profile scales by.
    pub base_rate: f64,
    pub policies: Vec<PolicyReport>,
}

impl ControlReport {
    /// Render the aggregate comparison for the terminal.
    pub fn render(&self) -> String {
        let mut out = format!(
            "\n=== control — trace '{}' ({} steps, seed {}) on '{}' @ '{}' \
             (base rate {:.1} tuple/s) ===\n",
            self.trace, self.steps, self.seed, self.topology, self.cluster, self.base_rate
        );
        out.push_str(&format!(
            "{:<10} {:>14} {:>14} {:>10} {:>8} {:>12} {:>9}\n",
            "policy",
            "offered(tup)",
            "delivered(tup)",
            "deliv %",
            "SLO-s",
            "reschedules",
            "migrated"
        ));
        out.push_str(&"-".repeat(84));
        out.push('\n');
        for p in &self.policies {
            out.push_str(&format!(
                "{:<10} {:>14.0} {:>14.0} {:>9.1}% {:>8.0} {:>12} {:>9}\n",
                p.policy,
                p.offered_volume,
                p.delivered_volume,
                p.delivered_pct(),
                p.slo_violation_secs,
                p.reschedules,
                p.tasks_migrated
            ));
        }
        out
    }

    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("trace", json::s(&self.trace)),
            ("seed", json::num(self.seed as f64)),
            ("steps", json::num(self.steps as f64)),
            ("topology", json::s(&self.topology)),
            ("cluster", json::s(&self.cluster)),
            ("base_rate", json::num(self.base_rate)),
            ("policies", json::arr(self.policies.iter().map(|p| p.to_json()).collect())),
        ])
    }

    /// Look a policy's aggregates up by name.
    pub fn policy(&self, name: &str) -> Option<&PolicyReport> {
        self.policies.iter().find(|p| p.policy == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ControlReport {
        let mut p = PolicyReport::new("reactive");
        p.steps = 2;
        p.offered_volume = 200.0;
        p.delivered_volume = 150.0;
        p.slo_violation_secs = 1.0;
        p.reschedules = 1;
        p.tasks_migrated = 3;
        p.rows.push(StepRow {
            t: 0.0,
            offered: 100.0,
            capacity: 75.0,
            delivered: 75.0,
            rescheduled: true,
            migrated: 3,
            events: 1,
        });
        ControlReport {
            trace: "diurnal".into(),
            seed: 42,
            steps: 2,
            topology: "linear".into(),
            cluster: "paper-table2".into(),
            base_rate: 100.0,
            policies: vec![p],
        }
    }

    #[test]
    fn delivered_pct_math() {
        let r = sample();
        assert!((r.policies[0].delivered_pct() - 75.0).abs() < 1e-9);
        assert!((PolicyReport::new("static").delivered_pct() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn render_names_all_policies() {
        let r = sample();
        let text = r.render();
        assert!(text.contains("diurnal"));
        assert!(text.contains("reactive"));
        assert!(text.contains("75.0%"));
    }

    #[test]
    fn json_roundtrips() {
        let r = sample();
        let text = json::to_string_pretty(&r.to_json());
        let back = json::parse(&text).unwrap();
        assert_eq!(back.str_field("trace").unwrap(), "diurnal");
        let pol = &back.get("policies").unwrap().as_arr().unwrap()[0];
        assert_eq!(pol.num_field("reschedules").unwrap(), 1.0);
        assert_eq!(
            pol.get("rows").unwrap().as_arr().unwrap()[0].get("rescheduled").unwrap().as_bool(),
            Some(true)
        );
    }
}
