"""Pure-jnp oracle for the Pallas kernels and the full evaluation model.

Everything here is straight-line numpy-style code with no Pallas, no
BlockSpecs and no grids; pytest/hypothesis compare the kernels (and the
composed L2 model) against these implementations.
"""

import jax.numpy as jnp


def score_utilization_ref(x, ir_task, e_m, met_m):
    """util[b,m] = sum_c x[b,c,m] * (e_m[c,m]*ir_task[b,c] + met_m[c,m])."""
    per_task = e_m[None, :, :] * ir_task[:, :, None] + met_m[None, :, :]
    return jnp.sum(x * per_task, axis=1)


def propagate_step_ref(ir, adj, alpha, src):
    """ir'[b,j] = src[b,j] + sum_i adj[i,j] * alpha[i] * ir[b,i]."""
    return src + (ir * alpha[None, :]) @ adj


def propagate_ref(adj, alpha, src, depth):
    """Iterate eq. 6 to the DAG fixed point."""
    ir = src
    for _ in range(depth):
        ir = propagate_step_ref(ir, adj, alpha, src)
    return ir


def evaluate_placements_ref(x, adj, alpha, src_mask, r0, e_m, met_m, cap,
                            active, depth, eps=1e-6):
    """Reference for the full L2 model; see model.evaluate_placements."""
    n_c = jnp.sum(x, axis=2)                       # [B, C]
    src = src_mask[None, :] * r0[:, None]          # [B, C]
    ir_comp = propagate_ref(adj, alpha, src, depth)
    ir_task = ir_comp / jnp.maximum(n_c, 1.0)
    util = score_utilization_ref(x, ir_task, e_m, met_m)
    over = jnp.any(util > cap[None, :] + eps, axis=1)
    missing = jnp.any((n_c < 0.5) & (active[None, :] > 0.5), axis=1)
    feasible = jnp.logical_and(~over, ~missing).astype(x.dtype)
    throughput = jnp.sum(ir_comp * active[None, :], axis=1)
    return util, throughput, feasible, ir_comp
