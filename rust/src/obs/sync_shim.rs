//! Swappable synchronization primitives for the `obs` atomic cores.
//!
//! [`super::histogram_core`] imports its atomics from `super::sync_shim`
//! instead of `std::sync` directly, so the exact same source file can be
//! re-included by the out-of-workspace `tools/loom` crate under a
//! loom-backed shim (`loom::sync::atomic`) and model-checked without a
//! `cfg(loom)` dependency in this crate's manifest or lockfile.  In the
//! production build this module is a zero-cost re-export of `std`.

pub use std::sync::atomic::{AtomicU64, Ordering};
