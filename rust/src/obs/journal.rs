//! Structured event journal: typed decision events in a bounded ring.
//!
//! Every layer that makes a decision worth explaining — the kernel
//! search, the schedulers, the controllers, the event simulator —
//! records a typed [`Event`].  The journal keeps the last
//! [`RING_CAPACITY`] events in memory (each stamped with a monotonic
//! sequence number) and can mirror them to a JSONL file sink.  Events
//! carry no wall-clock timestamps: identical runs produce identical
//! journals, which keeps the controller/workload determinism
//! guarantees intact and makes journal dumps diff cleanly in CI.

use std::collections::VecDeque;
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::Result;

/// Events retained in memory (older events fall off the ring; the
/// JSONL sink, when attached, keeps everything).
pub const RING_CAPACITY: usize = 4096;

/// A decision event.  Numeric payloads are plain `f64`/`u64` so
/// `to_json` is lossless through [`crate::util::json`].
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// A scheduler began searching a design space.
    SearchStarted { policy: String, components: usize, machines: usize },
    /// Aggregate of candidates a search discarded (counted locally in
    /// the DFS leaves, flushed once per search — no hot-path atomics).
    CandidatePruned { policy: String, count: u64, reason: String },
    /// A scheduler committed to a placement.
    ScheduleChosen {
        policy: String,
        backend: String,
        objective: String,
        rate: f64,
        evaluated: u64,
        pruned: u64,
        wall_ms: f64,
    },
    /// A candidate the search considered but did not choose.
    RunnerUp { policy: String, label: String, rate: f64 },
    /// Controller: offered load exceeded certified capacity.
    BreachDetected { policy: String, step: usize, offered: f64, capacity: f64 },
    /// Controller: a re-plan ran, with its cause.  Decision latency is
    /// telemetry, not a decision, and lives in the `control.replan_s`
    /// histogram — keeping it out of the journal is what makes journals
    /// bit-identical across identical runs.
    Replanned { policy: String, step: usize, cause: String },
    /// Workload controller: a tenant admission was rejected.
    AdmissionDenied { tenant: String, step: usize, reason: String },
    /// Workload controller: a tenant was admitted.
    AdmissionGranted { tenant: String, step: usize },
    /// Event simulator: end-of-run stability verdict.
    BackpressureVerdict { rate: f64, backpressure: bool, queue_growth: f64, shed: u64 },
    /// Portfolio search: one strategy finished its budget share.
    StrategyFinished { policy: String, strategy: String, rate: f64, evaluated: u64 },
    /// A deprecated registry alias resolved (warned once per process).
    DeprecatedAlias { alias: String, canonical: String },
}

impl Event {
    /// Stable machine-readable discriminator.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::SearchStarted { .. } => "search_started",
            Event::CandidatePruned { .. } => "candidate_pruned",
            Event::ScheduleChosen { .. } => "schedule_chosen",
            Event::RunnerUp { .. } => "runner_up",
            Event::BreachDetected { .. } => "breach_detected",
            Event::Replanned { .. } => "replanned",
            Event::AdmissionDenied { .. } => "admission_denied",
            Event::AdmissionGranted { .. } => "admission_granted",
            Event::BackpressureVerdict { .. } => "backpressure_verdict",
            Event::StrategyFinished { .. } => "strategy_finished",
            Event::DeprecatedAlias { .. } => "deprecated_alias",
        }
    }

    pub fn to_json(&self) -> Value {
        let mut pairs: Vec<(&str, Value)> = vec![("kind", json::s(self.kind()))];
        match self {
            Event::SearchStarted { policy, components, machines } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("components", json::num(*components as f64)));
                pairs.push(("machines", json::num(*machines as f64)));
            }
            Event::CandidatePruned { policy, count, reason } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("count", json::num(*count as f64)));
                pairs.push(("reason", json::s(reason)));
            }
            Event::ScheduleChosen {
                policy,
                backend,
                objective,
                rate,
                evaluated,
                pruned,
                wall_ms,
            } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("backend", json::s(backend)));
                pairs.push(("objective", json::s(objective)));
                pairs.push(("rate", json::num(*rate)));
                pairs.push(("evaluated", json::num(*evaluated as f64)));
                pairs.push(("pruned", json::num(*pruned as f64)));
                pairs.push(("wall_ms", json::num(*wall_ms)));
            }
            Event::RunnerUp { policy, label, rate } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("label", json::s(label)));
                pairs.push(("rate", json::num(*rate)));
            }
            Event::BreachDetected { policy, step, offered, capacity } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("step", json::num(*step as f64)));
                pairs.push(("offered", json::num(*offered)));
                pairs.push(("capacity", json::num(*capacity)));
            }
            Event::Replanned { policy, step, cause } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("step", json::num(*step as f64)));
                pairs.push(("cause", json::s(cause)));
            }
            Event::AdmissionDenied { tenant, step, reason } => {
                pairs.push(("tenant", json::s(tenant)));
                pairs.push(("step", json::num(*step as f64)));
                pairs.push(("reason", json::s(reason)));
            }
            Event::AdmissionGranted { tenant, step } => {
                pairs.push(("tenant", json::s(tenant)));
                pairs.push(("step", json::num(*step as f64)));
            }
            Event::BackpressureVerdict { rate, backpressure, queue_growth, shed } => {
                pairs.push(("rate", json::num(*rate)));
                pairs.push(("backpressure", json::bool(*backpressure)));
                pairs.push(("queue_growth", json::num(*queue_growth)));
                pairs.push(("shed", json::num(*shed as f64)));
            }
            Event::StrategyFinished { policy, strategy, rate, evaluated } => {
                pairs.push(("policy", json::s(policy)));
                pairs.push(("strategy", json::s(strategy)));
                pairs.push(("rate", json::num(*rate)));
                pairs.push(("evaluated", json::num(*evaluated as f64)));
            }
            Event::DeprecatedAlias { alias, canonical } => {
                pairs.push(("alias", json::s(alias)));
                pairs.push(("canonical", json::s(canonical)));
            }
        }
        json::obj(pairs)
    }
}

/// One retained journal entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    /// Monotonic sequence number (never reused, survives ring eviction).
    pub seq: u64,
    pub event: Event,
}

impl Entry {
    pub fn to_json(&self) -> Value {
        let mut obj = match self.event.to_json() {
            Value::Obj(o) => o,
            other => {
                let mut o = std::collections::BTreeMap::new();
                o.insert("event".to_string(), other);
                o
            }
        };
        obj.insert("seq".to_string(), json::num(self.seq as f64));
        Value::Obj(obj)
    }
}

#[derive(Debug, Default)]
struct Inner {
    ring: VecDeque<Entry>,
    next_seq: u64,
    sink: Option<File>,
}

/// Bounded in-memory event journal with an optional JSONL file sink.
#[derive(Debug, Default)]
pub struct Journal {
    inner: Mutex<Inner>,
}

impl Journal {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an event; evicts the oldest entry past [`RING_CAPACITY`]
    /// and mirrors the event to the JSONL sink when one is attached.
    pub fn record(&self, event: Event) {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        let entry = Entry { seq, event };
        if let Some(sink) = inner.sink.as_mut() {
            // one compact JSON object per line; sink errors must never
            // take down the instrumented caller
            let line = json::to_string_compact(&entry.to_json());
            let _ = writeln!(sink, "{line}");
        }
        if inner.ring.len() == RING_CAPACITY {
            inner.ring.pop_front();
        }
        inner.ring.push_back(entry);
    }

    /// Number of events currently retained (ring occupancy).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever recorded (monotonic, survives eviction).
    pub fn total_recorded(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Copy of the retained entries, oldest first.
    pub fn entries(&self) -> Vec<Entry> {
        self.inner.lock().unwrap().ring.iter().cloned().collect()
    }

    /// Drop all retained entries (sequence numbers keep advancing).
    pub fn clear(&self) {
        self.inner.lock().unwrap().ring.clear();
    }

    /// Attach a JSONL sink; subsequent events are appended to `path`
    /// as one JSON object per line.
    pub fn set_sink(&self, path: &Path) -> Result<()> {
        let file = File::create(path)
            .map_err(|e| crate::Error::Config(format!("journal sink {}: {e}", path.display())))?;
        self.inner.lock().unwrap().sink = Some(file);
        Ok(())
    }

    /// Retained entries as a JSON array.
    pub fn to_json(&self) -> Value {
        Value::Arr(self.entries().iter().map(|e| e.to_json()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chosen(policy: &str, evaluated: u64) -> Event {
        Event::ScheduleChosen {
            policy: policy.into(),
            backend: "native".into(),
            objective: "max-throughput".into(),
            rate: 100.0,
            evaluated,
            pruned: 3,
            wall_ms: 1.5,
        }
    }

    #[test]
    fn records_in_order_with_monotonic_seq() {
        let j = Journal::new();
        j.record(Event::SearchStarted { policy: "hetero".into(), components: 4, machines: 3 });
        j.record(chosen("hetero", 42));
        let entries = j.entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].seq, 0);
        assert_eq!(entries[1].seq, 1);
        assert_eq!(entries[0].event.kind(), "search_started");
        assert_eq!(entries[1].event.kind(), "schedule_chosen");
    }

    #[test]
    fn ring_evicts_oldest_but_keeps_seq() {
        let j = Journal::new();
        for i in 0..(RING_CAPACITY as u64 + 10) {
            j.record(chosen("optimal", i));
        }
        assert_eq!(j.len(), RING_CAPACITY);
        assert_eq!(j.total_recorded(), RING_CAPACITY as u64 + 10);
        let first = &j.entries()[0];
        assert_eq!(first.seq, 10, "oldest 10 entries evicted");
    }

    #[test]
    fn event_json_is_typed_and_deterministic() {
        let e = Event::Replanned { policy: "reactive".into(), step: 7, cause: "band".into() };
        let v = e.to_json();
        assert_eq!(v.str_field("kind").unwrap(), "replanned");
        assert_eq!(v.str_field("cause").unwrap(), "band");
        assert_eq!(v.num_field("step").unwrap(), 7.0);
        assert_eq!(v.to_string(), e.to_json().to_string());
    }

    #[test]
    fn jsonl_sink_mirrors_every_event() {
        let dir = std::env::temp_dir();
        let path = dir.join("hstorm_journal_sink_test.jsonl");
        let j = Journal::new();
        j.set_sink(&path).unwrap();
        let denied =
            Event::AdmissionDenied { tenant: "t1".into(), step: 3, reason: "capacity".into() };
        j.record(denied);
        j.record(Event::AdmissionGranted { tenant: "t2".into(), step: 4 });
        drop(j);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.str_field("kind").unwrap(), "admission_denied");
        assert_eq!(first.num_field("seq").unwrap(), 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn clear_keeps_sequence_monotonic() {
        let j = Journal::new();
        j.record(chosen("default", 1));
        j.clear();
        assert!(j.is_empty());
        j.record(chosen("default", 2));
        assert_eq!(j.entries()[0].seq, 1);
    }
}
