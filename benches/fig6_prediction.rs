//! Bench: regenerate the paper's Fig.6-prediction-accuracy table (fig6) and time it.
//! Run: cargo bench --bench fig6_prediction  [HSTORM_FAST=1 for quick mode]

use hstorm::experiments::fig6;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig6::run(fast).expect("fig6 runs"));
    println!("{}", result.render());
    println!("[fig6_prediction] regenerated in {dt:?} (fast={fast})");
}
