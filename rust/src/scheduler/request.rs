//! Scheduling requests: *what* to optimize ([`Objective`]) under *which*
//! restrictions ([`Constraints`]).
//!
//! A [`ScheduleRequest`] is the second argument of
//! [`Scheduler::schedule`](super::Scheduler::schedule); the first is the
//! validated [`Problem`](super::Problem).  Splitting the two follows the
//! request-with-constraints shape of R-Storm and of Shukla & Simmhan's
//! model-driven scheduler: the problem is built (and validated) once,
//! while requests vary over its lifetime — the control plane issues a
//! new request per breach, never a new problem unless the world changed.
//!
//! ## Objective semantics
//!
//! * [`Objective::MaxThroughput`] — the paper's objective: certify the
//!   largest topology input rate the placement sustains (eq. 5
//!   feasibility on every machine) and report throughput at that rate.
//! * [`Objective::MinMachinesAtRate`]`(r)` — the smallest set of
//!   machines that still sustains input rate `r`.  Heuristic policies
//!   schedule for max throughput first (erroring if even that certifies
//!   below `r`), then greedily drain machines — moving every instance of
//!   the emptiest machine onto other *already-used* machines — while the
//!   certified rate stays `>= r`.  The optimal search compares
//!   candidates by (fewest used machines, then highest rate) among
//!   those sustaining `r`.
//! * [`Objective::BalancedUtilization`] — max throughput first, ties
//!   broken toward the smallest utilization spread (max − min predicted
//!   utilization over non-excluded machines at the certified rate).
//!   Balance never sacrifices certified rate: heuristics hill-climb
//!   single-instance moves that keep the rate and strictly shrink the
//!   spread; the optimal search breaks rate ties by spread.
//!
//! ## Constraint semantics
//!
//! * `exclude_machine(name)` — the machine hosts **zero** task
//!   instances.  This is how drained/failed machines are rescheduled
//!   around ([`super::reschedule`]).
//! * `pin_component(component, machines)` — every instance of the named
//!   component is placed on one of the listed machines.
//! * `max_instances(component, n)` — the component's instance count
//!   stays `<= n` (`n >= 1`; every component always keeps at least one
//!   instance).
//! * `reserve_headroom(pct)` — every machine keeps `pct` percentage
//!   points of CPU budget free: schedulers see `cap_m − pct` instead of
//!   `cap_m` when certifying rates and checking over-utilization.
//! * `reserve_machine_load(machine, pct)` — `pct` points of the named
//!   machine's budget are already spoken for.  This is the
//!   residual-capacity constraint behind incremental tenant admission
//!   ([`super::workload`]): resident tenants' predicted load at their
//!   certified rates is reserved machine by machine, so the admitted
//!   tenant's closed-form rates read `(cap_m − resident_m − b_m)/a_m`.
//!
//! Constraints name components and machines by their string names; they
//! are resolved against the [`Problem`](super::Problem) (and unknown
//! names rejected with the valid options) at schedule time.

use crate::predict::Placement;

/// What a [`ScheduleRequest`] asks the scheduler to optimize.
#[derive(Debug, Clone, PartialEq)]
pub enum Objective {
    /// Maximize the certified topology input rate (the paper's eq. 2).
    MaxThroughput,
    /// Use as few machines as possible while sustaining the given
    /// topology input rate (tuples/s).
    MinMachinesAtRate(f64),
    /// Maximize throughput, then minimize the utilization spread.
    BalancedUtilization,
}

impl Objective {
    /// Human-readable form, recorded in [`super::Provenance`].
    pub fn describe(&self) -> String {
        match self {
            Objective::MaxThroughput => "max-throughput".into(),
            Objective::MinMachinesAtRate(r) => format!("min-machines@{r:.1}"),
            Objective::BalancedUtilization => "balanced-utilization".into(),
        }
    }
}

/// Placement restrictions, named by component/machine strings and
/// resolved against a [`Problem`](super::Problem) at schedule time.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Constraints {
    pub(crate) excluded_machines: Vec<String>,
    /// `(component, allowed machines)`.
    pub(crate) pins: Vec<(String, Vec<String>)>,
    /// `(component, max instance count)`.
    pub(crate) max_instances: Vec<(String, usize)>,
    /// CPU percentage points kept free on every machine.
    pub(crate) headroom_pct: f64,
    /// `(machine, CPU percentage points already spoken for)` — resident
    /// load the scheduler must plan around (incremental tenant
    /// admission); repeated entries for one machine accumulate.
    pub(crate) reserved_loads: Vec<(String, f64)>,
}

impl Constraints {
    pub fn new() -> Self {
        Constraints::default()
    }

    /// True when no restriction is set.
    pub fn is_empty(&self) -> bool {
        self.excluded_machines.is_empty()
            && self.pins.is_empty()
            && self.max_instances.is_empty()
            && self.headroom_pct == 0.0
            && self.reserved_loads.is_empty()
    }

    /// The named machine hosts zero task instances.
    pub fn exclude_machine(mut self, machine: impl Into<String>) -> Self {
        self.excluded_machines.push(machine.into());
        self
    }

    /// Exclude several machines at once.
    pub fn exclude_machines<I, S>(mut self, machines: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.excluded_machines.extend(machines.into_iter().map(Into::into));
        self
    }

    /// Restrict every instance of `component` to the listed machines.
    pub fn pin_component<I, S>(mut self, component: impl Into<String>, machines: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        self.pins
            .push((component.into(), machines.into_iter().map(Into::into).collect()));
        self
    }

    /// Cap `component` at `n` instances (`n >= 1`).
    pub fn max_instances(mut self, component: impl Into<String>, n: usize) -> Self {
        self.max_instances.push((component.into(), n));
        self
    }

    /// Keep `pct` percentage points of CPU budget free on every machine.
    pub fn reserve_headroom(mut self, pct: f64) -> Self {
        self.headroom_pct = pct;
        self
    }

    /// Mark `pct` percentage points of the named machine's budget as
    /// already spoken for — the residual-capacity constraint incremental
    /// tenant admission schedules under (residents' predicted load at
    /// their certified rates is reserved machine by machine).  Repeated
    /// calls for one machine accumulate.
    pub fn reserve_machine_load(mut self, machine: impl Into<String>, pct: f64) -> Self {
        self.reserved_loads.push((machine.into(), pct));
        self
    }
}

/// An anytime-search budget: how much work a scheduler may spend before
/// it must return its incumbent.  The default is unlimited — identical
/// behavior to the pre-budget API.
///
/// Budgets are **deterministic**: they count candidates and virtual
/// work units, never wall-clock time, so a budgeted search returns the
/// bit-identical schedule on every machine and at every load.  Policies
/// that stop on budget report it through
/// [`Provenance::terminated`](super::Provenance) together with the best
/// surviving bound and the resulting optimality gap; heuristic policies
/// (which evaluate a bounded handful of candidates anyway) ignore
/// budgets cheaply.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SearchBudget {
    /// Stop after evaluating this many complete candidates.
    pub max_candidates: Option<u64>,
    /// Deterministic virtual-time cap: every candidate evaluation or
    /// delta probe charges one unit per machine touched (`O(M)` work →
    /// `M` units), so the cap tracks compute without reading a clock.
    pub max_virtual_ops: Option<u64>,
    /// Stop as soon as the certified relative gap (incumbent vs. best
    /// surviving bound) drops to this value or below.
    pub target_gap: Option<f64>,
}

impl SearchBudget {
    /// No limits: search runs to exhaustion (the default).
    pub fn unlimited() -> Self {
        SearchBudget::default()
    }

    /// True when no cap is set (target gap alone still counts as a
    /// limit: it can stop an exhaustive walk early).
    pub fn is_unlimited(&self) -> bool {
        self.max_candidates.is_none() && self.max_virtual_ops.is_none() && self.target_gap.is_none()
    }

    /// Cap the number of complete candidates evaluated.
    pub fn with_max_candidates(mut self, n: u64) -> Self {
        self.max_candidates = Some(n);
        self
    }

    /// Cap deterministic virtual work units (≈ machines touched).
    pub fn with_max_virtual_ops(mut self, n: u64) -> Self {
        self.max_virtual_ops = Some(n);
        self
    }

    /// Stop once the certified optimality gap is ≤ `gap` (relative,
    /// e.g. `0.05` for 5%).
    pub fn with_target_gap(mut self, gap: f64) -> Self {
        self.target_gap = Some(gap);
        self
    }
}

/// One scheduling request: an objective plus constraints, optionally
/// under a [`SearchBudget`] and warm-started from an incumbent
/// placement.
///
/// ```no_run
/// use hstorm::scheduler::{Constraints, Objective, ScheduleRequest, SearchBudget};
///
/// let req = ScheduleRequest::new(Objective::MaxThroughput)
///     .with_constraints(Constraints::new().exclude_machine("i3-0").reserve_headroom(10.0))
///     .with_budget(SearchBudget::unlimited().with_max_candidates(50_000).with_target_gap(0.05));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleRequest {
    pub objective: Objective,
    pub constraints: Constraints,
    /// Anytime-search budget (default: unlimited).
    pub budget: SearchBudget,
    /// Incumbent placement to warm-start search policies from — the
    /// controller's re-plan path passes the currently-running placement
    /// so the portfolio starts at a known-good solution.  Heuristic
    /// policies ignore it; search policies repair it against the
    /// request's constraints before use.
    pub warm_start: Option<Placement>,
}

impl Default for ScheduleRequest {
    fn default() -> Self {
        ScheduleRequest::max_throughput()
    }
}

impl ScheduleRequest {
    pub fn new(objective: Objective) -> Self {
        ScheduleRequest {
            objective,
            constraints: Constraints::default(),
            budget: SearchBudget::default(),
            warm_start: None,
        }
    }

    /// The common case: maximize throughput, no constraints.
    pub fn max_throughput() -> Self {
        ScheduleRequest::new(Objective::MaxThroughput)
    }

    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    pub fn with_constraints(mut self, constraints: Constraints) -> Self {
        self.constraints = constraints;
        self
    }

    /// Bound the search effort (see [`SearchBudget`]).
    pub fn with_budget(mut self, budget: SearchBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Warm-start search policies from an incumbent placement.
    pub fn with_warm_start(mut self, placement: Placement) -> Self {
        self.warm_start = Some(placement);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates() {
        let c = Constraints::new()
            .exclude_machine("a")
            .exclude_machines(["b", "c"])
            .pin_component("bolt", ["a"])
            .max_instances("bolt", 2)
            .reserve_headroom(5.0)
            .reserve_machine_load("a", 12.5);
        assert_eq!(c.excluded_machines, vec!["a", "b", "c"]);
        assert_eq!(c.pins.len(), 1);
        assert_eq!(c.max_instances, vec![("bolt".to_string(), 2)]);
        assert_eq!(c.headroom_pct, 5.0);
        assert_eq!(c.reserved_loads, vec![("a".to_string(), 12.5)]);
        assert!(!c.is_empty());
        assert!(Constraints::new().is_empty());
        assert!(!Constraints::new().reserve_machine_load("a", 1.0).is_empty());
    }

    #[test]
    fn objective_describe_is_stable() {
        assert_eq!(Objective::MaxThroughput.describe(), "max-throughput");
        assert_eq!(Objective::MinMachinesAtRate(120.0).describe(), "min-machines@120.0");
        assert_eq!(Objective::BalancedUtilization.describe(), "balanced-utilization");
    }

    #[test]
    fn request_default_is_max_throughput() {
        let r = ScheduleRequest::default();
        assert_eq!(r.objective, Objective::MaxThroughput);
        assert!(r.constraints.is_empty());
        assert!(r.budget.is_unlimited());
        assert!(r.warm_start.is_none());
    }

    #[test]
    fn budget_builder_accumulates() {
        let b = SearchBudget::unlimited();
        assert!(b.is_unlimited());
        let b = b.with_max_candidates(100).with_max_virtual_ops(5_000).with_target_gap(0.1);
        assert_eq!(b.max_candidates, Some(100));
        assert_eq!(b.max_virtual_ops, Some(5_000));
        assert_eq!(b.target_gap, Some(0.1));
        assert!(!b.is_unlimited());
        // a target gap alone already counts as a limit
        assert!(!SearchBudget::unlimited().with_target_gap(0.01).is_unlimited());
    }
}
