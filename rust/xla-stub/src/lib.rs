//! API-compatible **stub** of the `xla` PJRT bindings.
//!
//! hstorm's `pjrt` cargo feature compiles `rust/src/runtime/` against an
//! `xla` crate.  The real bindings link the XLA C++ runtime, which only
//! exists in the vendored build image; this stub keeps the feature
//! *type-checking* (and the default build resolving) on any machine.
//! Every entry point fails at runtime with a clear message, which the
//! callers already treat as "PJRT unavailable" — the same degraded path
//! as missing AOT artifacts.  A vendored build swaps in the real crate
//! via `[patch]` or by pointing the `xla` path dependency at the vendor
//! checkout.

use std::borrow::Borrow;
use std::fmt;

/// Stub error: every fallible operation returns this.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn stub(op: &str) -> Self {
        Error(format!(
            "xla stub: {op} is unavailable (hstorm was built against the in-repo xla stub; \
             build against the vendored xla crate for real PJRT execution)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module text (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::stub("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_pjrt_entry_point_errors_with_a_stub_message() {
        assert!(PjRtClient::cpu().is_err());
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("xla stub"), "{e}");
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
