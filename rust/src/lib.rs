//! # hstorm — heterogeneity-aware stream scheduling
//!
//! A production-shaped reproduction of Nasiri, Nasehi, Divband & Goudarzi,
//! *"A Scheduling Algorithm to Maximize Storm Throughput in Heterogeneous
//! Cluster"* (2020), as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: topology model, heterogeneous
//!   cluster model, the paper's scheduler (Alg. 1 + Alg. 2), the Storm
//!   default Round-Robin baseline, the optimal exhaustive comparator, a
//!   tokio stream-processing engine (the "real cluster" substitute), a
//!   large-scale analytic simulator, an online control plane
//!   ([`controller`]) that replays workload traces over virtual time and
//!   keeps the topology scheduled as machines churn and profiles drift,
//!   and the experiment harness that regenerates every figure/table of
//!   the paper's evaluation.
//! * **L2 (python/compile/model.py)** — the placement-evaluation model
//!   (rate propagation, eq. 6; CPU prediction, eq. 5; feasibility +
//!   throughput) as a JAX graph, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the scoring
//!   contraction and the propagation step, validated against a pure-jnp
//!   oracle.
//!
//! Python never runs at schedule or serve time: `make artifacts` lowers
//! the model once; [`runtime`] loads and executes the HLO via PJRT.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hstorm::cluster::presets;
//! use hstorm::scheduler::{hetero::HeteroScheduler, Scheduler};
//! use hstorm::topology::benchmarks;
//!
//! let top = benchmarks::linear();
//! let (cluster, profiles) = presets::paper_cluster();
//! let sched = HeteroScheduler::default();
//! let out = sched.schedule(&top, &cluster, &profiles).unwrap();
//! println!("rate={} thpt={}", out.rate, out.eval.throughput);
//! ```

pub mod cluster;
pub mod config;
pub mod controller;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod predict;
pub mod profiling;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod topology;
pub mod util;

pub use error::{Error, Result};
