//! Tiny CLI argument parser for the launcher.
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments; unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) given the set of flags
    /// that take values and the set of boolean flags.
    pub fn parse(
        argv: impl IntoIterator<Item = String>,
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if bool_flags.contains(&name.as_str()) {
                    if inline.is_some() {
                        return Err(Error::Config(format!("--{name} takes no value")));
                    }
                    out.bools.push(name);
                } else if value_flags.contains(&name.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?,
                    };
                    out.flags.insert(name, v);
                } else {
                    return Err(Error::Config(format!("unknown flag --{name}")));
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not a number"))),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name}: '{v}' is not an integer"))),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            sv(&["bench", "fig3", "--rate", "8", "--verbose", "--out=x.json"]),
            &["rate", "out"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["bench", "fig3"]);
        assert_eq!(a.get("rate"), Some("8"));
        assert_eq!(a.get("out"), Some("x.json"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(Args::parse(sv(&["--nope"]), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(sv(&["--rate"]), &["rate"], &[]).is_err());
    }

    #[test]
    fn numeric_accessors() {
        let a = Args::parse(sv(&["--rate", "2.5", "--n", "7"]), &["rate", "n"], &[]).unwrap();
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get_usize("n", 0).unwrap(), 7);
        assert_eq!(a.get_usize("missing", 3).unwrap(), 3);
        let bad = Args::parse(sv(&["--n", "x"]), &["n"], &[]).unwrap();
        assert!(bad.get_usize("n", 0).is_err());
    }

    #[test]
    fn bool_with_value_rejected() {
        assert!(Args::parse(sv(&["--verbose=1"]), &[], &["verbose"]).is_err());
    }
}
