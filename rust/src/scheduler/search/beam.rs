//! Beam search over per-component row choices.
//!
//! The DFS's levels become beam levels: starting from the empty
//! prefix, each level pushes every candidate row of one component onto
//! every surviving partial state, ranks the children by their
//! admissible optimistic bound and keeps the best `width`.  Rows are
//! expanded best-singleton-bound-first ([`super::singleton_order`]),
//! so when the budget starves a level the few expansions it affords
//! still probe the strongest rows — the beam degrades toward a greedy
//! descent instead of an arbitrary truncation.  At the leaf level the
//! surviving prefixes' completions are evaluated exactly and folded
//! through the same objective-aware predicates as the exhaustive
//! search.
//!
//! Beam search is incomplete, so it never claims a bound or a gap of
//! its own; the portfolio combines it with branch-and-bound's
//! certificate.

use std::time::Instant;

use super::super::optimal::{no_best_error, seed_candidates, Best, KernelCtx};
use super::super::{
    Problem, Provenance, Schedule, ScheduleRequest, Scheduler, SearchBudget, Termination,
};
use super::{record_search_started, repair_warm_start, singleton_order, BudgetMeter, TableSet};
use crate::predict::kernel::AccumState;
use crate::{Error, Result};

/// Beam-search policy (`beam` in the registry).
#[derive(Debug, Clone)]
pub struct BeamScheduler {
    /// Max instances per component (bounds each level's row set).
    pub max_instances_per_component: usize,
    /// Partial candidates kept per level.
    pub width: usize,
    /// Seed the fold with the heuristics (guarantees a feasible result
    /// even when every beam completion is infeasible).
    pub seed_heuristics: bool,
    /// Default budget when the request leaves its budget unlimited.
    pub budget: SearchBudget,
}

impl Default for BeamScheduler {
    fn default() -> Self {
        BeamScheduler {
            max_instances_per_component: 3,
            width: 8,
            seed_heuristics: true,
            budget: SearchBudget::unlimited(),
        }
    }
}

/// One surviving partial candidate: accumulators + row choices so far.
struct State {
    acc: AccumState,
    sel: Vec<usize>,
}

pub(crate) struct BeamOutcome {
    pub(crate) evaluated: u64,
    pub(crate) pruned: u64,
    /// Budget ran dry before the planned expansions finished.
    pub(crate) stopped: bool,
}

/// Run one beam descent, folding completions into `best`.
pub(crate) fn run(
    ctx: &KernelCtx,
    orders: &[Vec<usize>],
    width: usize,
    best: &mut Option<Best>,
    meter: &mut BudgetMeter,
) -> BeamOutcome {
    let n_comp = ctx.tables.len();
    let n_m = ctx.ev.n_machines() as u64;
    let width = width.max(1);
    let mut out = BeamOutcome { evaluated: 0, pruned: 0, stopped: false };
    let mut beam = vec![State { acc: AccumState::new(ctx.ev.n_machines()), sel: vec![0; n_comp] }];

    // internal levels, outermost component first (the DFS's order)
    for c in (1..n_comp).rev() {
        let rows = &ctx.tables[c].rows;
        // (bound, parent, row): score every affordable child cheaply,
        // clone accumulators only for the `width` survivors
        let mut scored: Vec<(f64, usize, usize)> = Vec::new();
        'expand: for (pi, st) in beam.iter_mut().enumerate() {
            for &ri in &orders[c] {
                if !meter.try_charge_vops(n_m) {
                    out.stopped = true;
                    break 'expand;
                }
                st.acc.push(&rows[ri]);
                let b = st.acc.bound(&ctx.ev.cap);
                st.acc.pop();
                if b > 0.0 {
                    scored.push((b, pi, ri));
                }
            }
        }
        if scored.is_empty() {
            // every affordable child was infeasible (or the budget died
            // at the level boundary): descend anyway through the
            // strongest singleton row so a complete candidate exists
            scored.push((0.0, 0, orders[c][0]));
        }
        scored.sort_by(|x, y| {
            y.0.partial_cmp(&x.0)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(x.1.cmp(&y.1))
                .then(x.2.cmp(&y.2))
        });
        scored.truncate(width);
        let next: Vec<State> = scored
            .into_iter()
            .map(|(_, pi, ri)| {
                let mut acc = beam[pi].acc.clone();
                acc.push(&rows[ri]);
                let mut sel = beam[pi].sel.clone();
                sel[c] = ri;
                State { acc, sel }
            })
            .collect();
        beam = next;
        if out.stopped {
            // states below this level never received their rows, so a
            // leaf evaluation would score incomplete accumulators
            // optimistically and could displace a better seed — stop
            // here and let the fold's seeds stand
            return out;
        }
    }

    // leaf level: evaluate completions exactly, identical fold
    let rows = &ctx.tables[0].rows;
    'leaf: for st in beam.iter_mut() {
        for &ri in &orders[0] {
            if !meter.try_charge() {
                out.stopped = true;
                break 'leaf;
            }
            out.evaluated += 1;
            st.sel[0] = ri;
            st.acc.push(&rows[ri]);
            let acc = &st.acc;
            let sel = &st.sel;
            let r = ctx.consider_scored(acc, || ctx.materialize(sel), best);
            st.acc.pop();
            if r <= 0.0 {
                out.pruned += 1;
            }
        }
    }
    out
}

impl Scheduler for BeamScheduler {
    fn name(&self) -> &'static str {
        "beam"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let started = Instant::now();
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        let n_comp = problem.topology().n_components();
        let n_m = problem.cluster().n_machines();
        record_search_started(self.name(), n_comp, n_m);

        let ts = TableSet::build(&ev, &rc, self.max_instances_per_component, n_comp, n_m);
        let ctx = ts.ctx(&ev, &rc, &req.objective);
        let orders = singleton_order(&ctx);

        let mut best: Option<Best> = None;
        let mut evaluated: u64 = 0;
        if self.seed_heuristics {
            seed_candidates(&ctx, problem, req, self.name(), &mut best, &mut evaluated);
        }
        if let Some(warm) = &req.warm_start {
            if let Some(fixed) = repair_warm_start(&rc, warm, n_comp, n_m) {
                ctx.consider_seed(fixed, &mut best, &mut evaluated);
            }
        }

        let budget = if req.budget.is_unlimited() { self.budget } else { req.budget };
        let mut meter = BudgetMeter::new(&budget, n_m as u64);
        meter.charge_n(evaluated);
        let out = run(&ctx, &orders, self.width, &mut best, &mut meter);
        evaluated += out.evaluated;

        let best = best.ok_or_else(|| no_best_error(&req.objective))?;
        if best.rate <= 0.0 {
            return Err(Error::Schedule("no feasible placement found by the beam".into()));
        }
        let mut s = super::super::finish(&ev, best.placement)?;
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: "kernel".into(),
            wall: started.elapsed(),
            // incomplete search: no certificate of its own
            bound: None,
            optimality_gap: None,
            terminated: if out.stopped { Termination::Budget } else { Termination::Exhausted },
        };
        super::super::record_schedule_telemetry(&s, out.pruned);
        super::super::debug_validate(problem, req, &s);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::optimal::OptimalScheduler;
    use super::super::super::{Problem, ScheduleRequest};
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    /// On the paper-cluster micro space a width-8 beam finds the true
    /// optimum (the space is near-disjoint, which is the regime beam
    /// search exploits).
    #[test]
    fn beam_finds_optimum_on_micro_space() {
        let p = problem();
        let req = ScheduleRequest::max_throughput();
        let opt = OptimalScheduler { threads: 1, ..Default::default() }
            .schedule(&p, &req)
            .unwrap();
        let beam = BeamScheduler::default().schedule(&p, &req).unwrap();
        assert!(
            beam.rate >= opt.rate * 0.95,
            "beam rate {} far below optimum {}",
            beam.rate,
            opt.rate
        );
        assert!(
            beam.provenance.placements_evaluated < opt.provenance.placements_evaluated,
            "beam must evaluate far fewer candidates than exhaustive"
        );
    }

    /// The beam honors a candidate budget and says so.
    #[test]
    fn beam_honors_budget() {
        let p = problem();
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_max_candidates(10));
        let s = BeamScheduler::default().schedule(&p, &req).unwrap();
        assert!(s.provenance.placements_evaluated <= 10);
        assert_eq!(s.provenance.terminated, Termination::Budget);
        assert_eq!(s.provenance.optimality_gap, None, "incomplete search claims no gap");
    }

    /// Determinism: two runs produce bit-identical schedules.
    #[test]
    fn beam_is_deterministic() {
        let p = problem();
        let req = ScheduleRequest::max_throughput();
        let a = BeamScheduler::default().schedule(&p, &req).unwrap();
        let b = BeamScheduler::default().schedule(&p, &req).unwrap();
        assert_eq!(a.placement.x, b.placement.x);
        assert_eq!(a.rate.to_bits(), b.rate.to_bits());
    }
}
