//! `dataplane` — ROADMAP item 1: execute each scheduler's placement on
//! the batched ring dataplane and publish the measured rates.
//!
//! For every (benchmark topology × policy) cell on the paper cluster,
//! schedule, pick 80% of the certified rate, choose a `time_scale`
//! that maps the predicted virtual throughput onto a
//! millions-of-tuples/s wall-clock target, run the engine
//! ([`crate::engine`], ring dataplane), and table executed wall
//! tuples/s, virtual-vs-predicted throughput error,
//! predicted-vs-executed utilization error (the §6.2 accuracy claim
//! re-grounded on real threads) and sink latency percentiles.
//!
//! The CLI writes the machine-readable form to `BENCH_dataplane.json`;
//! CI's dataplane smoke greps the rendered notes
//! `executed throughput >= 1M tuples/s : PASS` (scored on the
//! word-count benchmark topology, `rolling-count`) and the
//! `predicted-vs-executed utilization` accuracy headline, and uploads
//! the JSON as an artifact.

use crate::cluster::presets;
use crate::engine::{self, EngineConfig};
use crate::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use crate::util::json::{self, Value};
use crate::Result;

use super::{f1, f2, ExperimentResult};

/// Fraction of each schedule's certified max stable rate the engine
/// runs at (safely sub-saturation, as in the paper's sweeps).
const RATE_FRACTION: f64 = 0.8;

/// The word-count benchmark topology the 1M-tuples/s roadmap target is
/// scored on.
const WORDCOUNT: &str = "rolling-count";

pub fn run(fast: bool) -> Result<ExperimentResult> {
    run_with_json(fast).map(|(r, _)| r)
}

pub fn run_with_json(fast: bool) -> Result<(ExperimentResult, Value)> {
    let mut out = ExperimentResult::new(
        "dataplane",
        "executed throughput/latency/utilization on the batched ring dataplane (paper cluster)",
        &[
            "topology",
            "policy",
            "rate",
            "wall tuple/s",
            "thpt err %",
            "util err pp (mean/max)",
            "p50/p95/p99 (ms)",
            "verdict",
        ],
    );
    // the word-count topology leads so the roadmap gate is always
    // exercised, fast or full
    let topologies: Vec<&str> = if fast {
        vec![WORDCOUNT, "linear"]
    } else {
        vec![WORDCOUNT, "linear", "diamond", "star", "unique-visitor"]
    };
    let policies: Vec<&str> =
        if fast { vec!["hetero", "default"] } else { vec!["hetero", "default", "optimal"] };
    let wall_target = if fast { 2.5e6 } else { 3.0e6 };
    let cfg_base = EngineConfig {
        duration: std::time::Duration::from_millis(if fast { 700 } else { 2000 }),
        warmup: std::time::Duration::from_millis(if fast { 250 } else { 500 }),
        ..Default::default()
    };

    let (cluster, db) = presets::paper_cluster();
    let mut runs: Vec<Value> = Vec::new();
    let mut util_errs: Vec<f64> = Vec::new();
    let mut wordcount_best = 0.0f64;
    let mut total_shed = 0u64;
    for tname in &topologies {
        let top = crate::resolve::topology(tname)?;
        let problem = Problem::new(&top, &cluster, &db)?;
        for pol in &policies {
            let sched = registry::create(pol, &PolicyParams::default())?;
            let s = sched.schedule(&problem, &ScheduleRequest::max_throughput())?;
            let rate = s.rate * RATE_FRACTION;
            if rate <= 0.0 {
                continue;
            }
            let pred = problem.evaluator().evaluate(&s.placement, rate)?;
            // compress time so the predicted virtual throughput lands on
            // the wall-clock target rate
            let time_scale = (pred.throughput / wall_target).clamp(1e-5, 1.0);
            let cfg = EngineConfig { time_scale, ..cfg_base.clone() };
            let rep = engine::run(&top, &cluster, &db, &s.placement, rate, &cfg)?;

            let thpt_err =
                (rep.throughput - pred.throughput).abs() / pred.throughput.max(1e-9) * 100.0;
            let mut mean_err = 0.0;
            let mut max_err = 0.0f64;
            for (p, g) in pred.util.iter().zip(&rep.util) {
                let err = (p - g).abs();
                util_errs.push(err);
                mean_err += err;
                max_err = max_err.max(err);
            }
            mean_err /= pred.util.len().max(1) as f64;
            total_shed += rep.shed;
            if *tname == WORDCOUNT {
                wordcount_best = wordcount_best.max(rep.wall_throughput);
            }
            let lat = rep.latency.as_ref();
            out.row(vec![
                tname.to_string(),
                pol.to_string(),
                f1(rate),
                format!("{:.2}M", rep.wall_throughput / 1e6),
                f2(thpt_err),
                format!("{} / {}", f2(mean_err), f2(max_err)),
                lat.map_or("-".to_string(), |l| {
                    format!("{} / {} / {}", f2(l.p50 * 1e3), f2(l.p95 * 1e3), f2(l.p99 * 1e3))
                }),
                if rep.throttled { "throttled" } else { "ok" }.to_string(),
            ]);
            runs.push(json::obj(vec![
                ("topology", json::s(*tname)),
                ("policy", json::s(*pol)),
                ("rate", json::num(rate)),
                ("time_scale", json::num(time_scale)),
                ("wall_tuples_s", json::num(rep.wall_throughput)),
                ("virtual_throughput", json::num(rep.throughput)),
                ("predicted_throughput", json::num(pred.throughput)),
                ("throughput_err_pct", json::num(thpt_err)),
                ("util_executed", json::arr(rep.util.iter().map(|&u| json::num(u)).collect())),
                ("util_predicted", json::arr(pred.util.iter().map(|&u| json::num(u)).collect())),
                ("util_err_mean_pp", json::num(mean_err)),
                ("util_err_max_pp", json::num(max_err)),
                ("latency_p50_ms", json::num(lat.map_or(0.0, |l| l.p50 * 1e3))),
                ("latency_p95_ms", json::num(lat.map_or(0.0, |l| l.p95 * 1e3))),
                ("latency_p99_ms", json::num(lat.map_or(0.0, |l| l.p99 * 1e3))),
                ("credit_stalls", json::num(rep.credit_stalls as f64)),
                ("throttled", Value::Bool(rep.throttled)),
                ("shed", json::num(rep.shed as f64)),
            ]));
        }
    }

    let pass_1m = wordcount_best >= 1.0e6;
    out.note(format!(
        "executed throughput >= 1M tuples/s : {} (word-count best {:.2}M tuples/s wall, \
         batched ring dataplane)",
        if pass_1m { "PASS" } else { "FAIL" },
        wordcount_best / 1e6
    ));
    let mean = util_errs.iter().sum::<f64>() / util_errs.len().max(1) as f64;
    let max = util_errs.iter().cloned().fold(0.0, f64::max);
    out.note(format!(
        "dataplane predicted-vs-executed utilization: mean |err| = {mean:.2} pp, max |err| = \
         {max:.2} pp over {} machine readings -> mean accuracy = {:.1}% (paper §6.2 re-grounded \
         on real threads)",
        util_errs.len(),
        100.0 - mean
    ));
    out.note(format!(
        "credit-based backpressure is lossless: {total_shed} tuples shed across all runs \
         (executed at {:.0}% of each certified rate)",
        RATE_FRACTION * 100.0
    ));
    let v = json::obj(vec![
        ("runs", json::arr(runs)),
        ("wordcount_wall_tuples_s", json::num(wordcount_best)),
        ("pass_1m", Value::Bool(pass_1m)),
        ("util_err_mean_pp", json::num(mean)),
        ("util_err_max_pp", json::num(max)),
        ("shed_total", json::num(total_shed as f64)),
    ]);
    Ok((out, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    // One shared fast run: four engine executions are expensive, so the
    // structural and accounting checks share it.
    #[test]
    fn dataplane_rows_are_lossless_and_accurate() {
        let (r, v) = run_with_json(true).unwrap();
        assert_eq!(r.rows.len(), 4, "{:?}", r.rows);
        // the roadmap gate note must always be present (CI greps PASS
        // on the release build; debug unit tests only check presence)
        assert!(
            r.notes.iter().any(|n| n.contains("executed throughput >= 1M tuples/s")),
            "{:?}",
            r.notes
        );
        let note = r
            .notes
            .iter()
            .find(|n| n.contains("predicted-vs-executed utilization"))
            .expect("accuracy note");
        assert!(note.contains("mean accuracy"), "{note}");
        // charged-service accounting keeps executed util close to eq. 5
        // even on loaded test machines
        assert_eq!(v.num_field("shed_total").unwrap(), 0.0, "ring dataplane must never shed");
        assert!(v.num_field("util_err_mean_pp").unwrap() < 8.0);
        // every run processed at a wall rate far beyond the legacy
        // engine's regime
        let runs = v.get("runs").unwrap().as_arr().expect("runs array");
        assert_eq!(runs.len(), 4);
        for run in runs {
            assert!(run.num_field("wall_tuples_s").unwrap() > 100_000.0, "{run}");
        }
    }
}
