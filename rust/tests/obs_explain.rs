//! Acceptance tests for the observability layer and the `explain`
//! decision-provenance surface: for every registered policy on the
//! paper cluster, the explanation names the bottleneck (component,
//! machine, residual headroom) that determines R0*, and the candidate
//! counts it reports exactly match the schedule's [`Provenance`] and
//! the journal's `schedule_chosen` event.

use std::sync::Mutex;

use hstorm::cluster::presets;
use hstorm::obs;
use hstorm::obs::explain;
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest, Scheduler};
use hstorm::topology::benchmarks;

/// Tests that read the process-global journal must not interleave.
static JOURNAL_GATE: Mutex<()> = Mutex::new(());

fn paper_problem() -> Problem {
    let (cluster, db) = presets::paper_cluster();
    Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
}

fn params() -> PolicyParams {
    // small search bound keeps the optimal policy fast in debug mode
    PolicyParams { max_instances_per_component: 2, ..Default::default() }
}

#[test]
fn every_policy_explains_its_bottleneck() {
    let problem = paper_problem();
    let top = problem.topology().clone();
    let cluster = problem.cluster().clone();
    for info in registry::policies() {
        let sched = registry::create(info.name, &params()).unwrap();
        let s = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        let x = explain::analyze(&top, &cluster, problem.evaluator(), &s);

        // candidates evaluated mirror provenance exactly
        assert_eq!(
            x.evaluated, s.provenance.placements_evaluated,
            "{}: explain evaluated != provenance",
            info.name
        );
        assert_eq!(x.policy, info.name);

        // the bottleneck names the machine/component pair capping R0*
        let b = x.bottleneck.as_ref().unwrap_or_else(|| panic!("{}: no bottleneck", info.name));
        assert!(
            cluster.machines.iter().any(|m| m.name == b.machine),
            "{}: bottleneck machine '{}' not in cluster",
            info.name,
            b.machine
        );
        assert!(
            top.components.iter().any(|c| c.name == b.component),
            "{}: bottleneck component '{}' not in topology",
            info.name,
            b.component
        );
        assert!(
            (b.rate_cap - s.rate).abs() < 1e-6,
            "{}: bottleneck caps at {} but certified rate is {}",
            info.name,
            b.rate_cap,
            s.rate
        );
        assert!(b.headroom.abs() < 1e-6, "{}: residual headroom {}", info.name, b.headroom);

        // the rendered text carries the full decision story
        let text = explain::render(&x);
        for needle in [b.machine.as_str(), b.component.as_str(), "residual headroom"] {
            assert!(text.contains(needle), "{}: missing '{needle}' in:\n{text}", info.name);
        }
        assert!(
            text.contains(&format!("candidates evaluated : {}", x.evaluated)),
            "{}:\n{text}",
            info.name
        );
    }
}

#[test]
fn journal_schedule_chosen_matches_provenance() {
    let _gate = JOURNAL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let problem = paper_problem();
    for info in registry::policies() {
        let sched = registry::create(info.name, &params()).unwrap();
        let s = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        // the latest schedule_chosen for this policy is the one just
        // recorded (other tests' events may precede it in the ring)
        let entries = obs::global().journal().entries();
        let chosen = entries
            .iter()
            .rev()
            .find_map(|e| match &e.event {
                obs::Event::ScheduleChosen { policy, evaluated, rate, .. }
                    if policy == info.name =>
                {
                    Some((*evaluated, *rate))
                }
                _ => None,
            })
            .unwrap_or_else(|| panic!("{}: no schedule_chosen journaled", info.name));
        assert_eq!(
            chosen.0, s.provenance.placements_evaluated,
            "{}: journal evaluated != provenance",
            info.name
        );
        assert!((chosen.1 - s.rate).abs() < 1e-9, "{}: journal rate != schedule", info.name);
    }
}

#[test]
fn disabling_telemetry_changes_nothing_but_the_journal() {
    let _gate = JOURNAL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let problem = paper_problem();
    let sched = registry::create("hetero", &params()).unwrap();
    let on = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();

    obs::set_enabled(false);
    let before = obs::global().journal().total_recorded();
    let off = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
    let after = obs::global().journal().total_recorded();
    obs::set_enabled(true);

    assert_eq!(before, after, "disabled telemetry must not journal");
    assert_eq!(on.placement, off.placement, "telemetry must not change the placement");
    assert_eq!(on.rate, off.rate, "telemetry must not change the certified rate");
}

#[test]
fn explain_cli_names_bottleneck_and_writes_metrics() {
    let dir = std::env::temp_dir();
    let metrics_path = dir.join("hstorm_obs_explain_metrics.json");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_hstorm"))
        .args([
            "explain",
            "--topology",
            "linear",
            "--max-instances",
            "2",
            "--metrics-out",
            metrics_path.to_str().unwrap(),
        ])
        .output()
        .expect("spawn hstorm explain");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for needle in ["bottleneck", "residual headroom", "candidates evaluated"] {
        assert!(stdout.contains(needle), "missing '{needle}' in:\n{stdout}");
    }
    // every registered policy got its own explain block
    for info in registry::policies() {
        assert!(stdout.contains(&format!("policy={}", info.name)), "{stdout}");
    }

    // --metrics-out dumped the telemetry snapshot of that process
    let text = std::fs::read_to_string(&metrics_path).expect("metrics file written");
    let v = hstorm::util::json::parse(&text).unwrap();
    let metrics = v.get("metrics").unwrap();
    assert!(metrics.num_field("sched.hetero.evaluated").unwrap() > 0.0);
    let journal = v.get("journal").unwrap().as_arr().unwrap();
    assert!(
        journal.iter().any(|e| e.str_field("kind").is_ok_and(|k| k == "schedule_chosen")),
        "journal missing schedule_chosen events"
    );
    let _ = std::fs::remove_file(&metrics_path);
}
