//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null); numbers are f64.  Used for
//! `artifacts/dims.json`, experiment configs and report emission.

use std::collections::BTreeMap;
use std::fmt;

use crate::{Error, Result};

/// A JSON value.  Objects use `BTreeMap` so serialization is
/// deterministic (stable diffs in EXPERIMENTS.md).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|v| *v >= 0.0 && v.fract() == 0.0).map(|v| v as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| Error::Config(format!("missing key '{key}'")))
    }

    /// Optional lookup.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn num_field(&self, key: &str) -> Result<f64> {
        self.get(key)?
            .as_f64()
            .ok_or_else(|| Error::Config(format!("key '{key}' is not a number")))
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)?
            .as_str()
            .ok_or_else(|| Error::Config(format!("key '{key}' is not a string")))
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, text: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{text}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(arr)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw bytes
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let numeric = |c: u8| {
            c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
        };
        while matches!(self.peek(), Some(c) if numeric(c)) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, 0, &mut s);
    s
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Value::Obj(o) => {
            if o.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
    }
}

/// Serialize on one line with no whitespace — the JSONL form used by
/// the observability journal sink ([`crate::obs::Journal`]).
pub fn to_string_compact(v: &Value) -> String {
    let mut s = String::new();
    write_compact(v, &mut s);
    s
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null | Value::Bool(_) | Value::Num(_) | Value::Str(_) => write_value(v, 0, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Value::Obj(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string_pretty(self))
    }
}

/// Builder helpers.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(v: f64) -> Value {
    Value::Num(v)
}

pub fn bool(v: bool) -> Value {
    Value::Bool(v)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].str_field("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn reject_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"dims": {"C": 16, "M": 32}, "list": [1, 2.5, "x", true, null]}"#;
        let v = parse(text).unwrap();
        let back = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn real_dims_json_shape() {
        let text = r#"{"C": 16, "M": 32, "DEPTH": 16, "B_BATCH": 256,
                       "B_ONE": 1, "CAP": 100.0, "WORK_N": 64,
                       "artifacts": {"scorer_b256.hlo.txt": 19511}}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.num_field("C").unwrap(), 16.0);
        assert_eq!(v.get("artifacts").unwrap().num_field("scorer_b256.hlo.txt").unwrap(), 19511.0);
    }

    #[test]
    fn writer_escapes() {
        let v = s("a\"b\nc");
        let text = to_string_pretty(&v);
        assert_eq!(text, "\"a\\\"b\\nc\"");
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
        assert_eq!(to_string_pretty(&parse("[]").unwrap()), "[]");
    }
}
