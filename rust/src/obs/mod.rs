//! Unified observability: histograms, span timers, a structured event
//! journal, and the decision-provenance `explain` renderer.
//!
//! Everything hangs off the existing [`crate::metrics::Registry`]: the
//! registry owns named [`Histogram`]s and one [`Journal`] next to its
//! counters/gauges/means, so engine metrics and scheduler telemetry
//! share a single snapshot/export path.  The instrumented layers are:
//!
//! * the kernel DFS ([`crate::scheduler::optimal`] /
//!   [`crate::predict::kernel`]) — candidates evaluated, candidates
//!   pruned, row-table build time, search wall time;
//! * the schedulers — per-policy timing and runner-up rates;
//! * the controllers ([`crate::controller`]) — per-step decision
//!   latency, breach / re-plan / admission events;
//! * the event simulator ([`crate::simulator::event`]) — queue-depth
//!   gauges, shed counters, latency histograms.
//!
//! Telemetry is side-channel only: nothing recorded here feeds back
//! into placements, certified rates or report structs, so instrumented
//! and uninstrumented runs produce identical schedules.  The global
//! [`set_enabled`] switch turns every instrumentation site into a
//! no-op, which is how the benches measure telemetry overhead.

pub mod explain;
pub mod histogram;
mod histogram_core;
pub mod journal;
pub(crate) mod sync_shim;

pub use histogram::{Histogram, Span};
pub use journal::{Event, Journal};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::metrics::Registry;
use crate::util::json::{self, Value};

static GLOBAL: OnceLock<Registry> = OnceLock::new();
static ENABLED: AtomicBool = AtomicBool::new(true);

/// The process-wide registry all instrumentation sites write to.
pub fn global() -> &'static Registry {
    GLOBAL.get_or_init(Registry::new)
}

/// Is telemetry collection on?  Instrumentation sites check this
/// before touching histograms or the journal.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip telemetry collection globally (default: on).  The benches use
/// the off position as the zero-overhead baseline.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Sanitize a dotted metric name into the Prometheus grammar
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
fn prom_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().map_or(true, |c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Prometheus-style text exposition of a registry snapshot.
pub fn prometheus_text(reg: &Registry) -> String {
    let mut out = String::new();
    for (name, value) in reg.snapshot() {
        out.push_str(&format!("{} {}\n", prom_name(&name), value));
    }
    out
}

/// JSON snapshot: every metric row plus the retained journal entries.
pub fn json_snapshot(reg: &Registry) -> Value {
    let metrics = Value::Obj(
        reg.snapshot().into_iter().map(|(name, value)| (name, json::num(value))).collect(),
    );
    json::obj(vec![("metrics", metrics), ("journal", reg.journal().to_json())])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_is_shared() {
        let c = global().counter("obs.test.shared");
        c.inc();
        assert_eq!(global().counter("obs.test.shared").get(), 1);
    }

    #[test]
    fn prom_names_are_sanitized() {
        assert_eq!(prom_name("sched.hetero.wall_s"), "sched_hetero_wall_s");
        assert_eq!(prom_name("kernel.p50"), "kernel_p50");
        assert_eq!(prom_name("9lives"), "_9lives");
    }

    #[test]
    fn prometheus_text_has_one_line_per_row() {
        let reg = Registry::new();
        reg.counter("a.count").add(3);
        reg.gauge("b.gauge").set(1.5);
        let text = prometheus_text(&reg);
        assert!(text.contains("a_count 3\n"), "{text}");
        assert!(text.contains("b_gauge 1.5\n"), "{text}");
        assert_eq!(text.lines().count(), reg.snapshot().len());
    }

    #[test]
    fn json_snapshot_carries_metrics_and_journal() {
        let reg = Registry::new();
        reg.counter("x").inc();
        reg.journal().record(Event::SearchStarted {
            policy: "hetero".into(),
            components: 4,
            machines: 3,
        });
        let snap = json_snapshot(&reg);
        assert_eq!(snap.get("metrics").unwrap().num_field("x").unwrap(), 1.0);
        let journal = snap.get("journal").unwrap().as_arr().unwrap();
        assert_eq!(journal.len(), 1);
        assert_eq!(journal[0].str_field("kind").unwrap(), "search_started");
    }
}
