//! Fluent builder for user topology graphs.
//!
//! ```no_run
//! use hstorm::topology::builder::TopologyBuilder;
//!
//! let top = TopologyBuilder::new("my-top")
//!     .spout("src", "spout", 1.0)
//!     .bolt("work", "midCompute", 1.0, &["src"])
//!     .bolt("sink", "lowCompute", 0.5, &["work"])
//!     .build()
//!     .unwrap();
//! assert_eq!(top.n_components(), 3);
//! ```

use super::{Component, ComponentKind, Topology};
use crate::{Error, Result};

/// Incrementally assembles a [`Topology`], resolving parent names to
/// indices and validating on `build()`.
pub struct TopologyBuilder {
    name: String,
    components: Vec<Component>,
    edges: Vec<(usize, usize)>,
    errors: Vec<String>,
}

impl TopologyBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        TopologyBuilder {
            name: name.into(),
            components: Vec::new(),
            edges: Vec::new(),
            errors: Vec::new(),
        }
    }

    fn index_of(&self, name: &str) -> Option<usize> {
        self.components.iter().position(|c| c.name == name)
    }

    /// Add a spout. `task_type` keys the profile DB; `alpha` is the
    /// emitted-per-consumed tuple ratio (spouts conventionally 1.0).
    pub fn spout(mut self, name: &str, task_type: &str, alpha: f64) -> Self {
        self.components.push(Component {
            name: name.into(),
            kind: ComponentKind::Spout,
            task_type: task_type.into(),
            alpha,
            weight: 1.0,
        });
        self
    }

    /// Set the input-rate weight of an already-added component (see
    /// [`Component::weight`]): the named spout's external stream arrives
    /// at `weight · R0` instead of `R0`.
    pub fn input_weight(mut self, name: &str, weight: f64) -> Self {
        match self.index_of(name) {
            Some(i) => self.components[i].weight = weight,
            None => self.errors.push(format!("input_weight '{name}': unknown component")),
        }
        self
    }

    /// Add a bolt fed by every component in `parents` (names).
    pub fn bolt(mut self, name: &str, task_type: &str, alpha: f64, parents: &[&str]) -> Self {
        let idx = self.components.len();
        self.components.push(Component {
            name: name.into(),
            kind: ComponentKind::Bolt,
            task_type: task_type.into(),
            alpha,
            weight: 1.0,
        });
        for p in parents {
            match self.index_of(p) {
                Some(pi) => self.edges.push((pi, idx)),
                None => self.errors.push(format!("bolt '{name}': unknown parent '{p}'")),
            }
        }
        self
    }

    /// Add an explicit edge between two existing components by name.
    pub fn edge(mut self, from: &str, to: &str) -> Self {
        match (self.index_of(from), self.index_of(to)) {
            (Some(a), Some(b)) => self.edges.push((a, b)),
            _ => self.errors.push(format!("edge '{from}'->'{to}': unknown component")),
        }
        self
    }

    pub fn build(self) -> Result<Topology> {
        if !self.errors.is_empty() {
            return Err(Error::Topology(self.errors.join("; ")));
        }
        let top = Topology { name: self.name, components: self.components, edges: self.edges };
        top.validate()?;
        Ok(top)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_linear() {
        let t = TopologyBuilder::new("t")
            .spout("s", "spout", 1.0)
            .bolt("a", "lowCompute", 1.0, &["s"])
            .bolt("b", "midCompute", 1.0, &["a"])
            .build()
            .unwrap();
        assert_eq!(t.edges, vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn unknown_parent_is_error() {
        let r = TopologyBuilder::new("t")
            .spout("s", "spout", 1.0)
            .bolt("a", "lowCompute", 1.0, &["nope"])
            .build();
        assert!(r.is_err());
    }

    #[test]
    fn fan_in_edges() {
        let t = TopologyBuilder::new("t")
            .spout("s1", "spout", 1.0)
            .spout("s2", "spout", 1.0)
            .bolt("join", "highCompute", 1.0, &["s1", "s2"])
            .build()
            .unwrap();
        assert_eq!(t.upstream(2).len(), 2);
    }

    #[test]
    fn input_weight_sets_spout_weight() {
        let t = TopologyBuilder::new("t")
            .spout("s", "spout", 1.0)
            .bolt("a", "lowCompute", 1.0, &["s"])
            .input_weight("s", 2.0)
            .build()
            .unwrap();
        assert_eq!(t.components[0].weight, 2.0);
        assert!(TopologyBuilder::new("t")
            .spout("s", "spout", 1.0)
            .input_weight("ghost", 2.0)
            .build()
            .is_err());
    }

    #[test]
    fn explicit_edge() {
        let t = TopologyBuilder::new("t")
            .spout("s", "spout", 1.0)
            .bolt("a", "lowCompute", 1.0, &["s"])
            .bolt("b", "lowCompute", 1.0, &["s"])
            .edge("a", "b")
            .build()
            .unwrap();
        assert_eq!(t.upstream(2).len(), 2);
    }
}
