"""L2 model tests: evaluate_placements vs the pure-jnp oracle, plus
semantic checks of feasibility/throughput on hand-built topologies."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile import dims
from compile.kernels.ref import evaluate_placements_ref
from compile.model import bolt_work, evaluate_placements

jax.config.update("jax_platform_name", "cpu")


def micro_linear(b=dims.B_BATCH, n_machines=3, r0=50.0, seed=0):
    """A Linear micro-benchmark-like problem padded to AOT dims.

    spout -> low -> mid -> high -> sink(low), on a 3-machine cluster with
    Table-3-like profile costs.
    """
    rng = np.random.default_rng(seed)
    C, M = dims.C, dims.M
    n_comp = 5
    adj = np.zeros((C, C), np.float32)
    for i in range(n_comp - 1):
        adj[i, i + 1] = 1.0
    alpha = np.zeros(C, np.float32)
    alpha[:n_comp] = 1.0
    src_mask = np.zeros(C, np.float32)
    src_mask[0] = 1.0
    active = np.zeros(C, np.float32)
    active[:n_comp] = 1.0

    # Table-3-like costs (%·s/tuple): spout cheap, low/mid/high per paper.
    cost = np.array([0.01, 0.0581, 0.103, 0.1915, 0.0581], np.float32)
    e_m = np.zeros((C, M), np.float32)
    met_m = np.zeros((C, M), np.float32)
    machine_scale = np.array([1.0, 1.8, 1.6], np.float32)  # M1 fastest, paper
    for c in range(n_comp):
        for m in range(n_machines):
            e_m[c, m] = cost[c] * machine_scale[m]
            met_m[c, m] = 2.0
    cap = np.zeros(M, np.float32)
    cap[:n_machines] = dims.CAP

    x = np.zeros((b, C, M), np.float32)
    for bi in range(b):
        for c in range(n_comp):
            x[bi, c, rng.integers(0, n_machines)] += 1.0
        # random extra instances
        for _ in range(int(rng.integers(0, 4))):
            x[bi, rng.integers(0, n_comp), rng.integers(0, n_machines)] += 1
    r0v = np.full(b, r0, np.float32)
    return (x, adj, alpha, src_mask, r0v, e_m, met_m, cap, active)


def as_jnp(args):
    return tuple(jnp.array(a) for a in args)


class TestEvaluatePlacements:
    def test_matches_ref(self):
        args = micro_linear()
        got = evaluate_placements(*as_jnp(args))
        want = evaluate_placements_ref(*args, depth=dims.DEPTH)
        for g, w, name in zip(got, want, ["util", "thpt", "feas", "ir"]):
            assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-4,
                            atol=1e-4, err_msg=name)

    def test_throughput_is_rate_sum(self):
        """Linear chain alpha=1: throughput == n_components * R0."""
        args = micro_linear(b=dims.B_BATCH, r0=10.0)
        _, thpt, _, ir = evaluate_placements(*as_jnp(args))
        assert_allclose(np.asarray(thpt), 5 * 10.0, rtol=1e-5)
        assert_allclose(np.asarray(ir)[:, :5], 10.0, rtol=1e-5)

    def test_infeasible_when_rate_huge(self):
        args = list(micro_linear(r0=1e6))
        _, _, feas, _ = evaluate_placements(*as_jnp(args))
        assert np.all(np.asarray(feas) == 0.0)

    def test_feasible_when_rate_tiny(self):
        args = list(micro_linear(r0=1.0))
        util, _, feas, _ = evaluate_placements(*as_jnp(args))
        assert np.all(np.asarray(feas) == 1.0)
        assert np.all(np.asarray(util) <= dims.CAP + 1e-5)

    def test_missing_instance_infeasible(self):
        args = list(micro_linear(b=dims.B_BATCH, r0=1.0))
        x = args[0].copy()
        x[:, 2, :] = 0.0   # drop all instances of component 2
        args[0] = x
        _, _, feas, _ = evaluate_placements(*as_jnp(args))
        assert np.all(np.asarray(feas) == 0.0)

    def test_more_instances_lower_util(self):
        """Adding an instance of the hottest component must not raise the
        max machine utilization (rate divides, eq. 6 share)."""
        args = list(micro_linear(b=dims.B_BATCH, r0=100.0, seed=7))
        x = args[0].copy()
        util1 = np.asarray(evaluate_placements(*as_jnp(args))[0])
        # duplicate the high-compute component (index 3) onto machine 2
        x2 = x.copy()
        x2[:, 3, 2] += 1.0
        args[0] = x2
        util2 = np.asarray(evaluate_placements(*as_jnp(args))[0])
        # total load can shift, but per-instance IR strictly drops; the
        # machines that hosted c3 see no increase from c3's share.
        n1 = x[:, 3, :].sum(1)
        n2 = x2[:, 3, :].sum(1)
        assert np.all(n2 == n1 + 1)
        # sanity: utilization stays finite and non-negative
        assert np.all(util2 >= -1e-6)

    @settings(deadline=None, max_examples=10)
    @given(seed=st.integers(0, 2**31 - 1),
           r0=st.floats(1.0, 500.0))
    def test_hypothesis_matches_ref(self, seed, r0):
        args = micro_linear(b=32, r0=np.float32(r0), seed=seed)
        got = evaluate_placements(*as_jnp(args))
        want = evaluate_placements_ref(*args, depth=dims.DEPTH)
        for g, w in zip(got, want):
            assert_allclose(np.asarray(g), np.asarray(w),
                            rtol=1e-3, atol=1e-3)


class TestBoltWork:
    def test_shape_and_finite(self):
        x = jnp.linspace(-1, 1, dims.WORK_N)
        (y,) = bolt_work(x)
        assert y.shape == (dims.WORK_N,)
        assert np.all(np.isfinite(np.asarray(y)))

    def test_deterministic(self):
        x = jnp.linspace(-2, 2, dims.WORK_N)
        (a,) = bolt_work(x)
        (b,) = bolt_work(x)
        assert_allclose(np.asarray(a), np.asarray(b))
