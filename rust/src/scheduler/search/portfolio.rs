//! Portfolio runner: branch-and-bound, beam and annealing racing under
//! one shared deterministic budget.
//!
//! Strategies run in a fixed order, each over a share of the remaining
//! budget and warm-started from the incumbent so far:
//!
//! 1. **bnb** — establishes the incumbent and the certificate; when it
//!    exhausts the space the portfolio stops (the incumbent is proven
//!    optimal, the remaining strategies cannot improve it — this is
//!    what makes the unlimited-budget portfolio bit-identical to
//!    `optimal`).
//! 2. **beam** — a bound-guided sweep that covers row combinations a
//!    truncated DFS never reaches.
//! 3. **anneal** — local refinement around the incumbent, spending
//!    whatever budget is left.
//!
//! Results merge through the exhaustive search's own fold predicates,
//! so a later strategy only replaces the incumbent when strictly
//! better under the request's objective.  The schedule's provenance
//! carries the certified `bound`/`optimality_gap` (incumbent vs. the
//! best surviving bound) and each strategy journals a
//! `strategy_finished` event plus a `search.<strategy>.wall_s` span.

use std::time::Instant;

use super::super::optimal::{no_best_error, seed_candidates, Best};
use super::super::{
    Problem, Provenance, Schedule, ScheduleRequest, Scheduler, SearchBudget, Termination,
};
use super::{
    anneal, beam, certify, global_bound, record_bound_pruned, record_search_started,
    repair_warm_start, singleton_order, walk, BudgetMeter, TableSet,
};
use crate::{Error, Result};

/// Budget shares per strategy (normalized at run time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyMix {
    pub bnb: f64,
    pub beam: f64,
    pub anneal: f64,
}

impl Default for StrategyMix {
    fn default() -> Self {
        StrategyMix { bnb: 0.5, beam: 0.25, anneal: 0.25 }
    }
}

/// Portfolio policy (`portfolio` in the registry).
#[derive(Debug, Clone)]
pub struct PortfolioScheduler {
    pub max_instances_per_component: usize,
    /// Space-size cap when no budget is set (same contract as `bnb`).
    pub enumeration_limit: u64,
    pub mix: StrategyMix,
    /// Beam width for the beam stage.
    pub width: usize,
    /// Annealing knobs for the refinement stage.
    pub restarts: usize,
    pub steps: usize,
    pub seed: u64,
    /// Default budget when the request leaves its budget unlimited.
    pub budget: SearchBudget,
}

impl Default for PortfolioScheduler {
    fn default() -> Self {
        PortfolioScheduler {
            max_instances_per_component: 3,
            enumeration_limit: 3_000_000,
            mix: StrategyMix::default(),
            width: 8,
            restarts: 4,
            steps: 400,
            seed: 0xA11E_A1,
            budget: SearchBudget::unlimited(),
        }
    }
}

/// Journal one strategy's contribution.
fn record_strategy(strategy: &str, rate: f64, evaluated: u64) {
    if crate::obs::enabled() {
        crate::obs::global().journal().record(crate::obs::Event::StrategyFinished {
            policy: "portfolio".into(),
            strategy: strategy.into(),
            rate,
            evaluated,
        });
    }
}

fn best_rate(best: &Option<Best>) -> f64 {
    best.as_ref().map_or(0.0, |b| b.rate)
}

impl Scheduler for PortfolioScheduler {
    fn name(&self) -> &'static str {
        "portfolio"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let started = Instant::now();
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        let n_comp = problem.topology().n_components();
        let n_m = problem.cluster().n_machines();
        record_search_started(self.name(), n_comp, n_m);

        let ts = TableSet::build(&ev, &rc, self.max_instances_per_component, n_comp, n_m);
        let budget = if req.budget.is_unlimited() { self.budget } else { req.budget };
        if budget.is_unlimited() && ts.size > self.enumeration_limit as u128 {
            return Err(Error::Schedule(format!(
                "design space has {} placements (> limit {}); set a search budget for anytime mode",
                ts.size, self.enumeration_limit
            )));
        }
        let ctx = ts.ctx(&ev, &rc, &req.objective);

        let mut best: Option<Best> = None;
        let mut evaluated: u64 = 0;
        seed_candidates(&ctx, problem, req, self.name(), &mut best, &mut evaluated);
        if let Some(warm) = &req.warm_start {
            if let Some(fixed) = repair_warm_start(&rc, warm, n_comp, n_m) {
                ctx.consider_seed(fixed, &mut best, &mut evaluated);
            }
        }

        let mut meter = BudgetMeter::new(&budget, n_m as u64);
        meter.charge_n(evaluated);
        let glob = global_bound(&ctx);
        let norm = (self.mix.bnb + self.mix.beam + self.mix.anneal).max(1e-12);
        let mut pruned: u64 = 0;
        let mut frontier = f64::NEG_INFINITY;
        let mut terminated = Termination::Budget;

        // ---- stage 1: branch-and-bound (incumbent + certificate) ----
        let reg = crate::obs::global();
        let bnb_out = {
            let _span = crate::obs::Span::start(reg.histogram("search.bnb.wall_s"));
            let mut sub = meter.share(self.mix.bnb / norm);
            let out = walk(&ctx, best.take(), glob, &mut sub, true);
            meter.absorb(&sub);
            out
        };
        best = bnb_out.best;
        evaluated += bnb_out.evaluated;
        pruned += bnb_out.pruned;
        frontier = frontier.max(bnb_out.frontier);
        record_bound_pruned(self.name(), bnb_out.bound_pruned);
        record_strategy("bnb", best_rate(&best), bnb_out.evaluated);

        let target_met = |best: &Option<Best>| {
            budget.target_gap.is_some_and(|t| {
                let r = best_rate(best);
                r > 0.0 && glob.is_finite() && (glob - r) / r <= t
            })
        };

        if bnb_out.terminated == Termination::Exhausted {
            // the space is proven: nothing left for beam/anneal to find
            terminated = Termination::Exhausted;
        } else if bnb_out.terminated == Termination::TargetGap || target_met(&best) {
            terminated = Termination::TargetGap;
        } else {
            // ---- stage 2: beam over the surviving budget ----
            let beam_share = self.mix.beam / (self.mix.beam + self.mix.anneal).max(1e-12);
            {
                let _span = crate::obs::Span::start(reg.histogram("search.beam.wall_s"));
                let orders = singleton_order(&ctx);
                let mut sub = meter.share(beam_share);
                let out = beam::run(&ctx, &orders, self.width, &mut best, &mut sub);
                meter.absorb(&sub);
                evaluated += out.evaluated;
                pruned += out.pruned;
                record_strategy("beam", best_rate(&best), out.evaluated);
            }
            if target_met(&best) {
                terminated = Termination::TargetGap;
            } else {
                // ---- stage 3: anneal around the incumbent ----
                let _span = crate::obs::Span::start(reg.histogram("search.anneal.wall_s"));
                let base = match &best {
                    Some(b) => b.placement.clone(),
                    None => anneal::base_placement(problem, req, &rc)?,
                };
                let mut sub = meter.share(1.0);
                let out = anneal::run(
                    &ev,
                    &rc,
                    &base,
                    self.max_instances_per_component,
                    self.restarts,
                    self.steps,
                    self.seed,
                    &mut sub,
                )?;
                meter.absorb(&sub);
                evaluated += out.evaluated;
                let anneal_rate = out.best.as_ref().map_or(0.0, |(_, r)| *r);
                if let Some((p, _)) = out.best {
                    // fold through the exhaustive predicates: replace
                    // only when strictly better under the objective
                    // (already counted as a probe — don't re-count)
                    let mut dup = 0u64;
                    ctx.consider_seed(p, &mut best, &mut dup);
                }
                record_strategy("anneal", anneal_rate, out.evaluated);
                if target_met(&best) {
                    terminated = Termination::TargetGap;
                }
            }
        }

        let best = best.ok_or_else(|| no_best_error(&req.objective))?;
        if best.rate <= 0.0 {
            return Err(Error::Schedule("no feasible placement found by the portfolio".into()));
        }
        let mut s = super::super::finish(&ev, best.placement)?;
        let (bound, gap) = certify(terminated, s.rate, frontier, glob);
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: "kernel".into(),
            wall: started.elapsed(),
            bound,
            optimality_gap: gap,
            terminated,
        };
        super::super::record_schedule_telemetry(&s, pruned);
        super::super::debug_validate(problem, req, &s);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::optimal::OptimalScheduler;
    use super::super::super::{Problem, ScheduleRequest};
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem(top: &crate::topology::Topology) -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(top, &cluster, &db).unwrap()
    }

    /// Unlimited budget ⇒ bnb exhausts ⇒ the portfolio is bit-identical
    /// to the exhaustive optimal, on every benchmark topology.
    #[test]
    fn bit_identical_to_optimal_when_unlimited() {
        for top in benchmarks::all() {
            let name = top.name.clone();
            let p = problem(&top);
            let req = ScheduleRequest::max_throughput();
            let opt = OptimalScheduler {
                max_instances_per_component: 2,
                threads: 1,
                ..Default::default()
            }
            .schedule(&p, &req)
            .unwrap();
            let pf = PortfolioScheduler {
                max_instances_per_component: 2,
                ..Default::default()
            }
            .schedule(&p, &req)
            .unwrap();
            assert_eq!(pf.placement.x, opt.placement.x, "{name}: placements diverge");
            assert_eq!(pf.rate.to_bits(), opt.rate.to_bits(), "{name}: rates diverge");
            assert_eq!(pf.provenance.terminated, Termination::Exhausted);
            assert_eq!(pf.provenance.optimality_gap, Some(0.0));
        }
    }

    /// Under a tight budget the portfolio still returns a feasible
    /// schedule with a certified gap.
    #[test]
    fn budgeted_portfolio_certifies_a_gap() {
        let p = problem(&benchmarks::linear());
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_max_candidates(200));
        let s = PortfolioScheduler::default().schedule(&p, &req).unwrap();
        assert!(s.rate > 0.0);
        assert!(s.provenance.placements_evaluated <= 200);
        let gap = s.provenance.optimality_gap.expect("budgeted run must certify a gap");
        assert!(gap >= 0.0);
        assert!(s.provenance.bound.unwrap() + 1e-9 >= s.rate);
    }

    /// The warm-start seed is honored: scheduling with the previous
    /// placement as warm start can only match or beat it.
    #[test]
    fn warm_start_never_regresses() {
        let p = problem(&benchmarks::linear());
        let first = PortfolioScheduler::default()
            .schedule(&p, &ScheduleRequest::max_throughput())
            .unwrap();
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_max_candidates(50))
            .with_warm_start(first.placement.clone());
        let s = PortfolioScheduler::default().schedule(&p, &req).unwrap();
        assert!(
            s.rate + 1e-9 >= first.rate,
            "warm-started portfolio regressed: {} < {}",
            s.rate,
            first.rate
        );
    }

    /// Determinism under a budget (the replay gate's property).
    #[test]
    fn budgeted_portfolio_is_deterministic() {
        let p = problem(&benchmarks::diamond());
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_max_candidates(500));
        let a = PortfolioScheduler::default().schedule(&p, &req).unwrap();
        let b = PortfolioScheduler::default().schedule(&p, &req).unwrap();
        assert_eq!(a.placement.x, b.placement.x);
        assert_eq!(a.rate.to_bits(), b.rate.to_bits());
        assert_eq!(a.provenance.placements_evaluated, b.provenance.placements_evaluated);
    }
}
