//! Ablations of the design choices DESIGN.md calls out:
//!
//! * **refine on/off** — the post-pass (prune + single-move hill climb)
//!   we added under the paper's §8 "scheduler efficiency" future work;
//! * **grouping: shuffle vs speed-weighted** — the paper names Storm's
//!   "simple grouping strategies" as the main obstacle to full
//!   utilization and proposes rate-weighted grouping as future work;
//!   here we evaluate the proposed schedule under both semantics;
//! * **heterogeneity-blindness** — the same algorithm fed a profile
//!   that averages the machine types (what a heterogeneity-unaware
//!   modeler would use), quantifying what the paper's core idea buys.

use crate::cluster::presets;
use crate::cluster::profile::{ProfileDb, TaskProfile};
use crate::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use crate::topology::benchmarks;
use crate::Result;

use super::{f1, pct, ExperimentResult};

/// Profile DB with every task's `e` replaced by its across-type mean —
/// the "heterogeneity-blind" modeler.
fn blind_profiles(db: &ProfileDb, types: &[&str], tasks: &[&str]) -> ProfileDb {
    let mut out = ProfileDb::new();
    for tt in tasks {
        let mut es = Vec::new();
        let mut mets = Vec::new();
        for mt in types {
            if let Ok(p) = db.get(tt, mt) {
                es.push(p.e);
                mets.push(p.met);
            }
        }
        let e = es.iter().sum::<f64>() / es.len().max(1) as f64;
        let met = mets.iter().sum::<f64>() / mets.len().max(1) as f64;
        for mt in types {
            out.insert(tt, mt, TaskProfile { e, met });
        }
    }
    out
}

pub fn run(_fast: bool) -> Result<ExperimentResult> {
    let (cluster, db) = presets::paper_cluster();
    let mut out = ExperimentResult::new(
        "ablation",
        "design-choice ablations (max stable throughput, tuples/s, model)",
        &["topology", "proposed", "no refine", "weighted grouping", "hetero-blind profile"],
    );
    let types = ["pentium", "core-i3", "core-i5"];
    let tasks = ["spout", "lowCompute", "midCompute", "highCompute"];
    let req = ScheduleRequest::max_throughput();
    let hetero = registry::create("hetero", &PolicyParams::default())?;
    let no_refine_sched =
        registry::create("hetero", &PolicyParams { refine: false, ..Default::default() })?;
    for top in benchmarks::micro() {
        let problem = Problem::new(&top, &cluster, &db)?;
        let ev = problem.evaluator();

        let full = hetero.schedule(&problem, &req)?;
        let no_refine = no_refine_sched.schedule(&problem, &req)?;

        // same placement, weighted-grouping semantics
        let weighted_rate = ev.max_stable_rate_weighted(&full.placement)?;
        let gain_sum: f64 = top.rate_gains()?.iter().sum();
        let weighted_thpt = weighted_rate * gain_sum;

        // schedule decided with a heterogeneity-blind profile, evaluated
        // against the true machine costs
        let blind_db = blind_profiles(&db, &types, &tasks);
        let blind_problem = Problem::new(&top, &cluster, &blind_db)?;
        let blind = hetero.schedule(&blind_problem, &req)?;
        let blind_true_rate = ev.max_stable_rate(&blind.placement)?;
        let blind_thpt = blind_true_rate.min(1e12) * gain_sum;

        out.row(vec![
            top.name.clone(),
            f1(full.eval.throughput),
            format!(
                "{} ({})",
                f1(no_refine.eval.throughput),
                pct((no_refine.eval.throughput - full.eval.throughput) / full.eval.throughput
                    * 100.0)
            ),
            format!(
                "{} ({})",
                f1(weighted_thpt),
                pct((weighted_thpt - full.eval.throughput) / full.eval.throughput * 100.0)
            ),
            format!(
                "{} ({})",
                f1(blind_thpt),
                pct((blind_thpt - full.eval.throughput) / full.eval.throughput * 100.0)
            ),
        ]);
    }
    out.note(
        "weighted grouping applies speed-proportional stream shares to the proposed \
         placement (paper §8 future work); it helps isolated instances and can hurt \
         co-located ones",
    );
    out.note(
        "hetero-blind: schedule computed from type-averaged profiles, evaluated on \
         true costs — what ignoring heterogeneity costs",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn ablation_rows_complete() {
        let r = super::run(true).unwrap();
        assert_eq!(r.rows.len(), 3);
        for row in &r.rows {
            let full: f64 = row[1].parse().unwrap();
            assert!(full > 0.0);
        }
    }

    #[test]
    fn refine_never_hurts() {
        let r = super::run(true).unwrap();
        for row in &r.rows {
            let full: f64 = row[1].parse().unwrap();
            let no_refine: f64 = row[2].split(' ').next().unwrap().parse().unwrap();
            assert!(full >= no_refine * 0.999, "{}: refine hurt", row[0]);
        }
    }

    #[test]
    fn blind_profile_never_helps() {
        let r = super::run(true).unwrap();
        for row in &r.rows {
            let full: f64 = row[1].parse().unwrap();
            let blind: f64 = row[4].split(' ').next().unwrap().parse().unwrap();
            assert!(blind <= full * 1.001, "{}: blind schedule beat informed one", row[0]);
        }
    }
}
