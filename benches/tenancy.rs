//! Multi-tenant scheduling comparison (joint vs incremental admission
//! vs isolated partitions) in full mode: `cargo bench --bench tenancy`.

fn main() {
    let r = hstorm::experiments::tenancy::run(false).expect("tenancy experiment");
    println!("{}", r.render());
}
