//! Pre-process profiling (paper §5.2): recover `e_ij` and `MET_ij` from
//! engine measurements.
//!
//! The paper profiles every task type on every machine type by raising
//! the input rate until the CPU saturates, then reads the average tuple
//! execution time (`get_execute_ms_avg()`) and inverts eq. 5 for MET.
//! This module reproduces that procedure against the stream engine: a
//! probe topology (spout → probe bolt, both pinned to the target
//! machine... spout on a helper machine so only the probe loads the
//! target) is driven at increasing rates; at the highest stable rate we
//! measure the service time and utilization and solve
//!
//!   `MET = TCU_measured - e_measured * IR`.
//!
//! Tests validate that the recovered profile matches the profile the
//! engine was configured with — the same self-consistency the paper's
//! 92% prediction accuracy demonstrates.


use crate::cluster::profile::{ProfileDb, TaskProfile};
use crate::cluster::Cluster;
use crate::engine::{self, EngineConfig};
use crate::predict::Placement;
use crate::topology::builder::TopologyBuilder;
use crate::topology::Topology;
use crate::{Error, Result};

/// One profiling measurement point.
#[derive(Debug, Clone)]
pub struct ProbePoint {
    pub rate: f64,
    pub util: f64,
    /// Measured mean service time, profile units (%·s/tuple after x100).
    pub service_e: Option<f64>,
}

/// Result of profiling one (task_type, machine_type) pair.
#[derive(Debug, Clone)]
pub struct ProfiledTask {
    pub task_type: String,
    pub machine_type: String,
    pub measured: TaskProfile,
    /// The rate sweep that produced it.
    pub sweep: Vec<ProbePoint>,
}

/// A probe topology: helper spout feeding one probe bolt.
fn probe_topology(task_type: &str) -> Topology {
    TopologyBuilder::new("probe")
        .spout("probe-spout", "spout", 1.0)
        .bolt("probe", task_type, 1.0, &["probe-spout"])
        .build()
        .expect("probe topology is valid")
}

/// A probe cluster: the target machine plus a helper that hosts the
/// spout (so the target machine's utilization is the probe bolt alone).
fn probe_cluster(cluster: &Cluster, machine_type: &str) -> Result<Cluster> {
    let tid = cluster
        .types
        .iter()
        .position(|t| t.name == machine_type)
        .ok_or_else(|| Error::Cluster(format!("unknown machine type '{machine_type}'")))?;
    let mut probe = Cluster::new(format!("probe-{machine_type}"));
    let target = probe.add_type(machine_type, &cluster.types[tid].description);
    let helper = probe.add_type("probe-helper", "synthetic spout host");
    probe.add_machines(target, 1, "target");
    probe.add_machines(helper, 1, "helper");
    Ok(probe)
}

/// Profile `task_type` on `machine_type`, sweeping the input rate until
/// the target machine saturates (the paper's procedure).
///
/// `truth` supplies the engine's ground-truth costs (in production this
/// is the real hardware); the returned profile is what the *measurement*
/// recovered and is what schedulers should be fed.
pub fn profile_task(
    cluster: &Cluster,
    truth: &ProfileDb,
    task_type: &str,
    machine_type: &str,
    cfg: &EngineConfig,
) -> Result<ProfiledTask> {
    let top = probe_topology(task_type);
    let probe = probe_cluster(cluster, machine_type)?;

    // engine truth for the probe cluster: target type from `truth`,
    // helper is a free spout host
    let mut db = ProfileDb::new();
    let spout_p = truth.get("spout", machine_type).unwrap_or(TaskProfile { e: 0.004, met: 1.0 });
    db.insert("spout", "probe-helper", spout_p);
    db.insert("spout", machine_type, spout_p);
    db.insert(task_type, machine_type, truth.get(task_type, machine_type)?);
    // bolt never runs on the helper, but coverage checks need a row
    db.insert(task_type, "probe-helper", truth.get(task_type, machine_type)?);

    // placement: spout on helper (machine 1), probe bolt on target (0)
    let mut placement = Placement::empty(2, 2);
    placement.x[0][1] = 1;
    placement.x[1][0] = 1;

    // saturation rate from the truth (the profiler would discover this by
    // sweeping; we sweep a few points up to just past it)
    let p = truth.get(task_type, machine_type)?;
    let sat = (100.0 - p.met) / p.e;
    let rates = [0.25 * sat, 0.5 * sat, 0.75 * sat, 0.95 * sat];

    let mut sweep = Vec::new();
    let mut best: Option<(f64, f64, f64)> = None; // (rate, util, e_measured)
    for &rate in &rates {
        let rep = engine::run(&top, &probe, &db, &placement, rate, cfg)?;
        let util = rep.util[0];
        let service_e = rep.service[1][0].map(|s| s * 100.0); // s/budget -> %·s
        sweep.push(ProbePoint { rate, util, service_e });
        if let Some(e) = service_e {
            // prefer the highest rate that did not shed (paper: measure at
            // the maximum utilization point)
            if rep.shed == 0 {
                best = Some((rep.comp_rate[1], util, e));
            }
        }
    }
    let (rate, util, e_meas) =
        best.ok_or_else(|| Error::Engine("probe never produced service samples".into()))?;
    let met = (util - e_meas * rate).max(0.0);
    Ok(ProfiledTask {
        task_type: task_type.to_string(),
        machine_type: machine_type.to_string(),
        measured: TaskProfile { e: e_meas, met },
        sweep,
    })
}

/// Profile every `(task_type, machine_type)` combination a topology
/// needs on a cluster — the full pre-process step.  Returns a DB usable
/// by the schedulers.
pub fn profile_all(
    top: &Topology,
    cluster: &Cluster,
    truth: &ProfileDb,
    cfg: &EngineConfig,
) -> Result<ProfileDb> {
    let mut types: Vec<&str> = top.components.iter().map(|c| c.task_type.as_str()).collect();
    types.sort_unstable();
    types.dedup();
    let mut machine_types: Vec<&str> = cluster.types.iter().map(|t| t.name.as_str()).collect();
    machine_types.sort_unstable();
    machine_types.dedup();

    let mut db = ProfileDb::new();
    for tt in &types {
        for mt in &machine_types {
            if *tt == "spout" {
                // spouts are too cheap to saturate a machine; carry the
                // truth value through (the paper profiles bolts)
                db.insert(tt, mt, truth.get(tt, mt)?);
                continue;
            }
            let prof = profile_task(cluster, truth, tt, mt, cfg)?;
            db.insert(tt, mt, prof.measured);
        }
    }
    Ok(db)
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::cluster::presets;

    fn quick_cfg() -> EngineConfig {
        EngineConfig {
            duration: Duration::from_millis(700),
            warmup: Duration::from_millis(250),
            time_scale: 0.2,
            ..Default::default()
        }
    }

    #[test]
    fn probe_topology_valid() {
        probe_topology("highCompute").validate().unwrap();
    }

    #[test]
    fn probe_cluster_isolates_target() {
        let (cluster, _) = presets::paper_cluster();
        let probe = probe_cluster(&cluster, "core-i5").unwrap();
        assert_eq!(probe.n_machines(), 2);
        assert_eq!(probe.type_name(0), "core-i5");
    }

    #[test]
    fn unknown_machine_type_rejected() {
        let (cluster, _) = presets::paper_cluster();
        assert!(probe_cluster(&cluster, "quantum").is_err());
    }

    #[test]
    fn recovers_e_within_tolerance() {
        let (cluster, truth) = presets::paper_cluster();
        let prof =
            profile_task(&cluster, &truth, "highCompute", "pentium", &quick_cfg()).unwrap();
        let want = truth.get("highCompute", "pentium").unwrap();
        let rel = (prof.measured.e - want.e).abs() / want.e;
        assert!(
            rel < 0.2,
            "recovered e={} truth e={} (rel {rel})",
            prof.measured.e,
            want.e
        );
        // MET recovered within a few percent points
        assert!(
            (prof.measured.met - want.met).abs() < 6.0,
            "met {} vs {}",
            prof.measured.met,
            want.met
        );
    }

    #[test]
    fn sweep_utilization_increases() {
        let (cluster, truth) = presets::paper_cluster();
        let prof = profile_task(&cluster, &truth, "midCompute", "core-i3", &quick_cfg()).unwrap();
        let utils: Vec<f64> = prof.sweep.iter().map(|p| p.util).collect();
        assert!(utils.windows(2).all(|w| w[1] > w[0] - 8.0), "sweep {utils:?}");
        assert!(utils.last().unwrap() > &50.0);
    }
}
