//! Fig. 9: per-worker-node CPU utilization under each scheduler for the
//! Micro-Benchmark topologies (engine-measured).
//!
//! The paper's reading: the optimal scheduler has the highest total
//! utilization; the proposed scheduler uses the most powerful processors
//! better than the default scheduler even where its *total* usage is
//! lower (the Star case).

use crate::cluster::presets;
use crate::engine::{self, EngineConfig};
use crate::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use crate::topology::benchmarks;
use crate::Result;

use super::{f1, ExperimentResult};

pub fn run(fast: bool) -> Result<ExperimentResult> {
    let (cluster, db) = presets::paper_cluster();
    let cfg = if fast {
        EngineConfig {
            duration: std::time::Duration::from_millis(600),
            warmup: std::time::Duration::from_millis(250),
            time_scale: 0.15,
            ..Default::default()
        }
    } else {
        EngineConfig::default()
    };
    let machine_names: Vec<String> = cluster.machines.iter().map(|m| m.name.clone()).collect();
    let mut headers: Vec<&str> = vec!["topology", "scheduler"];
    let name_refs: Vec<&str> = machine_names.iter().map(|s| s.as_str()).collect();
    headers.extend(name_refs.iter());
    headers.push("total");
    let mut out = ExperimentResult::new(
        "fig9",
        "measured per-node CPU utilization by scheduler (%)",
        &headers,
    );

    let params = PolicyParams {
        max_instances_per_component: if fast { 2 } else { 3 },
        ..Default::default()
    };
    let req = ScheduleRequest::max_throughput();
    for top in benchmarks::micro() {
        let problem = Problem::new(&top, &cluster, &db)?;
        let ours = registry::create("hetero", &params)?.schedule(&problem, &req)?;
        let def = registry::create("default", &params)?.schedule(&problem, &req)?;
        let opt = registry::create("optimal", &params)?.schedule(&problem, &req)?;
        for (name, s) in [("default", &def), ("proposed", &ours), ("optimal", &opt)] {
            let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate, &cfg)?;
            let mut row = vec![top.name.clone(), name.to_string()];
            row.extend(rep.util.iter().map(|u| f1(*u)));
            row.push(f1(rep.util.iter().sum::<f64>()));
            out.row(row);
        }
    }
    out.note(
        "paper: optimal has the highest total utilization; proposed exploits the \
         strongest CPU better than default",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn utilization_rows_complete_and_bounded() {
        let r = super::run(true).unwrap();
        assert_eq!(r.rows.len(), 9); // 3 topologies x 3 schedulers
        for row in &r.rows {
            for cell in &row[2..5] {
                let u: f64 = cell.parse().unwrap();
                assert!((0.0..=115.0).contains(&u), "util {u} out of range in {row:?}");
            }
        }
    }

    #[test]
    fn proposed_total_util_at_least_default_somewhere() {
        let r = super::run(true).unwrap();
        // paper: for Linear and Diamond the proposed scheduler uses more
        // CPU than default; check it wins on total for >= 1 topology
        let mut wins = 0;
        for chunk in r.rows.chunks(3) {
            let def_total: f64 = chunk[0].last().unwrap().parse().unwrap();
            let ours_total: f64 = chunk[1].last().unwrap().parse().unwrap();
            if ours_total >= def_total {
                wins += 1;
            }
        }
        assert!(wins >= 1, "proposed never out-utilized default");
    }
}
