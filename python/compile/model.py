"""L2: the scheduler's evaluation model as a JAX compute graph.

Composes the two L1 Pallas kernels into the full placement evaluator the
Rust coordinator calls through PJRT:

  1. rate propagation (eq. 6)   — kernels.propagate, iterated DEPTH times;
  2. CPU-utilization prediction (eq. 5) summed per machine
                                 — kernels.score;
  3. feasibility + throughput reduction (the objective of eq. 2).

All shapes are the fixed AOT dims from ``dims.py``; padding rows/columns
are masked with ``active``/zero instance counts.  ``aot.py`` lowers
``evaluate_placements`` to HLO text once at build time.
"""

import functools

import jax
import jax.numpy as jnp

from .dims import DEPTH
from .kernels.propagate import propagate_step
from .kernels.score import score_utilization


def propagate(adj, alpha, src, *, depth=DEPTH, interpret=True):
    """Iterate the eq.-6 step to the DAG fixed point.

    ``src[b, c]`` is R0 injected at spouts; a DAG with a longest path of L
    edges converges after L iterations, and extra iterations are no-ops, so
    a static ``depth >= L`` is exact (not approximate).

    The loop is unrolled at trace time (not ``lax.fori_loop``): an HLO
    ``while`` op blocks XLA from fusing the tiny per-step matmuls and
    costs a dispatch per iteration on the CPU PJRT runtime; unrolling cut
    the Rust-side batch-scoring latency (see EXPERIMENTS.md §Perf).
    """
    ir = src
    for _ in range(depth):
        ir = propagate_step(ir, adj, alpha, src, interpret=interpret)
    return ir


@functools.partial(jax.jit, static_argnames=("depth", "interpret"))
def evaluate_placements(x, adj, alpha, src_mask, r0, e_m, met_m, cap, active,
                        *, depth=DEPTH, interpret=True):
    """Score a batch of candidate placements.

    Args:
      x:        f32[B, C, M] instances of component c on machine m.
      adj:      f32[C, C]    adj[i, j] = 1 iff component i feeds j.
      alpha:    f32[C]       tuple division ratio per component (eq. 6).
      src_mask: f32[C]       input-rate weight at spout components
                             (1.0 classically; multi-tenant merges scale a
                             tenant's spouts by its rate-weight), 0 elsewhere.
      r0:       f32[B]       topology input rate per candidate.
      e_m:      f32[C, M]    per-tuple cost of c on machine m (%·s/tuple).
      met_m:    f32[C, M]    per-instance overhead of c on machine m (%).
      cap:      f32[M]       MAC budget per machine (100 active, 0 pad).
      active:   f32[C]       1.0 for real components, 0.0 padding.

    Returns:
      util:       f32[B, M] predicted machine utilization (eq. 5 summed).
      throughput: f32[B]    sum of component processing rates (objective).
      feasible:   f32[B]    1.0 iff no machine over-utilized and every
                            active component has >= 1 instance.
      ir_comp:    f32[B, C] component-level input rates (eq. 6 fixed point).
    """
    n_c = jnp.sum(x, axis=2)                        # [B, C]
    src = src_mask[None, :] * r0[:, None]           # [B, C]
    ir_comp = propagate(adj, alpha, src, depth=depth, interpret=interpret)
    # Shuffle grouping: a component's stream divides evenly over instances.
    ir_task = ir_comp / jnp.maximum(n_c, 1.0)
    util = score_utilization(x, ir_task, e_m, met_m, interpret=interpret)
    over = jnp.any(util > cap[None, :] + 1e-6, axis=1)
    missing = jnp.any((n_c < 0.5) & (active[None, :] > 0.5), axis=1)
    feasible = jnp.logical_and(~over, ~missing).astype(x.dtype)
    throughput = jnp.sum(ir_comp * active[None, :], axis=1)
    return util, throughput, feasible, ir_comp


def bolt_work(x, iters=8):
    """Synthetic CPU-burning bolt body for the engine's PJRT compute mode.

    A short chain of transcendental ops over a small vector; the Rust
    engine executes the compiled module k times per tuple, k scaled by the
    component's profiled cost, so 'real' compute flows through PJRT on the
    data path without Python.
    """

    def body(_, v):
        return jnp.tanh(v) * 1.000001 + jnp.sin(v) * 1e-3

    return (jax.lax.fori_loop(0, iters, body, x),)
