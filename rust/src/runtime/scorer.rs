//! Batched placement scoring through the AOT model.
//!
//! [`ScorerProblem`] pads one (topology, cluster, profiles) triple to the
//! AOT dims; `PjRtScorer` (behind the `pjrt` cargo feature) runs
//! candidate batches through the compiled HLO (L2 model + L1 Pallas
//! kernels); [`NativeScorer`] is the exact Rust mirror used as a fallback
//! for clusters larger than `MAX_MACHINES`, as the cross-check oracle in
//! integration tests, and as the only backend of non-`pjrt` builds.
//!
//! Both implement [`PlacementScorer`], so the schedulers are agnostic.

use super::dims::{MAX_COMPONENTS, MAX_MACHINES};
#[cfg(feature = "pjrt")]
use super::dims::{B_BATCH, B_ONE};
#[cfg(feature = "pjrt")]
use super::{literal_f32, PjRtRuntime};
use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::kernel;
use crate::predict::{Evaluator, Placement};
use crate::topology::Topology;
use crate::{Error, Result};

/// One scored candidate.
#[derive(Debug, Clone)]
pub struct ScoreRow {
    /// Predicted utilization per (real, unpadded) machine, percent.
    pub util: Vec<f64>,
    /// Overall throughput at the candidate's rate, tuples/s.
    pub throughput: f64,
    pub feasible: bool,
    /// Component-level input rates (real components only), tuples/s.
    pub ir_comp: Vec<f64>,
}

/// A problem instance padded to the AOT dims.
// The padded tables are only read by the feature-gated `PjRtScorer`;
// derives stopped counting as field reads for dead_code long ago.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
#[derive(Debug, Clone)]
pub struct ScorerProblem {
    pub n_comp: usize,
    pub n_machines: usize,
    adj: Vec<f64>,      // [C, C] row-major
    alpha: Vec<f64>,    // [C]
    src_mask: Vec<f64>, // [C]
    e_m: Vec<f64>,      // [C, M]
    met_m: Vec<f64>,    // [C, M]
    cap: Vec<f64>,      // [M]
    active: Vec<f64>,   // [C]
}

impl ScorerProblem {
    pub fn new(top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Self> {
        top.validate()?;
        cluster.validate()?;
        let n = top.n_components();
        let m = cluster.n_machines();
        if n > MAX_COMPONENTS {
            return Err(Error::Runtime(format!(
                "{n} components exceed AOT max {MAX_COMPONENTS}"
            )));
        }
        if m > MAX_MACHINES {
            return Err(Error::Runtime(format!(
                "{m} machines exceed AOT max {MAX_MACHINES}; use NativeScorer"
            )));
        }
        if top.longest_path()? >= super::dims::DEPTH {
            return Err(Error::Runtime("topology deeper than AOT DEPTH".into()));
        }
        let (e_exp, met_exp) = profiles.expand(top, cluster)?;
        let c_pad = MAX_COMPONENTS;
        let m_pad = MAX_MACHINES;
        let mut adj = vec![0.0; c_pad * c_pad];
        for &(a, b) in &top.edges {
            adj[a * c_pad + b] = 1.0;
        }
        let mut alpha = vec![0.0; c_pad];
        let mut src_mask = vec![0.0; c_pad];
        let mut active = vec![0.0; c_pad];
        for (i, comp) in top.components.iter().enumerate() {
            alpha[i] = comp.alpha;
            active[i] = 1.0;
            if comp.kind == crate::topology::ComponentKind::Spout {
                // the model seeds spout rates as `src_mask * R0`, so the
                // input-rate weight rides in the mask (1.0 classically)
                src_mask[i] = comp.weight;
            }
        }
        let mut e_m = vec![0.0; c_pad * m_pad];
        let mut met_m = vec![0.0; c_pad * m_pad];
        for c in 0..n {
            for mm in 0..m {
                e_m[c * m_pad + mm] = e_exp[c][mm];
                met_m[c * m_pad + mm] = met_exp[c][mm];
            }
        }
        let mut cap = vec![0.0; m_pad];
        for (mm, mach) in cluster.machines.iter().enumerate() {
            cap[mm] = mach.cap;
        }
        Ok(ScorerProblem {
            n_comp: n,
            n_machines: m,
            adj,
            alpha,
            src_mask,
            e_m,
            met_m,
            cap,
            active,
        })
    }

    /// Flatten a placement into a padded `[C, M]` f32 block (written into
    /// the caller's batch buffer — no per-candidate allocation).
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn pad_placement_into(&self, p: &Placement, out: &mut [f32]) -> Result<()> {
        if p.n_components() != self.n_comp || p.n_machines() != self.n_machines {
            return Err(Error::Runtime(format!(
                "placement {}x{} != problem {}x{}",
                p.n_components(),
                p.n_machines(),
                self.n_comp,
                self.n_machines
            )));
        }
        for c in 0..self.n_comp {
            for m in 0..self.n_machines {
                out[c * MAX_MACHINES + m] = p.x[c][m] as f32;
            }
        }
        Ok(())
    }
}

/// The scheduler-facing scoring interface.
pub trait PlacementScorer {
    /// Score `candidates[i]` at input rate `r0s[i]`.
    fn score_batch(&self, candidates: &[Placement], r0s: &[f64]) -> Result<Vec<ScoreRow>>;

    /// Convenience single-candidate call.
    fn score_one(&self, p: &Placement, r0: f64) -> Result<ScoreRow> {
        let mut rows = self.score_batch(std::slice::from_ref(p), &[r0])?;
        Ok(rows.remove(0))
    }

    /// Human-readable backend name ("pjrt" / "native").
    fn backend(&self) -> &'static str;
}

/// PJRT-backed scorer: executes the AOT model (`scorer_b256` for full
/// batches, `scorer_b1` for single candidates).
#[cfg(feature = "pjrt")]
pub struct PjRtScorer {
    problem: ScorerProblem,
    exe_batch: super::Executable,
    exe_one: super::Executable,
    /// Placement-independent input literals (adj, alpha, src_mask, e_m,
    /// met_m, cap, active), shaped once and reused every call.
    statics: Vec<xla::Literal>,
}

#[cfg(feature = "pjrt")]
impl PjRtScorer {
    pub fn new(
        rt: &PjRtRuntime,
        top: &Topology,
        cluster: &Cluster,
        profiles: &ProfileDb,
    ) -> Result<Self> {
        let problem = ScorerProblem::new(top, cluster, profiles)?;
        let exe_batch = rt.load(&format!("scorer_b{B_BATCH}.hlo.txt"))?;
        let exe_one = rt.load(&format!("scorer_b{B_ONE}.hlo.txt"))?;
        // Static (placement-independent) input literals, built once.
        let statics = vec![
            literal_f32(&problem.adj, &[MAX_COMPONENTS as i64, MAX_COMPONENTS as i64])?,
            literal_f32(&problem.alpha, &[MAX_COMPONENTS as i64])?,
            literal_f32(&problem.src_mask, &[MAX_COMPONENTS as i64])?,
            literal_f32(&problem.e_m, &[MAX_COMPONENTS as i64, MAX_MACHINES as i64])?,
            literal_f32(&problem.met_m, &[MAX_COMPONENTS as i64, MAX_MACHINES as i64])?,
            literal_f32(&problem.cap, &[MAX_MACHINES as i64])?,
            literal_f32(&problem.active, &[MAX_COMPONENTS as i64])?,
        ];
        Ok(PjRtScorer { problem, exe_batch, exe_one, statics })
    }

    pub fn problem(&self) -> &ScorerProblem {
        &self.problem
    }

    /// Run one padded chunk (`xs.len() <= b`) through an executable.
    ///
    /// §Perf: the seven placement-independent input literals are built
    /// once at construction and passed by reference; only the `x` and
    /// `r0` literals are created per call, from f32 buffers filled in
    /// place.
    fn run_chunk(
        &self,
        exe: &super::Executable,
        statics: &[xla::Literal],
        b: usize,
        xs: &[&Placement],
        r0s: &[f64],
    ) -> Result<Vec<ScoreRow>> {
        let cm = MAX_COMPONENTS * MAX_MACHINES;
        let mut x_flat = vec![0.0f32; b * cm];
        let mut r0_flat = vec![0.0f32; b];
        for (i, p) in xs.iter().enumerate() {
            self.problem.pad_placement_into(p, &mut x_flat[i * cm..(i + 1) * cm])?;
            r0_flat[i] = r0s[i] as f32;
        }
        let x_lit = xla::Literal::vec1(&x_flat)
            .reshape(&[b as i64, MAX_COMPONENTS as i64, MAX_MACHINES as i64])
            .map_err(|e| Error::Runtime(e.to_string()))?;
        let r0_lit = xla::Literal::vec1(&r0_flat);
        // Input order must match aot.py's lower_scorer signature:
        // (x, adj, alpha, src_mask, r0, e_m, met_m, cap, active)
        let args: Vec<&xla::Literal> = vec![
            &x_lit,
            &statics[0],
            &statics[1],
            &statics[2],
            &r0_lit,
            &statics[3],
            &statics[4],
            &statics[5],
            &statics[6],
        ];
        let out = exe.run_refs(&args)?;
        if out.len() != 4 {
            return Err(Error::Runtime(format!("scorer returned {} outputs, want 4", out.len())));
        }
        let util: Vec<f32> = out[0].to_vec().map_err(|e| Error::Runtime(e.to_string()))?;
        let thpt: Vec<f32> = out[1].to_vec().map_err(|e| Error::Runtime(e.to_string()))?;
        let feas: Vec<f32> = out[2].to_vec().map_err(|e| Error::Runtime(e.to_string()))?;
        let ir: Vec<f32> = out[3].to_vec().map_err(|e| Error::Runtime(e.to_string()))?;
        let mut rows = Vec::with_capacity(xs.len());
        for i in 0..xs.len() {
            rows.push(ScoreRow {
                util: (0..self.problem.n_machines)
                    .map(|m| util[i * MAX_MACHINES + m] as f64)
                    .collect(),
                throughput: thpt[i] as f64,
                feasible: feas[i] > 0.5,
                ir_comp: (0..self.problem.n_comp)
                    .map(|c| ir[i * MAX_COMPONENTS + c] as f64)
                    .collect(),
            });
        }
        Ok(rows)
    }
}

#[cfg(feature = "pjrt")]
impl PlacementScorer for PjRtScorer {
    fn score_batch(&self, candidates: &[Placement], r0s: &[f64]) -> Result<Vec<ScoreRow>> {
        if candidates.len() != r0s.len() {
            return Err(Error::Runtime("candidates/r0s length mismatch".into()));
        }
        let mut rows = Vec::with_capacity(candidates.len());
        let mut i = 0;
        while i < candidates.len() {
            let remaining = candidates.len() - i;
            if remaining == 1 {
                let refs = [&candidates[i]];
                let chunk =
                    self.run_chunk(&self.exe_one, &self.statics, B_ONE, &refs, &r0s[i..i + 1])?;
                rows.extend(chunk);
                i += 1;
            } else {
                let take = remaining.min(B_BATCH);
                let refs: Vec<&Placement> = candidates[i..i + take].iter().collect();
                let chunk = self.run_chunk(
                    &self.exe_batch,
                    &self.statics,
                    B_BATCH,
                    &refs,
                    &r0s[i..i + take],
                )?;
                rows.extend(chunk);
                i += take;
            }
        }
        Ok(rows)
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

/// Exact native mirror (used beyond AOT dims and as the test oracle).
pub struct NativeScorer {
    ev: Evaluator,
}

impl NativeScorer {
    pub fn new(top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Self> {
        Ok(NativeScorer { ev: Evaluator::new(top, cluster, profiles)? })
    }

    /// Wrap an already-built (possibly capacity-adjusted) evaluator —
    /// the path [`crate::scheduler::Problem`] uses so constrained
    /// requests score against headroom-reduced budgets without
    /// re-expanding profiles.
    pub fn from_evaluator(ev: Evaluator) -> Self {
        NativeScorer { ev }
    }

    pub fn evaluator(&self) -> &Evaluator {
        &self.ev
    }
}

impl PlacementScorer for NativeScorer {
    /// Batch evaluation over the kernel's shared tables: one `counts`
    /// scratch serves the whole batch
    /// ([`crate::predict::kernel::evaluate_with_scratch`] is
    /// arithmetic-identical to [`Evaluator::evaluate`], so this stays the
    /// exact oracle).
    fn score_batch(&self, candidates: &[Placement], r0s: &[f64]) -> Result<Vec<ScoreRow>> {
        if candidates.len() != r0s.len() {
            return Err(Error::Runtime("candidates/r0s length mismatch".into()));
        }
        let mut counts = Vec::with_capacity(self.ev.n_components());
        candidates
            .iter()
            .zip(r0s)
            .map(|(p, &r0)| {
                let e = kernel::evaluate_with_scratch(&self.ev, p, r0, &mut counts)?;
                Ok(ScoreRow {
                    util: e.util,
                    throughput: e.throughput,
                    feasible: e.feasible,
                    ir_comp: e.ir_comp,
                })
            })
            .collect()
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    #[test]
    fn problem_padding_shapes() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = ScorerProblem::new(&top, &cluster, &db).unwrap();
        assert_eq!(p.adj.len(), MAX_COMPONENTS * MAX_COMPONENTS);
        assert_eq!(p.e_m.len(), MAX_COMPONENTS * MAX_MACHINES);
        assert_eq!(p.cap[0], 100.0);
        assert_eq!(p.cap[cluster.n_machines()], 0.0); // padding
        assert_eq!(p.active.iter().sum::<f64>() as usize, top.n_components());
    }

    #[test]
    fn native_scorer_matches_evaluator() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::diamond();
        let sc = NativeScorer::new(&top, &cluster, &db).unwrap();
        let ev = Evaluator::new(&top, &cluster, &db).unwrap();
        let mut p = Placement::empty(top.n_components(), cluster.n_machines());
        for c in 0..top.n_components() {
            p.x[c][c % 3] = 1;
        }
        let row = sc.score_one(&p, 20.0).unwrap();
        let want = ev.evaluate(&p, 20.0).unwrap();
        assert_eq!(row.feasible, want.feasible);
        assert!((row.throughput - want.throughput).abs() < 1e-9);
        for (a, b) in row.util.iter().zip(&want.util) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn oversize_cluster_rejected() {
        let (cluster, db) = presets::homogeneous_cluster(MAX_MACHINES + 1);
        let top = benchmarks::linear();
        assert!(ScorerProblem::new(&top, &cluster, &db).is_err());
    }
}
