//! Fleet-scale control-plane experiment: decision latency and schedule
//! quality of the dirty-tenant incremental re-planner vs the
//! full-re-plan baseline, at 500–5000 machines and 50–200 tenants.
//!
//! Each configuration replays the same storm trace
//! ([`crate::controller::traces::fleet_storm`] — correlated rack
//! outages, a flapping machine, trace-driven autoscaling) under both
//! [`FleetMode`]s and reports per-step decision-latency percentiles
//! (milliseconds, from the run-local step histogram) plus the weighted
//! delivered-throughput gap.  The two headlines the CI pipeline greps,
//! gated on the 1000-machine / 100-tenant configuration:
//!
//! * `p99 step latency < 10ms at 1000 machines : PASS`
//! * `incremental within 5% of full re-plan throughput : PASS`
//!
//! Latency percentiles are wall-clock and vary run to run; everything
//! else in the table is deterministic in the seed.  Sub-1000-machine
//! configurations additionally run with per-step invariant auditing
//! ([`crate::check::validate_fleet`]) enabled; auditing is kept off the
//! gated configuration because the placement snapshots land inside the
//! measured step.

use crate::controller::fleet::{quality_gap_pct, run_fleet, FleetMode, FleetReport, FleetSpec};
use crate::controller::ControllerConfig;
use crate::scheduler::SearchBudget;
use crate::util::json::{self, Value};
use crate::Result;

use super::{f1, f2, ExperimentResult};

/// Headline latency budget, milliseconds.
const P99_BUDGET_MS: f64 = 10.0;
/// Headline quality budget: max weighted-throughput loss vs full
/// re-plans, percent.
const GAP_BUDGET_PCT: f64 = 5.0;
/// The configuration both headline gates are evaluated on.
const GATE_MACHINES: usize = 1000;

struct Case {
    machines: usize,
    tenants: usize,
    steps: usize,
    /// Run the full-re-plan comparator too (skipped for the largest
    /// fleets, where from-scratch-every-step is the cost being avoided).
    compare: bool,
    /// Audit every step with the fleet invariants.
    verify: bool,
}

fn cases(fast: bool) -> Vec<Case> {
    let steps = if fast { 40 } else { 120 };
    let mut out = vec![
        Case { machines: 500, tenants: 50, steps, compare: true, verify: true },
        Case { machines: GATE_MACHINES, tenants: 100, steps, compare: true, verify: false },
    ];
    if !fast {
        out.push(Case { machines: 2000, tenants: 150, steps: 60, compare: false, verify: false });
        out.push(Case { machines: 5000, tenants: 200, steps: 30, compare: false, verify: false });
    }
    out
}

/// Controller tuning for the incremental mode: a deterministic search
/// budget per re-plan and a per-step migration cap (the full-re-plan
/// comparator ignores both by construction).
fn fleet_cfg() -> ControllerConfig {
    ControllerConfig {
        replan_budget: SearchBudget::unlimited()
            .with_max_candidates(512)
            .with_max_virtual_ops(2_000_000),
        max_moves_per_step: 2000,
        ..Default::default()
    }
}

fn report_row(c: &Case, r: &FleetReport, gap: Option<f64>) -> Vec<String> {
    vec![
        c.machines.to_string(),
        c.tenants.to_string(),
        c.steps.to_string(),
        r.mode.to_string(),
        r.events.to_string(),
        r.replans.to_string(),
        r.deferred.to_string(),
        r.tasks_moved.to_string(),
        format!("{:.3}", r.p50_ms),
        format!("{:.3}", r.p95_ms),
        format!("{:.3}", r.p99_ms),
        f1(r.delivered_pct()),
        gap.map_or_else(|| "-".into(), f2),
    ]
}

pub fn run(fast: bool) -> Result<ExperimentResult> {
    run_with_json(fast).map(|(r, _)| r)
}

/// Run the experiment and also return the machine-readable JSON the CLI
/// writes to `BENCH_fleet.json` (uploaded by the CI experiments job).
pub fn run_with_json(fast: bool) -> Result<(ExperimentResult, Value)> {
    run_cases(&cases(fast), fast)
}

fn run_cases(cases: &[Case], fast: bool) -> Result<(ExperimentResult, Value)> {
    let mut out = ExperimentResult::new(
        "fleet",
        "fleet-scale incremental control plane: dirty-tenant re-plans vs full re-plans \
         under failure storms (hetero policy)",
        &[
            "machines", "tenants", "steps", "mode", "events", "re-plans", "deferred", "moved",
            "p50 ms", "p95 ms", "p99 ms", "deliv %", "gap %",
        ],
    );
    let cfg = fleet_cfg();

    let mut gate_p99: Option<f64> = None;
    let mut gate_gap: Option<f64> = None;
    let mut violations = 0usize;
    let mut any_verified = false;
    let mut case_rows = Vec::new();
    for c in cases {
        let spec = FleetSpec {
            steps: c.steps,
            verify: c.verify,
            ..FleetSpec::new(c.machines, c.tenants)
        };
        let inc = run_fleet(&spec, &cfg, FleetMode::Incremental)?;
        let full = if c.compare {
            Some(run_fleet(&spec, &cfg, FleetMode::FullReplan)?)
        } else {
            None
        };
        let gap = full.as_ref().map(|f| quality_gap_pct(&inc, f));
        if c.verify {
            any_verified = true;
            violations += inc.violations + full.as_ref().map_or(0, |f| f.violations);
        }
        if c.machines == GATE_MACHINES {
            gate_p99 = Some(inc.p99_ms);
            if let Some(g) = gap {
                gate_gap = Some(g);
            }
        }
        out.row(report_row(c, &inc, gap));
        if let Some(f) = &full {
            out.row(report_row(c, f, gap));
        }
        case_rows.push(json::obj(vec![
            ("machines", json::num(c.machines as f64)),
            ("tenants", json::num(c.tenants as f64)),
            ("steps", json::num(c.steps as f64)),
            ("incremental", inc.to_json()),
            ("full_replan", full.as_ref().map_or(Value::Null, |f| f.to_json())),
            ("gap_pct", gap.map_or(Value::Null, json::num)),
        ]));
    }

    let p99_ok = gate_p99.is_some_and(|p| p < P99_BUDGET_MS);
    let gap_ok = gate_gap.is_some_and(|g| g <= GAP_BUDGET_PCT);
    if let Some(p99) = gate_p99 {
        out.note(format!(
            "p99 step latency < 10ms at 1000 machines : {} ({p99:.3} ms)",
            if p99_ok { "PASS" } else { "FAIL" }
        ));
    }
    if let Some(gap) = gate_gap {
        out.note(format!(
            "incremental within 5% of full re-plan throughput : {} (gap {gap:+.2}%)",
            if gap_ok { "PASS" } else { "FAIL" }
        ));
    }
    if any_verified {
        out.note(format!(
            "fleet invariants clean on audited configs : {}",
            if violations == 0 { "PASS" } else { "FAIL" }
        ));
    }
    out.note(
        "gap % = weighted delivered-throughput loss vs re-planning every tenant from \
         scratch every step (negative: incremental wins by avoiding migration downtime); \
         latency percentiles are wall-clock per-step decision times, all other columns \
         are deterministic in the seed",
    );

    let v = json::obj(vec![
        ("id", json::s("fleet")),
        ("fast", Value::Bool(fast)),
        ("policy", json::s("hetero")),
        ("p99_budget_ms", json::num(P99_BUDGET_MS)),
        ("gap_budget_pct", json::num(GAP_BUDGET_PCT)),
        ("p99_under_budget", Value::Bool(p99_ok)),
        ("gap_under_budget", Value::Bool(gap_ok)),
        ("violations", json::num(violations as f64)),
        ("configs", json::arr(case_rows)),
    ]);
    Ok((out, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests run a miniature fleet (debug builds are ~50x slower
    // than the release bench); the real configurations run through
    // `hstorm bench fleet` in CI.
    fn tiny() -> Vec<Case> {
        vec![Case { machines: 24, tenants: 5, steps: 25, compare: true, verify: true }]
    }

    #[test]
    fn rows_cover_both_modes_and_json_carries_reports() {
        let (r, v) = run_cases(&tiny(), true).unwrap();
        assert_eq!(r.rows.len(), 2, "incremental + full-replan rows");
        for row in &r.rows {
            assert_eq!(row.len(), 13);
        }
        let configs = v.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(configs.len(), 1);
        let inc = configs[0].get("incremental").unwrap();
        assert_eq!(inc.str_field("mode").unwrap(), "incremental");
        assert!(configs[0].get("gap_pct").unwrap().as_f64().is_some());
        assert_eq!(v.num_field("violations").unwrap(), 0.0);
    }

    #[test]
    fn audited_tiny_fleet_is_clean_and_notes_say_so() {
        let (r, _) = run_cases(&tiny(), true).unwrap();
        assert!(r.notes.iter().any(|n| n.starts_with("fleet invariants clean")), "{:?}", r.notes);
        assert!(
            r.notes.iter().any(|n| n.contains(": PASS")),
            "audited run must pass: {:?}",
            r.notes
        );
    }

    #[test]
    fn gate_notes_only_appear_for_the_gate_config() {
        let (r, v) = run_cases(&tiny(), true).unwrap();
        assert!(
            !r.notes.iter().any(|n| n.contains("p99 step latency")),
            "no 1000-machine case, no latency gate: {:?}",
            r.notes
        );
        assert_eq!(v.get("p99_under_budget").unwrap().as_bool(), Some(false));
    }
}
