//! Branch-and-bound over the exhaustive search's exact design space.
//!
//! Same row tables, same enumeration order, same first-wins fold as
//! [`OptimalScheduler`](super::super::optimal::OptimalScheduler) — but
//! every internal DFS node reads the admissible optimistic bound off
//! the running accumulators and skips subtrees that cannot beat the
//! incumbent under the request's objective.  The prune predicates admit
//! exactly the candidates the exhaustive fold could take, so with an
//! unlimited budget the result is **bit-identical** to `optimal` while
//! evaluating strictly fewer candidates whenever any bound fires; the
//! skipped-candidate count is journaled as `candidate_pruned` with
//! reason `"bound"`.  Under a [`SearchBudget`] the walk becomes
//! anytime: it stops at the budget (or at the requested target gap) and
//! certifies the incumbent against the tightest surviving bound.

use std::time::Instant;

use super::super::optimal::{no_best_error, seed_candidates, Best};
use super::super::{
    Problem, Provenance, Schedule, ScheduleRequest, Scheduler, SearchBudget, Termination,
};
use super::{
    certify, global_bound, record_bound_pruned, record_search_started, repair_warm_start, walk,
    BudgetMeter, TableSet,
};
use crate::{Error, Result};

/// Branch-and-bound policy (`bnb` in the registry).
#[derive(Debug, Clone)]
pub struct BnbScheduler {
    /// Max instances per component (same space bound as `optimal`).
    pub max_instances_per_component: usize,
    /// Hard cap on the space size when no budget limits the walk; with
    /// any budget set, anytime mode accepts spaces of any size.
    pub enumeration_limit: u64,
    /// Seed the incumbent from the heuristics (a good incumbent is
    /// what makes bounds fire early).
    pub seed_heuristics: bool,
    /// Default budget when the request leaves its budget unlimited.
    pub budget: SearchBudget,
}

impl Default for BnbScheduler {
    fn default() -> Self {
        BnbScheduler {
            max_instances_per_component: 3,
            enumeration_limit: 3_000_000,
            seed_heuristics: true,
            budget: SearchBudget::unlimited(),
        }
    }
}

impl BnbScheduler {
    /// Request budget wins; the policy's configured budget is the
    /// fallback.
    pub(crate) fn effective_budget(&self, req: &ScheduleRequest) -> SearchBudget {
        if req.budget.is_unlimited() {
            self.budget
        } else {
            req.budget
        }
    }
}

impl Scheduler for BnbScheduler {
    fn name(&self) -> &'static str {
        "bnb"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let started = Instant::now();
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        let n_comp = problem.topology().n_components();
        let n_m = problem.cluster().n_machines();
        record_search_started(self.name(), n_comp, n_m);

        let ts = TableSet::build(&ev, &rc, self.max_instances_per_component, n_comp, n_m);
        let budget = self.effective_budget(req);
        if budget.is_unlimited() && ts.size > self.enumeration_limit as u128 {
            return Err(Error::Schedule(format!(
                "design space has {} placements (> limit {}); set a search budget for anytime mode",
                ts.size, self.enumeration_limit
            )));
        }
        let ctx = ts.ctx(&ev, &rc, &req.objective);

        let mut best: Option<Best> = None;
        let mut evaluated: u64 = 0;
        if self.seed_heuristics {
            seed_candidates(&ctx, problem, req, self.name(), &mut best, &mut evaluated);
        }
        if let Some(warm) = &req.warm_start {
            if let Some(fixed) = repair_warm_start(&rc, warm, n_comp, n_m) {
                ctx.consider_seed(fixed, &mut best, &mut evaluated);
            }
        }

        let mut meter = BudgetMeter::new(&budget, n_m as u64);
        meter.charge_n(evaluated); // the seeds count against the budget
        let glob = global_bound(&ctx);
        let out = walk(&ctx, best, glob, &mut meter, true);
        evaluated += out.evaluated;

        let best = out.best.ok_or_else(|| no_best_error(&req.objective))?;
        if best.rate <= 0.0 {
            return Err(Error::Schedule("no feasible placement in the design space".into()));
        }
        let mut s = super::super::finish(&ev, best.placement)?;
        let (bound, gap) = certify(out.terminated, s.rate, out.frontier, glob);
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: "kernel".into(),
            wall: started.elapsed(),
            bound,
            optimality_gap: gap,
            terminated: out.terminated,
        };
        super::super::record_schedule_telemetry(&s, out.pruned);
        record_bound_pruned(self.name(), out.bound_pruned);
        super::super::debug_validate(problem, req, &s);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::optimal::OptimalScheduler;
    use super::super::super::{Objective, Problem, ScheduleRequest};
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem(top: &crate::topology::Topology) -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(top, &cluster, &db).unwrap()
    }

    fn assert_identical(p: &Problem, name: &str, max_inst: usize) {
        let req = ScheduleRequest::max_throughput();
        let opt = OptimalScheduler {
            max_instances_per_component: max_inst,
            threads: 1,
            ..Default::default()
        }
        .schedule(p, &req)
        .unwrap();
        let bnb = BnbScheduler { max_instances_per_component: max_inst, ..Default::default() }
            .schedule(p, &req)
            .unwrap();
        assert_eq!(bnb.placement.x, opt.placement.x, "{name}: placements diverge");
        assert_eq!(bnb.rate.to_bits(), opt.rate.to_bits(), "{name}: rates diverge");
        assert!(
            bnb.provenance.placements_evaluated <= opt.provenance.placements_evaluated,
            "{name}: bnb evaluated more ({} > {})",
            bnb.provenance.placements_evaluated,
            opt.provenance.placements_evaluated
        );
        assert_eq!(bnb.provenance.terminated, Termination::Exhausted);
        assert_eq!(bnb.provenance.optimality_gap, Some(0.0), "{name}: exhausted ⇒ gap 0");
    }

    /// The tentpole identity: with an unlimited budget, bnb returns the
    /// bit-identical schedule to the exhaustive optimal on every
    /// benchmark topology (paper cluster), evaluating no more
    /// candidates.  `max_instances 2` keeps the 5-component spaces at
    /// debug-test scale without weakening the property.
    #[test]
    fn bit_identical_to_optimal_on_all_benchmarks() {
        for top in benchmarks::all() {
            let name = top.name.clone();
            let p = problem(&top);
            assert_identical(&p, &name, 2);
        }
    }

    /// Same identity on a scenario cluster (6 heterogeneous machines).
    /// The 5-component topologies exceed the enumeration limit here
    /// (27^5 ≈ 14M), so the sweep covers the ≤ 4-component ones.
    #[test]
    fn bit_identical_on_scenario_cluster() {
        let (cluster, db) = crate::cluster::scenarios::by_id(1).unwrap().build();
        for top in benchmarks::all() {
            if top.n_components() > 4 {
                continue;
            }
            let name = top.name.clone();
            let p = Problem::new(&top, &cluster, &db).unwrap();
            assert_identical(&p, &name, 2);
        }
    }

    /// Identity must also hold under the non-default objectives (their
    /// prune predicates differ).
    #[test]
    fn bit_identical_under_every_objective() {
        let p = problem(&benchmarks::linear());
        let probe = OptimalScheduler { threads: 1, ..Default::default() }
            .schedule(&p, &ScheduleRequest::max_throughput())
            .unwrap();
        for objective in [
            Objective::MinMachinesAtRate(probe.rate * 0.5),
            Objective::BalancedUtilization,
        ] {
            let req = ScheduleRequest::new(objective);
            let opt = OptimalScheduler { threads: 1, ..Default::default() }
                .schedule(&p, &req)
                .unwrap();
            let bnb = BnbScheduler::default().schedule(&p, &req).unwrap();
            assert_eq!(bnb.placement.x, opt.placement.x, "{:?}", req.objective);
            assert_eq!(bnb.rate.to_bits(), opt.rate.to_bits());
        }
    }

    /// Pruning must actually fire (strictly fewer evaluations) — the
    /// acceptance criterion's micro form.
    #[test]
    fn prunes_strictly_on_linear_topology() {
        let p = problem(&benchmarks::linear());
        let req = ScheduleRequest::max_throughput();
        let opt = OptimalScheduler { threads: 1, ..Default::default() }
            .schedule(&p, &req)
            .unwrap();
        let bnb = BnbScheduler::default().schedule(&p, &req).unwrap();
        assert!(
            bnb.provenance.placements_evaluated < opt.provenance.placements_evaluated,
            "bound pruning never fired: {} vs {}",
            bnb.provenance.placements_evaluated,
            opt.provenance.placements_evaluated
        );
    }

    /// A candidate budget truncates the walk and certifies a gap.
    #[test]
    fn budget_truncates_and_certifies() {
        let p = problem(&benchmarks::linear());
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_max_candidates(25));
        let s = BnbScheduler::default().schedule(&p, &req).unwrap();
        assert!(s.provenance.placements_evaluated <= 25);
        assert_eq!(s.provenance.terminated, Termination::Budget);
        let gap = s.provenance.optimality_gap.expect("truncated run must report a gap");
        assert!(gap >= 0.0);
        let bound = s.provenance.bound.expect("truncated run must report a bound");
        assert!(bound + 1e-9 >= s.rate);
    }

    /// A generous target gap stops the walk as soon as the incumbent
    /// certifies within it.
    #[test]
    fn target_gap_stops_early() {
        let p = problem(&benchmarks::linear());
        let req = ScheduleRequest::max_throughput()
            .with_budget(SearchBudget::unlimited().with_target_gap(10.0));
        let s = BnbScheduler::default().schedule(&p, &req).unwrap();
        assert_eq!(s.provenance.terminated, Termination::TargetGap);
        assert!(s.provenance.optimality_gap.unwrap() <= 10.0);
    }
}
