//! Scheduler-search performance tracker (`hstorm bench sched-perf`).
//!
//! Races the optimal search's two engines over the exhaustive seed
//! scenarios — the naive batched scorer (`O(C·M)` per candidate, nested
//! `Vec` placements) against the incremental row-table kernel
//! ([`crate::predict::kernel`]), single-threaded and sharded — and
//! reports candidates/second, wall time and whether every engine
//! selected the identical schedule.
//!
//! The CLI writes the machine-readable form to `BENCH_sched.json`
//! whenever this experiment runs, and CI uploads it as an artifact, so
//! the scheduling-perf trajectory is tracked run over run.  CI's
//! perf-smoke step greps the rendered notes
//! `incremental >= naive candidates/s : PASS`,
//! `bnb prunes > 0 and same schedule as exhaustive : PASS` and
//! `portfolio gap <= 10% : PASS`.

use crate::cluster::profile::ProfileDb;
use crate::cluster::{presets, scenarios, Cluster};
use crate::scheduler::optimal::OptimalScheduler;
use crate::scheduler::search::{BnbScheduler, PortfolioScheduler};
use crate::scheduler::{Problem, Schedule, ScheduleRequest, Scheduler, SearchBudget};
use crate::topology::benchmarks;
use crate::util::json::{self, Value};
use crate::Result;

use super::{f1, f2, ExperimentResult};

/// One engine's measured run.
struct EngineRun {
    engine: &'static str,
    schedule: Schedule,
}

impl EngineRun {
    fn wall_s(&self) -> f64 {
        self.schedule.provenance.wall.as_secs_f64().max(1e-9)
    }

    fn candidates_per_s(&self) -> f64 {
        self.schedule.provenance.placements_evaluated as f64 / self.wall_s()
    }

    fn to_json(&self) -> Value {
        json::obj(vec![
            ("engine", json::s(self.engine)),
            ("evaluated", json::num(self.schedule.provenance.placements_evaluated as f64)),
            ("wall_s", json::num(self.wall_s())),
            ("candidates_per_s", json::num(self.candidates_per_s())),
            ("rate", json::num(self.schedule.rate)),
        ])
    }
}

/// One scenario of the race.
struct Case {
    name: &'static str,
    cluster: Cluster,
    db: ProfileDb,
    max_instances: usize,
}

fn cases(fast: bool) -> Vec<Case> {
    let (paper, paper_db) = presets::paper_cluster();
    let (small, small_db) = scenarios::by_id(1).expect("scenario 1 registered").build();
    vec![
        Case {
            name: "paper-cluster",
            cluster: paper,
            db: paper_db,
            max_instances: if fast { 2 } else { 3 },
        },
        // the largest seed scenario the exhaustive search can enumerate
        // (scenario 2/3 design spaces exceed the enumeration limit)
        Case { name: "scenario1-small", cluster: small, db: small_db, max_instances: 2 },
    ]
}

/// Run the race and return (rendered table, BENCH_sched.json payload).
pub fn run_with_json(fast: bool) -> Result<(ExperimentResult, Value)> {
    let mut out = ExperimentResult::new(
        "sched-perf",
        "optimal-search engines head-to-head (naive vs incremental kernel)",
        &[
            "scenario",
            "engine",
            "space",
            "evaluated",
            "wall",
            "candidates/s",
            "speedup",
            "same schedule",
        ],
    );
    let top = benchmarks::linear();
    let req = ScheduleRequest::max_throughput();
    let auto_threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    let mut scenario_objs = Vec::new();
    let mut min_speedup = f64::INFINITY;

    for case in cases(fast) {
        let problem = Problem::new(&top, &case.cluster, &case.db)?;
        let single = OptimalScheduler {
            max_instances_per_component: case.max_instances,
            threads: 1,
            ..Default::default()
        };
        let space = single.design_space_size(top.n_components(), case.cluster.n_machines());

        let naive =
            EngineRun { engine: "naive", schedule: single.schedule_naive(&problem, &req)? };
        let incr = EngineRun { engine: "incremental", schedule: single.schedule(&problem, &req)? };
        let parallel = EngineRun {
            engine: "parallel",
            schedule: OptimalScheduler { threads: 0, ..single.clone() }.schedule(&problem, &req)?,
        };

        let same = naive.schedule.placement == incr.schedule.placement
            && incr.schedule.placement == parallel.schedule.placement;
        let speedup_incr = incr.candidates_per_s() / naive.candidates_per_s();
        let speedup_par = parallel.candidates_per_s() / naive.candidates_per_s();
        min_speedup = min_speedup.min(speedup_incr);

        for (run, speedup) in
            [(&naive, 1.0), (&incr, speedup_incr), (&parallel, speedup_par)]
        {
            out.row(vec![
                case.name.into(),
                run.engine.into(),
                space.to_string(),
                run.schedule.provenance.placements_evaluated.to_string(),
                format!("{:.1} ms", run.wall_s() * 1e3),
                f1(run.candidates_per_s()),
                format!("{}x", f2(speedup)),
                if same { "yes" } else { "NO" }.into(),
            ]);
        }

        scenario_objs.push(json::obj(vec![
            ("name", json::s(case.name)),
            ("machines", json::num(case.cluster.n_machines() as f64)),
            ("max_instances", json::num(case.max_instances as f64)),
            ("space", json::num(space as f64)),
            ("naive", naive.to_json()),
            ("incremental", incr.to_json()),
            ("parallel", parallel.to_json()),
            ("speedup_incremental", json::num(speedup_incr)),
            ("speedup_parallel", json::num(speedup_par)),
            ("same_schedule", json::bool(same)),
        ]));
    }

    // --- branch-and-bound identity gate: bit-identical schedule to the
    // exhaustive kernel on scenario 1 while evaluating strictly fewer
    // candidates (the pruned remainder is certified by the bound) ---
    let (s1_cluster, s1_db) = scenarios::by_id(1).expect("scenario 1 registered").build();
    let s1_problem = Problem::new(&top, &s1_cluster, &s1_db)?;
    let s1_single = OptimalScheduler {
        max_instances_per_component: 2,
        threads: 1,
        ..Default::default()
    };
    let s1_space = s1_single.design_space_size(top.n_components(), s1_cluster.n_machines());
    let exhaustive =
        EngineRun { engine: "exhaustive", schedule: s1_single.schedule(&s1_problem, &req)? };
    let bnb = EngineRun {
        engine: "bnb",
        schedule: BnbScheduler { max_instances_per_component: 2, ..Default::default() }
            .schedule(&s1_problem, &req)?,
    };
    let bnb_same = bnb.schedule.placement == exhaustive.schedule.placement
        && bnb.schedule.rate.to_bits() == exhaustive.schedule.rate.to_bits();
    let bnb_fewer = bnb.schedule.provenance.placements_evaluated
        < exhaustive.schedule.provenance.placements_evaluated;
    let bnb_verdict = if bnb_same && bnb_fewer { "PASS" } else { "FAIL" };
    for run in [&exhaustive, &bnb] {
        out.row(vec![
            "scenario1-bnb".into(),
            run.engine.into(),
            s1_space.to_string(),
            run.schedule.provenance.placements_evaluated.to_string(),
            format!("{:.1} ms", run.wall_s() * 1e3),
            f1(run.candidates_per_s()),
            format!("{}x", f2(exhaustive.wall_s() / run.wall_s())),
            if bnb_same { "yes" } else { "NO" }.into(),
        ]);
    }

    // --- anytime gate: a budgeted portfolio on the 180-machine
    // scenario must return a feasible schedule with a certified
    // optimality gap within 10% ---
    let (big_cluster, big_db) = scenarios::by_id(3).expect("scenario 3 registered").build();
    let big_problem = Problem::new(&top, &big_cluster, &big_db)?;
    let big_machines = big_cluster.n_machines();
    let budget_candidates: u64 = if fast { 2_000 } else { 6_000 };
    let big_space = OptimalScheduler { max_instances_per_component: 2, ..Default::default() }
        .design_space_size(top.n_components(), big_machines);
    let preq = ScheduleRequest::max_throughput().with_budget(
        SearchBudget::unlimited()
            .with_max_candidates(budget_candidates)
            .with_max_virtual_ops(budget_candidates * big_machines as u64 * 8),
    );
    let portfolio = EngineRun {
        engine: "portfolio",
        schedule: PortfolioScheduler { max_instances_per_component: 2, ..Default::default() }
            .schedule(&big_problem, &preq)?,
    };
    let gap = portfolio.schedule.provenance.optimality_gap;
    let pf_ok = portfolio.schedule.eval.feasible && gap.map_or(false, |g| g <= 0.10);
    let pf_verdict = if pf_ok { "PASS" } else { "FAIL" };
    out.row(vec![
        "scenario3-portfolio".into(),
        portfolio.engine.into(),
        big_space.to_string(),
        portfolio.schedule.provenance.placements_evaluated.to_string(),
        format!("{:.1} ms", portfolio.wall_s() * 1e3),
        f1(portfolio.candidates_per_s()),
        "-".into(),
        if pf_ok { "yes" } else { "NO" }.into(),
    ]);

    let verdict = if min_speedup >= 1.0 { "PASS" } else { "FAIL" };
    out.note(format!(
        "incremental >= naive candidates/s : {verdict} (min speedup {}x)",
        f2(min_speedup)
    ));
    out.note(format!(
        "parallel shards: {auto_threads} threads (identical schedule at any thread count)"
    ));
    out.note(format!(
        "bnb prunes > 0 and same schedule as exhaustive : {bnb_verdict} ({} of {} candidates)",
        bnb.schedule.provenance.placements_evaluated,
        exhaustive.schedule.provenance.placements_evaluated
    ));
    out.note(format!(
        "portfolio gap <= 10% : {pf_verdict} (gap {}, {big_machines} machines, \
         {budget_candidates} candidate budget)",
        gap.map_or("none".to_string(), |g| format!("{:.2}%", g * 100.0)),
    ));

    let payload = json::obj(vec![
        ("bench", json::s("sched-perf")),
        ("fast", json::bool(fast)),
        ("auto_threads", json::num(auto_threads as f64)),
        ("min_speedup_incremental", json::num(min_speedup)),
        ("verdict", json::s(verdict)),
        ("scenarios", json::arr(scenario_objs)),
        (
            "bnb_identity",
            json::obj(vec![
                ("space", json::num(s1_space as f64)),
                (
                    "evaluated_exhaustive",
                    json::num(exhaustive.schedule.provenance.placements_evaluated as f64),
                ),
                ("evaluated_bnb", json::num(bnb.schedule.provenance.placements_evaluated as f64)),
                ("same_schedule", json::bool(bnb_same)),
                ("verdict", json::s(bnb_verdict)),
            ]),
        ),
        (
            "portfolio_anytime",
            json::obj(vec![
                ("machines", json::num(big_machines as f64)),
                ("space", json::num(big_space as f64)),
                ("budget_candidates", json::num(budget_candidates as f64)),
                ("evaluated", json::num(portfolio.schedule.provenance.placements_evaluated as f64)),
                ("rate", json::num(portfolio.schedule.rate)),
                ("feasible", json::bool(portfolio.schedule.eval.feasible)),
                ("optimality_gap", gap.map(json::num).unwrap_or(Value::Null)),
                ("verdict", json::s(pf_verdict)),
            ]),
        ),
    ]);
    Ok((out, payload))
}

/// Experiment-harness entry point (table only).
pub fn run(fast: bool) -> Result<ExperimentResult> {
    run_with_json(fast).map(|(r, _)| r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_races_both_scenarios() {
        let (r, v) = run_with_json(true).unwrap();
        // 2 scenarios x 3 engines + 2 bnb-identity rows + 1 portfolio row
        assert_eq!(r.rows.len(), 9);
        assert!(r.notes.iter().any(|n| n.contains("incremental >= naive")), "{:?}", r.notes);
        let scenarios = v.get("scenarios").unwrap().as_arr().unwrap();
        assert_eq!(scenarios.len(), 2);
        for s in scenarios {
            assert_eq!(
                s.get("same_schedule").unwrap().as_bool(),
                Some(true),
                "engines must select the identical schedule"
            );
        }
    }

    /// Acceptance (scenario 1): bnb returns the identical schedule to
    /// the exhaustive kernel while evaluating strictly fewer candidates.
    /// Acceptance (scenario 3, 180 machines): the budgeted portfolio
    /// stays feasible and certifies an optimality gap within 10%.
    #[test]
    fn bnb_and_portfolio_gates_pass() {
        let (r, v) = run_with_json(true).unwrap();
        assert!(
            r.notes.iter().any(|n| n.contains("same schedule as exhaustive : PASS")),
            "{:?}",
            r.notes
        );
        assert!(
            r.notes.iter().any(|n| n.contains("portfolio gap <= 10% : PASS")),
            "{:?}",
            r.notes
        );
        let bnb = v.get("bnb_identity").unwrap();
        assert_eq!(bnb.get("same_schedule").unwrap().as_bool(), Some(true));
        assert!(
            bnb.num_field("evaluated_bnb").unwrap() < bnb.num_field("evaluated_exhaustive").unwrap()
        );
        let pf = v.get("portfolio_anytime").unwrap();
        assert_eq!(pf.get("feasible").unwrap().as_bool(), Some(true));
        let gap = pf.num_field("optimality_gap").unwrap();
        assert!((0.0..=0.10).contains(&gap), "portfolio gap {gap} above 10%");
    }
}
