//! Lightweight metrics registry used by the engine and the CLI.
//!
//! Counters and gauges are atomic and cheap to update from the tokio hot
//! path; snapshots are taken lock-free.  This replaces Storm's UI /
//! `get_execute_ms_avg()` surface the paper's profiling step reads.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::RwLock;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (stored as micro-units to keep it atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store((v * 1e6) as i64, Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Accumulates (sum, count) pairs for mean statistics, e.g. per-tuple
/// service time — the engine-side `e_ij` measurement.
#[derive(Debug, Default)]
pub struct MeanStat {
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl MeanStat {
    /// Record one observation in seconds.  Accumulated in nanoseconds,
    /// rounded to nearest: the old micro-unit truncation dropped
    /// sub-microsecond observations entirely while still incrementing
    /// `count`, biasing the measured mean (the engine-side `e_ij`)
    /// downward.
    pub fn observe(&self, seconds: f64) {
        self.sum_ns.fetch_add((seconds * 1e9).round() as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean in seconds, or `None` with no observations.
    pub fn mean(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            return None;
        }
        Some(self.sum_ns.load(Ordering::Relaxed) as f64 / 1e9 / n as f64)
    }

    pub fn reset(&self) {
        self.sum_ns.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// Named metric registry shared across engine actors.
#[derive(Debug, Default, Clone)]
pub struct Registry {
    counters: Arc<RwLock<HashMap<String, Arc<Counter>>>>,
    gauges: Arc<RwLock<HashMap<String, Arc<Gauge>>>>,
    means: Arc<RwLock<HashMap<String, Arc<MeanStat>>>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return c.clone();
        }
        self.counters
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::default()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            return g.clone();
        }
        self.gauges
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::default()))
            .clone()
    }

    pub fn mean(&self, name: &str) -> Arc<MeanStat> {
        if let Some(m) = self.means.read().unwrap().get(name) {
            return m.clone();
        }
        self.means
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(MeanStat::default()))
            .clone()
    }

    /// Snapshot all metrics as `(name, value)` rows, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> = Vec::new();
        for (k, v) in self.counters.read().unwrap().iter() {
            rows.push((k.clone(), v.get() as f64));
        }
        for (k, v) in self.gauges.read().unwrap().iter() {
            rows.push((k.clone(), v.get()));
        }
        for (k, v) in self.means.read().unwrap().iter() {
            rows.push((format!("{k}.mean"), v.mean().unwrap_or(0.0)));
        }
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_inc() {
        let r = Registry::new();
        let c = r.counter("tuples");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("tuples").get(), 5);
    }

    #[test]
    fn gauge_roundtrip() {
        let r = Registry::new();
        r.gauge("util").set(73.25);
        assert!((r.gauge("util").get() - 73.25).abs() < 1e-5);
    }

    #[test]
    fn mean_stat() {
        let m = MeanStat::default();
        assert!(m.mean().is_none());
        m.observe(0.010);
        m.observe(0.020);
        assert!((m.mean().unwrap() - 0.015).abs() < 1e-6);
        m.reset();
        assert!(m.mean().is_none());
    }

    #[test]
    fn mean_stat_keeps_sub_microsecond_observations() {
        // 0.3 µs observations: micro-unit truncation recorded 0 for
        // every one (while still counting them), collapsing the mean
        // to zero; nanosecond accumulation preserves them exactly
        let m = MeanStat::default();
        for _ in 0..10 {
            m.observe(0.3e-6);
        }
        assert_eq!(m.count(), 10);
        assert!((m.mean().unwrap() - 0.3e-6).abs() < 1e-12, "{:?}", m.mean());
        // microsecond-scale values survive unchanged
        let m2 = MeanStat::default();
        m2.observe(1.6e-6);
        assert!((m2.mean().unwrap() - 1.6e-6).abs() < 1e-12, "{:?}", m2.mean());
    }

    #[test]
    fn snapshot_sorted() {
        let r = Registry::new();
        r.counter("b").inc();
        r.gauge("a").set(1.0);
        let snap = r.snapshot();
        assert_eq!(snap[0].0, "a");
        assert_eq!(snap[1].0, "b");
    }

    #[test]
    fn shared_across_clones() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
    }
}
