//! Drive hstorm from a JSON experiment config: define a custom user
//! topology graph and a custom heterogeneous cluster, save the config,
//! load it back, and schedule it — the downstream-user workflow without
//! writing any scheduler code.
//!
//! ```bash
//! cargo run --release --example custom_topology
//! ```

use hstorm::config::{
    ClusterConfig, ComponentConfig, ExperimentConfig, MachineGroupConfig, ProfileRowConfig,
    TopologyConfig,
};
use hstorm::resolve;
use hstorm::scheduler::{PolicyParams, Problem, ScheduleRequest};

fn main() -> hstorm::Result<()> {
    // an IoT-style ingest pipeline: two sensor spouts -> parse -> enrich
    // -> {alert, archive}
    let cfg = ExperimentConfig {
        topology: TopologyConfig {
            name: "iot-ingest".into(),
            components: vec![
                comp("sensors-a", "spout", "spout", 1.0, &[]),
                comp("sensors-b", "spout", "spout", 1.0, &[]),
                comp("parse", "bolt", "parse", 1.0, &["sensors-a", "sensors-b"]),
                comp("enrich", "bolt", "enrich", 0.8, &["parse"]),
                comp("alert", "bolt", "alert", 0.1, &["enrich"]),
                comp("archive", "bolt", "archive", 1.0, &["enrich"]),
            ],
        },
        cluster: ClusterConfig {
            name: "edge-cluster".into(),
            groups: vec![
                MachineGroupConfig {
                    machine_type: "arm-edge".into(),
                    description: "ARM edge node".into(),
                    count: 2,
                },
                MachineGroupConfig {
                    machine_type: "xeon".into(),
                    description: "Xeon server".into(),
                    count: 1,
                },
            ],
        },
        profiles: profile_rows(),
        r0: 20.0,
        scheduler: "hetero".into(),
    };

    let path = std::env::temp_dir().join("hstorm-custom-topology.json");
    cfg.save(&path)?;
    println!("wrote {}", path.display());

    // the downstream-user path: load + schedule through the same
    // resolver the CLI and the JSON runner use
    let loaded = ExperimentConfig::load(&path)?;
    let top = loaded.topology.to_topology()?;
    let cluster = loaded.cluster.to_cluster()?;
    let db = loaded.profile_db();

    let problem = Problem::new(&top, &cluster, &db)?; // validates coverage once
    let sched = resolve::policy(
        &loaded.scheduler,
        &PolicyParams { r0: loaded.r0, ..Default::default() },
    )?;
    let s = sched.schedule(&problem, &ScheduleRequest::max_throughput())?;
    println!("\nscheduled '{}' on '{}':", top.name, cluster.name);
    println!("  certified rate       {:.1} tuple/s", s.rate);
    println!("  predicted throughput {:.1} tuple/s", s.eval.throughput);
    print!("{}", s.describe(&top, &cluster));
    for (m, u) in s.eval.util.iter().enumerate() {
        println!("  {:<12} predicted {:>5.1}%", cluster.machines[m].name, u);
    }
    std::fs::remove_file(&path).ok();
    Ok(())
}

fn comp(name: &str, kind: &str, task_type: &str, alpha: f64, parents: &[&str]) -> ComponentConfig {
    ComponentConfig {
        name: name.into(),
        kind: kind.into(),
        task_type: task_type.into(),
        alpha,
        parents: parents.iter().map(|p| p.to_string()).collect(),
    }
}

fn profile_rows() -> Vec<ProfileRowConfig> {
    // (task_type, [e on arm-edge, e on xeon])
    let rows: &[(&str, [f64; 2])] = &[
        ("spout", [0.006, 0.003]),
        ("parse", [0.090, 0.030]),
        ("enrich", [0.200, 0.070]),
        ("alert", [0.040, 0.015]),
        ("archive", [0.110, 0.045]),
    ];
    let mut out = Vec::new();
    for (tt, e) in rows {
        for (i, mt) in ["arm-edge", "xeon"].iter().enumerate() {
            out.push(ProfileRowConfig {
                task_type: tt.to_string(),
                machine_type: mt.to_string(),
                e: e[i],
                met: 1.5,
            });
        }
    }
    out
}
