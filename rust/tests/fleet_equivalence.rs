//! Acceptance suite for the fleet-scale incremental control plane:
//! the copy-on-write delta path must be **bit-identical** to a full
//! rebuild — [`Problem::apply_delta`] over a randomized join/leave/
//! drift sequence produces the same evaluator matrices (to the bit) as
//! [`Problem::new`] on equivalently-mutated inputs, and the same
//! schedule; a dirty tenant's residual re-plan (the fleet harness
//! spelling: reserve every resident's utilization, then schedule) is
//! the same decision as [`WorkloadProblem::admit`]; and a long fleet
//! storm replay is deterministic in the seed, bit for bit.

use std::sync::Arc;

use hstorm::cluster::{scenarios, Machine};
use hstorm::controller::fleet::{run_fleet, FleetMode, FleetReport, FleetSpec};
use hstorm::controller::ControllerConfig;
use hstorm::predict::Evaluator;
use hstorm::scheduler::{
    registry, Constraints, PolicyParams, Problem, ProblemDelta, ScheduleRequest, Scheduler,
    SearchBudget, TenantSchedule, Workload, WorkloadProblem,
};
use hstorm::topology::benchmarks;
use hstorm::util::rng::Rng;

fn assert_eval_bits_eq(got: &Evaluator, want: &Evaluator, ctx: &str) {
    assert_eq!(got.n_components(), want.n_components(), "{ctx}: component count");
    assert_eq!(got.n_machines(), want.n_machines(), "{ctx}: machine count");
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&got.cap), bits(&want.cap), "{ctx}: cap");
    assert_eq!(bits(&got.gains), bits(&want.gains), "{ctx}: gains");
    for c in 0..want.n_components() {
        assert_eq!(bits(&got.e_m[c]), bits(&want.e_m[c]), "{ctx}: e_m[{c}]");
        assert_eq!(bits(&got.met_m[c]), bits(&want.met_m[c]), "{ctx}: met_m[{c}]");
    }
}

/// Bit-identity: a problem patched through a randomized event sequence
/// equals a from-scratch [`Problem::new`] on the mutated cluster +
/// profile db after **every** event — same evaluator matrices to the
/// bit, same hetero schedule at the end.
#[test]
fn patched_problem_is_bit_identical_to_a_rebuild() {
    let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
    let req = ScheduleRequest::max_throughput();
    for (top, seed) in [
        (benchmarks::linear(), 1u64),
        (benchmarks::rolling_count(), 2),
        (benchmarks::unique_visitor(), 3),
    ] {
        let (mut cluster, mut db) = scenarios::fleet(30, 6);
        let mut problem = Problem::new(&top, &cluster, &db).unwrap();
        let task_types: Vec<String> = top.components.iter().map(|c| c.task_type.clone()).collect();
        let mut rng = Rng::new(seed);
        let mut joins = 0usize;
        for step in 0..30 {
            // mutate the mirror inputs and the problem with the same event
            match rng.range(0, 2) {
                0 => {
                    let type_id = rng.range(0, cluster.types.len() - 1);
                    let name = format!("x-{joins}");
                    joins += 1;
                    problem
                        .apply_delta(&ProblemDelta::MachineJoin {
                            name: name.clone(),
                            machine_type: cluster.types[type_id].name.clone(),
                            cap: 100.0,
                        })
                        .unwrap();
                    cluster.machines.push(Machine { name, type_id, cap: 100.0 });
                }
                1 if cluster.n_machines() > 4 => {
                    let m = rng.range(0, cluster.n_machines() - 1);
                    let name = cluster.machines[m].name.clone();
                    problem.apply_delta(&ProblemDelta::MachineLeave { name }).unwrap();
                    cluster.machines.remove(m);
                }
                _ => {
                    let task = &task_types[rng.range(0, task_types.len() - 1)];
                    let mt = &cluster.types[rng.range(0, cluster.types.len() - 1)].name;
                    let factor = rng.range_f64(0.6, 1.4);
                    problem
                        .apply_delta(&ProblemDelta::ProfileDrift {
                            task_type: task.clone(),
                            machine_type: mt.clone(),
                            factor,
                        })
                        .unwrap();
                    // the mirror applies the documented drift semantics
                    let mut p = db.get(task, mt).unwrap();
                    p.e *= factor.max(1e-9);
                    db.insert(task, mt, p);
                }
            }
            let rebuilt = Problem::new(&top, &cluster, &db).unwrap();
            assert_eval_bits_eq(
                problem.evaluator(),
                rebuilt.evaluator(),
                &format!("{}/seed {seed}/event {step}", top.name),
            );
            let got = hetero.schedule(&problem, &req).unwrap();
            let want = hetero.schedule(&rebuilt, &req).unwrap();
            assert_eq!(got.placement, want.placement, "{}: placements diverge", top.name);
            assert_eq!(
                got.rate.to_bits(),
                want.rate.to_bits(),
                "{}: rates diverge ({} vs {})",
                top.name,
                got.rate,
                want.rate
            );
        }
        assert_eq!(problem.version(), 30, "{}: every event bumps the version", top.name);
    }
}

/// A resident pinned at a fraction of its certified max rate.
fn resident_at(
    wp: &WorkloadProblem,
    idx: usize,
    policy: &dyn Scheduler,
    frac: f64,
) -> TenantSchedule {
    let tp = &wp.tenants()[idx];
    let s = policy.schedule(&tp.problem, &ScheduleRequest::max_throughput()).unwrap();
    let rate = s.rate * frac;
    let eval = tp.problem.evaluator().evaluate(&s.placement, rate).unwrap();
    TenantSchedule {
        tenant: tp.name.clone(),
        weight: tp.weight,
        schedule: hstorm::scheduler::Schedule {
            placement: s.placement,
            rate,
            eval,
            provenance: s.provenance,
        },
    }
}

/// With exactly one dirty tenant, the fleet harness's residual re-plan
/// (reserve every resident's per-machine utilization, schedule the
/// dirty tenant's own problem) is the same decision as the workload
/// layer's [`WorkloadProblem::admit`] — identical placement, identical
/// certified rate to the bit.
#[test]
fn single_dirty_tenant_residual_replan_matches_admit() {
    let (cluster, db) = scenarios::fleet(12, 4);
    let shared = Arc::new(db);
    let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
    let req = ScheduleRequest::max_throughput();
    let wp = WorkloadProblem::new(
        Workload::new("fleet-slice")
            .tenant("resident-a", benchmarks::linear(), shared.clone(), 1.0)
            .tenant("resident-b", benchmarks::star(), shared.clone(), 1.5)
            .tenant("dirty", benchmarks::rolling_count(), shared.clone(), 2.0),
        &cluster,
    )
    .unwrap();
    let residents =
        [resident_at(&wp, 0, hetero.as_ref(), 0.5), resident_at(&wp, 1, hetero.as_ref(), 0.4)];

    // workload spelling: admission against the residual
    let admitted = wp.admit(&residents, 2, hetero.as_ref(), &req).unwrap();

    // fleet spelling: residents' combined utilization as per-machine
    // reservations on the dirty tenant's own problem
    let mut load = vec![0.0f64; cluster.n_machines()];
    for r in &residents {
        for (m, u) in r.schedule.eval.util.iter().enumerate() {
            load[m] += u;
        }
    }
    let mut constraints = Constraints::new();
    for (m, l) in load.iter().enumerate() {
        if *l > 1e-12 {
            constraints = constraints.reserve_machine_load(&cluster.machines[m].name, *l);
        }
    }
    let replanned = hetero
        .schedule(&wp.tenants()[2].problem, &req.clone().with_constraints(constraints))
        .unwrap();

    assert_eq!(replanned.placement, admitted.schedule.placement, "placements diverge");
    assert_eq!(
        replanned.rate.to_bits(),
        admitted.schedule.rate.to_bits(),
        "rates diverge ({} vs {})",
        replanned.rate,
        admitted.schedule.rate
    );
    assert!(admitted.schedule.rate > 0.0, "residual must have room at 50%/40% residency");
}

fn fingerprint(r: &FleetReport) -> Vec<u64> {
    vec![
        r.admitted as u64,
        r.events as u64,
        r.replans as u64,
        r.replan_steps as u64,
        r.deferred as u64,
        r.tasks_moved as u64,
        r.violations as u64,
        r.offered_volume.to_bits(),
        r.delivered_volume.to_bits(),
    ]
}

/// A long fleet storm trace (correlated rack outages, a flapper,
/// trace-driven autoscaling, dirty-tenant re-plans) replays
/// bit-identically: everything but the wall-clock latency percentiles
/// is deterministic in the seed.
#[test]
fn long_fleet_trace_replays_bit_identically() {
    let spec = FleetSpec { steps: 80, seed: 11, rack_size: 8, ..FleetSpec::new(48, 8) };
    let cfg = ControllerConfig {
        replan_budget: SearchBudget::unlimited().with_max_candidates(128),
        max_moves_per_step: 500,
        ..Default::default()
    };
    let a = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
    let b = run_fleet(&spec, &cfg, FleetMode::Incremental).unwrap();
    assert!(a.admitted > 0, "fleet must admit tenants");
    assert!(a.events > 0, "storm trace must carry events");
    assert!(a.replans > 0, "storm must dirty tenants");
    assert_eq!(a.steps, 80);
    assert_eq!(fingerprint(&a), fingerprint(&b), "replay diverged");
}
