//! Workload traces: rate profiles over virtual time, interleaved with
//! cluster events.
//!
//! A [`Trace`] is a seeded, deterministic sequence of [`TraceStep`]s.
//! Offered rates are **normalized** — `offered` is a multiple of the
//! initial schedule's certified rate — so the same trace shape stresses
//! any (topology, cluster) pair proportionally.  Cluster events model the
//! world changing under the scheduler: machines leaving and (re)joining,
//! and per-type profile drift (the measured `e_ij` of a task type on a
//! machine type changing over time, e.g. co-tenant interference easing
//! or worsening).
//!
//! Named generators ([`by_name`]):
//!
//! * `constant` — flat 0.8× load, no events (baseline / sanity).
//! * `diurnal`  — two sinusoidal day cycles between ~0.4× and ~1.3×,
//!   with a machine outage across the middle third, a favorable
//!   profile-drift episode, and its late reversal.
//! * `ramp`     — linear ramp 0.3× → 1.4× with a capacity expansion
//!   (machine join) at the midpoint.
//! * `bursty`   — ~0.55× baseline with seeded flash crowds (short
//!   windows at 1.05×–1.45×) plus one machine leave/rejoin churn pair.
//! * `fleet-storm` — flat 1.0× rate (the fleet runner overlays its own
//!   per-tenant profiles) carrying the cluster-event backbone of a
//!   fleet run: correlated rack outages (every machine whose name
//!   shares a rack prefix leaves at once and the rack returns later)
//!   and one flapping machine cycling leave/rejoin.

use crate::cluster::Cluster;
use crate::topology::Topology;
use crate::util::rng::Rng;

/// A change in cluster state at some trace step.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEvent {
    /// Machine `machine` (by name) leaves the cluster (failure or
    /// decommission).
    Leave { machine: String },
    /// A machine named `machine` of existing type `machine_type` joins
    /// (scale-out or a repaired node returning).
    Join { machine: String, machine_type: String },
    /// Profile drift: scale the per-tuple cost `e` of `task_type` on
    /// `machine_type` by `factor` (< 1 speeds the pair up, > 1 slows it
    /// down).
    Drift { task_type: String, machine_type: String, factor: f64 },
}

/// One step of virtual time.
#[derive(Debug, Clone)]
pub struct TraceStep {
    /// Virtual time, seconds since trace start.
    pub t: f64,
    /// Offered topology input rate, as a multiple of the initial
    /// certified rate (1.0 = exactly the capacity of the day-zero
    /// schedule).
    pub offered: f64,
    /// Cluster events applied at the start of this step.
    pub events: Vec<ClusterEvent>,
}

/// A deterministic workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub seed: u64,
    pub steps: Vec<TraceStep>,
}

impl Trace {
    pub fn n_steps(&self) -> usize {
        self.steps.len()
    }

    /// Total cluster events across all steps.
    pub fn n_events(&self) -> usize {
        self.steps.iter().map(|s| s.events.len()).sum()
    }
}

/// Trace names accepted by [`by_name`] (CLI error surfaces).
pub const NAMES: [&str; 5] = ["constant", "diurnal", "ramp", "bursty", "fleet-storm"];

/// Look a trace generator up by name.
pub fn by_name(
    name: &str,
    top: &Topology,
    cluster: &Cluster,
    steps: usize,
    seed: u64,
) -> Option<Trace> {
    match name {
        "constant" => Some(constant(steps, seed)),
        "diurnal" => Some(diurnal(top, cluster, steps, seed)),
        "ramp" => Some(ramp(cluster, steps, seed)),
        "bursty" => Some(bursty(cluster, steps, seed)),
        "fleet-storm" => Some(fleet_storm(cluster, steps, seed)),
        _ => None,
    }
}

/// ±2% seeded multiplicative jitter (real offered load is never smooth).
fn jitter(rng: &mut Rng) -> f64 {
    1.0 + 0.04 * (rng.f64() - 0.5)
}

/// Flat 0.8× load, no cluster events.
pub fn constant(steps: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let steps = (0..steps.max(1))
        .map(|i| TraceStep { t: i as f64, offered: 0.8 * jitter(&mut rng), events: Vec::new() })
        .collect();
    Trace { name: "constant".into(), seed, steps }
}

/// Two sinusoidal day cycles (~0.4×..1.3×) with a mid-trace outage of
/// the cluster's first machine (the profile-fastest one, which the
/// scheduler loads heavily), a favorable drift episode on the heaviest
/// task type, and its late reversal.
pub fn diurnal(top: &Topology, cluster: &Cluster, steps: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let n = steps.max(8);
    let victim = cluster.machines[0].name.clone();
    let victim_type = cluster.types[cluster.machines[0].type_id].name.clone();
    let heavy_task = top.components.last().expect("topology has components").task_type.clone();
    let drift_type = cluster.types.last().expect("cluster has types").name.clone();

    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // two full cycles over the trace
        let phase = 4.0 * std::f64::consts::PI * i as f64 / n as f64;
        let offered = ((0.85 + 0.45 * phase.sin()) * jitter(&mut rng)).max(0.05);
        let mut events = Vec::new();
        if i == n / 4 {
            events.push(ClusterEvent::Drift {
                task_type: heavy_task.clone(),
                machine_type: drift_type.clone(),
                factor: 0.8,
            });
        }
        if i == n / 3 {
            events.push(ClusterEvent::Leave { machine: victim.clone() });
        }
        if i == 2 * n / 3 {
            events.push(ClusterEvent::Join {
                machine: victim.clone(),
                machine_type: victim_type.clone(),
            });
        }
        if i == 7 * n / 8 {
            events.push(ClusterEvent::Drift {
                task_type: heavy_task.clone(),
                machine_type: drift_type.clone(),
                factor: 1.25,
            });
        }
        out.push(TraceStep { t: i as f64, offered, events });
    }
    Trace { name: "diurnal".into(), seed, steps: out }
}

/// Linear ramp 0.3× → 1.4× with a machine join (same type as the
/// cluster's first machine) at the midpoint — the capacity expansion a
/// static schedule can never use.
pub fn ramp(cluster: &Cluster, steps: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let n = steps.max(4);
    let join_type = cluster.types[cluster.machines[0].type_id].name.clone();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let frac = i as f64 / (n - 1) as f64;
        let offered = ((0.3 + 1.1 * frac) * jitter(&mut rng)).max(0.05);
        let mut events = Vec::new();
        if i == n / 2 {
            events.push(ClusterEvent::Join {
                machine: "elastic-0".into(),
                machine_type: join_type.clone(),
            });
        }
        out.push(TraceStep { t: i as f64, offered, events });
    }
    Trace { name: "ramp".into(), seed, steps: out }
}

/// ~0.55× baseline with seeded flash crowds — short windows at
/// 1.05×–1.45× — plus one leave/rejoin churn pair of the cluster's
/// first (profile-fastest, hence heavily loaded) machine.  One flash
/// crowd is guaranteed to land inside the outage window regardless of
/// seed, so policies that cannot re-plan around the dead machine are
/// exposed on every seed.
pub fn bursty(cluster: &Cluster, steps: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let n = steps.max(12);

    // one churn pair at a seeded point in the first half
    let victim = cluster.machines[0].name.clone();
    let victim_type = cluster.types[cluster.machines[0].type_id].name.clone();
    let leave_at = n / 4 + rng.range(0, n / 4);
    let rejoin_at = leave_at + n / 6;

    // flash-crowd schedule: expected ~4 random bursts, plus one pinned
    // inside the outage
    let mut boost = vec![1.0f64; n];
    let mut i = 0usize;
    while i < n {
        if rng.chance(4.0 / n as f64) {
            let len = rng.range(n / 25 + 1, n / 12 + 2);
            let amp = rng.range_f64(1.9, 2.6); // × the 0.55 baseline
            for b in boost.iter_mut().skip(i).take(len) {
                *b = amp;
            }
            i += len;
        } else {
            i += 1;
        }
    }
    for b in boost.iter_mut().skip(leave_at + 1).take(n / 12 + 1) {
        *b = 2.4;
    }

    let mut out = Vec::with_capacity(n);
    for (i, amp) in boost.iter().enumerate() {
        let offered = (0.55 * amp * jitter(&mut rng)).max(0.05);
        let mut events = Vec::new();
        if i == leave_at {
            events.push(ClusterEvent::Leave { machine: victim.clone() });
        }
        if i == rejoin_at {
            events.push(ClusterEvent::Join {
                machine: victim.clone(),
                machine_type: victim_type.clone(),
            });
        }
        out.push(TraceStep { t: i as f64, offered, events });
    }
    Trace { name: "bursty".into(), seed, steps: out }
}

/// The cluster-event backbone of a fleet run: correlated rack outages
/// and a flapping machine, over a flat 1.0× offered rate (the fleet
/// runner overlays its own per-tenant rate profiles — this trace only
/// models the world changing).
///
/// Racks are machine-name prefixes (the part before the final `-`, as
/// [`crate::cluster::scenarios::fleet`] names them).  Each storm takes
/// a whole rack down at once — every member leaves in one step — and
/// the rack returns a seeded number of steps later.  Rack 0 never
/// storms (the cluster is never emptied) but donates its last machine
/// as the flapper, which cycles leave/rejoin through the second half
/// of the trace.  Storms never overlap on the same rack, so every
/// leave addresses a live machine and every join a missing one.
pub fn fleet_storm(cluster: &Cluster, steps: usize, seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let n = steps.max(20);

    // racks in first-seen order: (prefix, members with their type names)
    let rack_of = |name: &str| -> String {
        name.rsplit_once('-').map_or(name, |(r, _)| r).to_string()
    };
    let mut racks: Vec<(String, Vec<(String, String)>)> = Vec::new();
    for m in &cluster.machines {
        let rack = rack_of(&m.name);
        let ty = cluster.types[m.type_id].name.clone();
        match racks.iter_mut().find(|(r, _)| *r == rack) {
            Some((_, members)) => members.push((m.name.clone(), ty)),
            None => racks.push((rack, vec![(m.name.clone(), ty)])),
        }
    }

    let mut events: Vec<Vec<ClusterEvent>> = vec![Vec::new(); n];

    // correlated rack outages (rack 0 exempt, no overlap per rack)
    if racks.len() > 1 {
        let n_storms = (n / 40).max(1);
        let mut down_until = vec![0usize; racks.len()];
        for _ in 0..n_storms {
            let rack = rng.range(1, racks.len() - 1);
            let len = rng.range(n / 20 + 2, n / 10 + 4);
            let latest = n.saturating_sub(len + 2).max(n / 10 + 1);
            let start = rng.range(n / 10, latest);
            if start < down_until[rack] || start + len >= n {
                continue; // rack still out, or the outage would never heal
            }
            down_until[rack] = start + len + 1;
            for (name, _) in &racks[rack].1 {
                events[start].push(ClusterEvent::Leave { machine: name.clone() });
            }
            for (name, ty) in &racks[rack].1 {
                events[start + len].push(ClusterEvent::Join {
                    machine: name.clone(),
                    machine_type: ty.clone(),
                });
            }
        }
    }

    // one flapping machine: rapid leave/rejoin cycles late in the trace
    if cluster.machines.len() > 1 {
        let (flapper, flapper_type) = racks[0].1.last().cloned().unwrap_or_else(|| {
            (
                cluster.machines[0].name.clone(),
                cluster.types[cluster.machines[0].type_id].name.clone(),
            )
        });
        let period = (n / 30).max(4);
        let mut at = 2 * n / 5;
        for _ in 0..4 {
            if at + 2 >= n {
                break;
            }
            events[at].push(ClusterEvent::Leave { machine: flapper.clone() });
            events[at + 2].push(ClusterEvent::Join {
                machine: flapper.clone(),
                machine_type: flapper_type.clone(),
            });
            at += period;
        }
    }

    let steps = events
        .into_iter()
        .enumerate()
        .map(|(i, events)| TraceStep { t: i as f64, offered: 1.0, events })
        .collect();
    Trace { name: "fleet-storm".into(), seed, steps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn setup() -> (Topology, Cluster) {
        let (cluster, _) = presets::paper_cluster();
        (benchmarks::linear(), cluster)
    }

    #[test]
    fn by_name_covers_all_names() {
        let (top, cluster) = setup();
        for name in NAMES {
            let t = by_name(name, &top, &cluster, 100, 7).unwrap();
            assert_eq!(t.name, name);
            assert_eq!(t.n_steps(), 100);
        }
        assert!(by_name("nope", &top, &cluster, 100, 7).is_none());
    }

    #[test]
    fn deterministic_by_seed() {
        let (top, cluster) = setup();
        for name in NAMES {
            let a = by_name(name, &top, &cluster, 200, 42).unwrap();
            let b = by_name(name, &top, &cluster, 200, 42).unwrap();
            for (sa, sb) in a.steps.iter().zip(&b.steps) {
                assert_eq!(sa.offered, sb.offered, "{name}");
                assert_eq!(sa.events, sb.events, "{name}");
            }
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (top, cluster) = setup();
        let a = bursty(&cluster, 300, 1);
        let b = bursty(&cluster, 300, 2);
        assert!(
            a.steps.iter().zip(&b.steps).any(|(x, y)| x.offered != y.offered),
            "seeds 1 and 2 produced identical bursty traces"
        );
        let _ = top;
    }

    #[test]
    fn diurnal_has_outage_drift_and_rejoin() {
        let (top, cluster) = setup();
        let t = diurnal(&top, &cluster, 240, 9);
        let events = || t.steps.iter().flat_map(|s| &s.events);
        let leaves = events().filter(|e| matches!(e, ClusterEvent::Leave { .. }));
        let joins = events().filter(|e| matches!(e, ClusterEvent::Join { .. }));
        let drifts = events().filter(|e| matches!(e, ClusterEvent::Drift { .. }));
        assert_eq!(leaves.count(), 1);
        assert_eq!(joins.count(), 1);
        assert_eq!(drifts.count(), 2);
        for s in &t.steps {
            assert!(s.offered > 0.0 && s.offered < 1.45, "offered {}", s.offered);
        }
    }

    #[test]
    fn ramp_rises_and_expands() {
        let (_, cluster) = setup();
        let t = ramp(&cluster, 200, 11);
        assert!(t.steps.last().unwrap().offered > t.steps[0].offered * 2.0);
        assert!(t
            .steps
            .iter()
            .flat_map(|s| &s.events)
            .any(|e| matches!(e, ClusterEvent::Join { .. })));
    }

    #[test]
    fn bursty_always_has_a_flash_crowd_and_churn() {
        let (_, cluster) = setup();
        for seed in [0, 1, 2, 3, 99] {
            let t = bursty(&cluster, 300, seed);
            assert!(
                t.steps.iter().any(|s| s.offered > 1.0),
                "seed {seed}: no flash crowd above 1.0x"
            );
            assert!(
                t.steps
                    .iter()
                    .flat_map(|s| &s.events)
                    .any(|e| matches!(e, ClusterEvent::Leave { .. })),
                "seed {seed}: no churn"
            );
        }
    }

    #[test]
    fn fleet_storm_outages_are_correlated_and_heal() {
        let (cluster, _) = crate::cluster::scenarios::fleet(200, 20);
        for seed in [0, 7, 13, 42] {
            let t = fleet_storm(&cluster, 160, seed);
            let mut down = std::collections::BTreeSet::new();
            for s in &t.steps {
                for e in &s.events {
                    match e {
                        ClusterEvent::Leave { machine } => {
                            assert!(down.insert(machine.clone()), "seed {seed}: double leave");
                        }
                        ClusterEvent::Join { machine, .. } => {
                            assert!(down.remove(machine), "seed {seed}: join of live machine");
                        }
                        ClusterEvent::Drift { .. } => {}
                    }
                }
            }
            assert!(down.is_empty(), "seed {seed}: outages never healed: {down:?}");
            // at least one whole-rack storm (all 20 members in one step)
            assert!(
                t.steps.iter().any(|s| {
                    s.events
                        .iter()
                        .filter(|e| matches!(e, ClusterEvent::Leave { .. }))
                        .count()
                        >= 20
                }),
                "seed {seed}: no correlated rack outage"
            );
        }
    }

    #[test]
    fn constant_is_flat_and_eventless() {
        let t = constant(50, 5);
        assert_eq!(t.n_events(), 0);
        for s in &t.steps {
            assert!((s.offered - 0.8).abs() < 0.02);
        }
    }
}
