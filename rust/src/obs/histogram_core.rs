//! The lock-free histogram core: every atomic operation of
//! [`Histogram`], with its sync primitives imported through
//! `super::sync_shim` so the identical source file compiles against
//! `std::sync::atomic` here and against `loom::sync::atomic` inside the
//! `tools/loom` model-checking crate (which re-includes this file by
//! `#[path]`).  Keep this file free of `crate::`/`std::sync` paths and
//! of anything but the histogram itself — the RAII [`super::Span`]
//! timer and the unit tests live in [`super::histogram`].
//!
//! Min/max tracking uses explicit compare-exchange loops
//! ([`atomic_min`]/[`atomic_max`]) rather than `fetch_min`/`fetch_max`
//! so the core sticks to the primitive op set loom models.

use super::sync_shim::{AtomicU64, Ordering};

/// Sub-buckets per octave (power of two so the index math is exact).
const SUB: f64 = 64.0;
/// Octaves below 1.0 covered by the grid.
const OCTAVES_BELOW: f64 = 32.0;
/// Total bucket count: 64 octaves x 64 sub-buckets.
pub const N_BUCKETS: usize = 4096;

/// Lock-free log-bucketed histogram of non-negative `f64` samples.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Exact sum, stored as `f64` bits and updated with a CAS loop.
    sum_bits: AtomicU64,
    /// Exact extremes as `f64` bits; valid because non-negative IEEE-754
    /// doubles order the same as their bit patterns.
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::with_buckets(N_BUCKETS)
    }
}

fn bucket_of(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        return if v.is_finite() { 0 } else { N_BUCKETS - 1 };
    }
    let idx = (v.log2() + OCTAVES_BELOW) * SUB;
    (idx.max(0.0) as usize).min(N_BUCKETS - 1)
}

/// Geometric midpoint of bucket `i` — the representative a quantile
/// lookup reports before clamping to the observed `[min, max]`.
fn representative(i: usize) -> f64 {
    ((i as f64 + 0.5) / SUB - OCTAVES_BELOW).exp2()
}

/// `cell = min(cell, v)` for bit-ordered words, via compare-exchange.
fn atomic_min(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while v < cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// `cell = max(cell, v)` for bit-ordered words, via compare-exchange.
fn atomic_max(cell: &AtomicU64, v: u64) {
    let mut cur = cell.load(Ordering::Relaxed);
    while v > cur {
        match cell.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// `cell += add` where `cell` holds `f64` bits, via compare-exchange.
fn atomic_add_f64(cell: &AtomicU64, add: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + add).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// A histogram with a reduced grid of `n` buckets (samples landing
    /// past the grid clamp into the last bucket).  Production code uses
    /// the full [`N_BUCKETS`] grid via [`new`](Self::new); the loom
    /// models use tiny grids so the model checker tracks few atomics.
    pub fn with_buckets(n: usize) -> Self {
        Histogram {
            buckets: (0..n.max(1)).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Record one sample.  Negative samples clamp to bucket zero; the
    /// exact sum/min/max still see the clamped value so the invariants
    /// `min <= mean <= max` and `p50 <= max` hold by construction.
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { return };
        let i = bucket_of(v).min(self.buckets.len() - 1);
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        atomic_min(&self.min_bits, v.to_bits());
        atomic_max(&self.max_bits, v.to_bits());
        atomic_add_f64(&self.sum_bits, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Exact mean; 0.0 with no samples.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Exact minimum; 0.0 with no samples.
    pub fn min(&self) -> f64 {
        let v = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        if v.is_finite() {
            v
        } else {
            0.0
        }
    }

    /// Exact maximum; 0.0 with no samples.
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`) over the bucket grid.
    /// The bucket's geometric midpoint is clamped to the observed
    /// `[min, max]`, so quantiles are monotone in `q`, `p100 == max`
    /// exactly, and every quantile is positive when `min > 0`.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return representative(i).clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Fold another histogram into this one (bucket-wise add, exact
    /// sum/extremes combine).  Used by shard-and-merge consumers.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        let n = other.count.load(Ordering::Relaxed);
        if n == 0 {
            return;
        }
        self.count.fetch_add(n, Ordering::Relaxed);
        atomic_min(&self.min_bits, other.min_bits.load(Ordering::Relaxed));
        atomic_max(&self.max_bits, other.max_bits.load(Ordering::Relaxed));
        atomic_add_f64(&self.sum_bits, other.sum());
    }
}
