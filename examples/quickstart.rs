//! Quickstart: schedule the Linear micro-benchmark on the paper's
//! Table-2 heterogeneous cluster through the `Problem`/`ScheduleRequest`
//! API and print the resulting execution topology graph.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hstorm::cluster::presets;
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::topology::benchmarks;

fn main() -> hstorm::Result<()> {
    let top = benchmarks::linear();
    let (cluster, profiles) = presets::paper_cluster();

    println!("== hstorm quickstart ==");
    println!("topology '{}' ({} components), cluster '{}' ({} machines)\n",
        top.name, top.n_components(), cluster.name, cluster.n_machines());

    // One Problem, validated once; policies resolve by name from the
    // registry and serve requests against it.
    let problem = Problem::new(&top, &cluster, &profiles)?;
    let req = ScheduleRequest::max_throughput();

    // The paper's scheduler: builds the ETG *and* the assignment.
    let ours = registry::create("hetero", &PolicyParams::default())?.schedule(&problem, &req)?;
    println!("proposed scheduler:");
    println!("  certified input rate  {:.1} tuple/s", ours.rate);
    println!("  predicted throughput  {:.1} tuple/s", ours.eval.throughput);
    println!("  provenance            {}", ours.provenance.render());
    print!("{}", ours.describe(&top, &cluster));

    // Storm's default: same instance counts (fair-comparison protocol
    // built into the registry's "default" policy), Round-Robin placement.
    let default = registry::create("default", &PolicyParams::default())?.schedule(&problem, &req)?;
    println!("\nStorm default scheduler (same ETG, Round-Robin):");
    println!("  max stable rate       {:.1} tuple/s", default.rate);
    println!("  predicted throughput  {:.1} tuple/s", default.eval.throughput);

    let gain = (ours.eval.throughput - default.eval.throughput) / default.eval.throughput * 100.0;
    println!("\n=> heterogeneity-aware scheduling gains {gain:+.1}% throughput (paper: +7%..+44%)");
    Ok(())
}
