//! Heterogeneous cluster substrate (paper §4.1, Tables 2 & 4).
//!
//! A cluster is a set of worker machines, each of a *machine type*
//! (processor generation).  In the paper's model each worker node runs
//! one worker process with a CPU budget `MAC = 100` (%); heterogeneity
//! enters exclusively through the per-type profile table `e_ij`/`MET_ij`
//! ([`profile::ProfileDb`]).

pub mod presets;
pub mod profile;
pub mod scenarios;

use crate::{Error, Result};

/// A processor generation ("Pentium Dual-Core 2.6", "Core i5 2.5", ...).
#[derive(Debug, Clone)]
pub struct MachineType {
    pub name: String,
    /// Free-text hardware description (Table 2 rows).
    pub description: String,
}

/// One worker node.
#[derive(Debug, Clone)]
pub struct Machine {
    /// Unique name ("m1", "i5-0", ...).
    pub name: String,
    /// Index into [`Cluster::types`].
    pub type_id: usize,
    /// Available CPU capacity (MAC), percent.  100 unless the node is
    /// partially reserved.
    pub cap: f64,
}

/// A heterogeneous cluster: machine types + worker machines.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub name: String,
    pub types: Vec<MachineType>,
    pub machines: Vec<Machine>,
}

impl Cluster {
    pub fn new(name: impl Into<String>) -> Self {
        Cluster { name: name.into(), types: Vec::new(), machines: Vec::new() }
    }

    /// Register a machine type; returns its id.
    pub fn add_type(&mut self, name: &str, description: &str) -> usize {
        self.types.push(MachineType { name: name.into(), description: description.into() });
        self.types.len() - 1
    }

    /// Add `count` identical machines of `type_id`, named `prefix-k`.
    pub fn add_machines(&mut self, type_id: usize, count: usize, prefix: &str) {
        for k in 0..count {
            self.machines.push(Machine {
                name: format!("{prefix}-{k}"),
                type_id,
                cap: 100.0,
            });
        }
    }

    pub fn n_machines(&self) -> usize {
        self.machines.len()
    }

    pub fn n_types(&self) -> usize {
        self.types.len()
    }

    /// Machine-type name of machine `m`.
    pub fn type_name(&self, m: usize) -> &str {
        &self.types[self.machines[m].type_id].name
    }

    /// Count machines per type — `N_{T_i}` in the paper.
    pub fn machines_per_type(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.types.len()];
        for m in &self.machines {
            counts[m.type_id] += 1;
        }
        counts
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        if self.machines.is_empty() {
            return Err(Error::Cluster("no machines".into()));
        }
        if self.types.is_empty() {
            return Err(Error::Cluster("no machine types".into()));
        }
        for m in &self.machines {
            if m.type_id >= self.types.len() {
                return Err(Error::Cluster(format!(
                    "machine '{}' references unknown type {}",
                    m.name, m.type_id
                )));
            }
            if !(0.0..=100.0).contains(&m.cap) {
                return Err(Error::Cluster(format!(
                    "machine '{}' capacity {} outside [0,100]",
                    m.name, m.cap
                )));
            }
        }
        let mut names: Vec<&str> = self.machines.iter().map(|m| m.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.machines.len() {
            return Err(Error::Cluster("duplicate machine names".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cluster {
        let mut c = Cluster::new("test");
        let a = c.add_type("fast", "fast cpu");
        let b = c.add_type("slow", "slow cpu");
        c.add_machines(a, 2, "fast");
        c.add_machines(b, 1, "slow");
        c
    }

    #[test]
    fn build_and_validate() {
        let c = small();
        c.validate().unwrap();
        assert_eq!(c.n_machines(), 3);
        assert_eq!(c.machines_per_type(), vec![2, 1]);
        assert_eq!(c.type_name(2), "slow");
    }

    #[test]
    fn empty_rejected() {
        assert!(Cluster::new("x").validate().is_err());
    }

    #[test]
    fn bad_type_id_rejected() {
        let mut c = small();
        c.machines[0].type_id = 9;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bad_cap_rejected() {
        let mut c = small();
        c.machines[0].cap = 150.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut c = small();
        let n = c.machines[0].name.clone();
        c.machines[1].name = n;
        assert!(c.validate().is_err());
    }
}
