//! Repo-local static lints the stock toolchain cannot express: the
//! determinism and robustness rules the scheduler's bit-identical
//! replay contract depends on.  CI runs this blocking (`cargo run
//! --release -p hstorm-lint`); it exits nonzero on any unsuppressed
//! hit *or* any stale allowlist entry.
//!
//! Rules (applied to non-test, non-comment lines of `rust/src`):
//!
//! * `wall-clock` — `Instant::now(` / `SystemTime::now(`: schedules
//!   must stay time-independent.  Whole layers whose *job* is the
//!   clock (telemetry in `obs/`, the real-time executor in `engine/`)
//!   are exempted via allowlist zones rather than per-file entries.
//! * `nondeterministic-rng` — `thread_rng` / `from_entropy` /
//!   `rand::random`: every random stream must be seeded
//!   (`util::rng::Rng`) so runs replay.
//! * `hash-iteration` — any `HashMap` / `HashSet`: iteration order is
//!   randomized per process and leaks into serialized output and
//!   tie-breaks; the repo-wide policy is `BTreeMap`/`BTreeSet`.
//! * `library-unwrap` — `.unwrap()` or `.expect("` in library code:
//!   fallible paths return `Error` instead of aborting.
//! * `float-eq` — `==`/`!=` against a float literal: scoring paths
//!   compare within tolerances, not exactly.
//!
//! Suppressions live in `tools/lint/allowlist.txt`:
//!
//! * `rule path # rationale` — matched per (rule, file) so entries
//!   survive line drift; the rationale is mandatory documentation.
//! * `zone rule prefix/ # rationale` — exempts every file under the
//!   prefix from one rule, for directories whose whole purpose makes
//!   the rule inapplicable (e.g. `obs/` and the clock).  Zones go
//!   stale like entries: a zone with no remaining hit fails the lint.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const RULES: &[&str] =
    &["wall-clock", "nondeterministic-rng", "hash-iteration", "library-unwrap", "float-eq"];

struct Hit {
    rule: &'static str,
    file: String,
    line_no: usize,
    line: String,
}

/// `==` or `!=` adjacent to a float literal (a token containing a
/// decimal point).  Token-level, both sides of the operator.
fn float_eq_hit(line: &str) -> bool {
    let bytes = line.as_bytes();
    let mut i = 0;
    while i + 1 < bytes.len() {
        let op = &bytes[i..i + 2];
        let standalone = (i == 0 || !matches!(bytes[i - 1], b'=' | b'!' | b'<' | b'>'))
            && bytes.get(i + 2) != Some(&b'=');
        if (op == b"==" || op == b"!=") && standalone {
            if float_literal_follows(&line[i + 2..]) || float_literal_precedes(&line[..i]) {
                return true;
            }
            i += 2;
        } else {
            i += 1;
        }
    }
    false
}

fn float_literal_follows(rest: &str) -> bool {
    let s = rest.trim_start().trim_start_matches('-');
    let mut saw_digit = false;
    for c in s.chars() {
        if c.is_ascii_digit() {
            saw_digit = true;
        } else {
            return saw_digit && c == '.';
        }
    }
    false
}

fn float_literal_precedes(before: &str) -> bool {
    let s = before.trim_end();
    // the preceding token must end like `<digits>.<digits>`; requiring a
    // digit on *both* sides of the dot keeps tuple-field access
    // (`pair.0 == other.0`) from reading as a float literal
    let tail: String = s.chars().rev().take_while(|c| c.is_ascii_digit() || *c == '.').collect();
    tail.contains('.')
        && tail.starts_with(|c: char| c.is_ascii_digit())
        && tail.ends_with(|c: char| c.is_ascii_digit())
}

fn scan_file(root: &Path, rel: &str, hits: &mut Vec<Hit>) {
    let text = match fs::read_to_string(root.join(rel)) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("hstorm-lint: cannot read rust/src/{rel}: {e}");
            std::process::exit(2);
        }
    };
    let mut in_test = false;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.contains("#[cfg(test)]") {
            // repo convention: the test module is the tail of the file
            in_test = true;
        }
        if in_test || line.starts_with("//") {
            continue;
        }
        let mut push = |rule: &'static str| {
            hits.push(Hit {
                rule,
                file: rel.to_string(),
                line_no: idx + 1,
                line: line.to_string(),
            })
        };
        let clock = line.contains("Instant::now(") || line.contains("SystemTime::now(");
        if clock {
            push("wall-clock");
        }
        let rng = line.contains("thread_rng")
            || line.contains("from_entropy")
            || line.contains("rand::random");
        if rng {
            push("nondeterministic-rng");
        }
        if line.contains("HashMap") || line.contains("HashSet") {
            push("hash-iteration");
        }
        if line.contains(".unwrap()") || line.contains(".expect(\"") {
            push("library-unwrap");
        }
        if float_eq_hit(line) {
            push("float-eq");
        }
    }
}

fn collect_sources(dir: &Path, base: &Path, out: &mut Vec<String>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok().map(|e| e.path())).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_sources(&p, base, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            if let Ok(rel) = p.strip_prefix(base) {
                out.push(rel.to_string_lossy().replace('\\', "/"));
            }
        }
    }
}

fn main() -> ExitCode {
    let repo_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let src_root = repo_root.join("rust/src");
    let allow_path = repo_root.join("tools/lint/allowlist.txt");

    let mut files = Vec::new();
    collect_sources(&src_root, &src_root, &mut files);
    if files.is_empty() {
        eprintln!("hstorm-lint: no sources under {}", src_root.display());
        return ExitCode::FAILURE;
    }

    let mut hits = Vec::new();
    for rel in &files {
        scan_file(&src_root, rel, &mut hits);
    }

    // allowlist: `rule path # rationale` entries matched per (rule,
    // file), plus `zone rule prefix/ # rationale` directory exemptions
    let mut allowed: BTreeSet<(String, String)> = BTreeSet::new();
    let mut zones: BTreeSet<(String, String)> = BTreeSet::new();
    let allow_text = fs::read_to_string(&allow_path).unwrap_or_default();
    let mut malformed = 0;
    for (idx, raw) in allow_text.lines().enumerate() {
        let entry = raw.split('#').next().unwrap_or("").trim();
        if entry.is_empty() {
            continue;
        }
        let toks: Vec<&str> = entry.split_whitespace().collect();
        match toks.as_slice() {
            [rule, path] if raw.contains('#') && RULES.contains(rule) => {
                allowed.insert((rule.to_string(), path.to_string()));
            }
            ["zone", rule, prefix] if raw.contains('#') && RULES.contains(rule) => {
                zones.insert((rule.to_string(), prefix.to_string()));
            }
            _ => {
                let n = idx + 1;
                eprintln!(
                    "allowlist.txt:{n}: malformed (want `rule path # rationale` or \
                     `zone rule prefix/ # rationale`): {raw}"
                );
                malformed += 1;
            }
        }
    }

    let mut used: BTreeSet<(String, String)> = BTreeSet::new();
    let mut used_zones: BTreeSet<(String, String)> = BTreeSet::new();
    let mut reported = 0;
    let mut suppressed = 0;
    for h in &hits {
        let key = (h.rule.to_string(), h.file.clone());
        // exact entries are matched before zones so both kinds report
        // staleness independently
        if allowed.contains(&key) {
            used.insert(key);
            suppressed += 1;
        } else if let Some(z) =
            zones.iter().find(|(rule, prefix)| *rule == h.rule && h.file.starts_with(prefix))
        {
            used_zones.insert(z.clone());
            suppressed += 1;
        } else {
            println!("rust/src/{}:{}: [{}] {}", h.file, h.line_no, h.rule, h.line);
            reported += 1;
        }
    }

    let mut stale = 0;
    for (rule, path) in allowed.difference(&used) {
        eprintln!("allowlist.txt: stale entry `{rule} {path}` (no remaining hit — delete it)");
        stale += 1;
    }
    for (rule, prefix) in zones.difference(&used_zones) {
        eprintln!(
            "allowlist.txt: stale zone `zone {rule} {prefix}` (no remaining hit — delete it)"
        );
        stale += 1;
    }

    if reported > 0 || stale > 0 || malformed > 0 {
        eprintln!("hstorm-lint: {reported} violation(s), {stale} stale, {malformed} malformed");
        ExitCode::FAILURE
    } else {
        let n = files.len();
        println!("hstorm-lint: clean — {n} files scanned, {suppressed} allowlisted hit(s)");
        ExitCode::SUCCESS
    }
}
