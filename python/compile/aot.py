"""AOT entry point: lower the L2 model to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the published ``xla`` crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts (consumed by rust/src/runtime/):
  scorer_b256.hlo.txt  — evaluate_placements, B=256 (optimal scheduler)
  scorer_b1.hlo.txt    — evaluate_placements, B=1   (heuristic inner loop)
  work.hlo.txt         — bolt_work, the engine's PJRT compute-mode body
  dims.json            — the dims the artifacts were lowered with

Run via ``make artifacts`` (no-op if inputs unchanged):
  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dims
from .model import bolt_work, evaluate_placements


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_scorer(batch: int) -> str:
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    args = (
        s((batch, dims.C, dims.M), f32),  # x
        s((dims.C, dims.C), f32),         # adj
        s((dims.C,), f32),                # alpha
        s((dims.C,), f32),                # src_mask
        s((batch,), f32),                 # r0
        s((dims.C, dims.M), f32),         # e_m
        s((dims.C, dims.M), f32),         # met_m
        s((dims.M,), f32),                # cap
        s((dims.C,), f32),                # active
    )
    fn = functools.partial(evaluate_placements, depth=dims.DEPTH,
                           interpret=True)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_work() -> str:
    arg = jax.ShapeDtypeStruct((dims.WORK_N,), jnp.float32)
    return to_hlo_text(jax.jit(bolt_work).lower(arg))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-file target (writes scorer_b256)")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    emitted = {}
    for name, text in (
        (f"scorer_b{dims.B_BATCH}.hlo.txt", lower_scorer(dims.B_BATCH)),
        (f"scorer_b{dims.B_ONE}.hlo.txt", lower_scorer(dims.B_ONE)),
        ("work.hlo.txt", lower_work()),
    ):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        emitted[name] = len(text)
        print(f"wrote {len(text):>9} chars to {path}")

    if args.out:  # Makefile stamp target
        with open(args.out, "w") as f:
            f.write(open(os.path.join(out_dir,
                    f"scorer_b{dims.B_BATCH}.hlo.txt")).read())

    meta = {
        "C": dims.C, "M": dims.M, "DEPTH": dims.DEPTH,
        "B_BATCH": dims.B_BATCH, "B_ONE": dims.B_ONE,
        "CAP": dims.CAP, "WORK_N": dims.WORK_N,
        "artifacts": emitted,
    }
    with open(os.path.join(out_dir, "dims.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote dims.json: {meta}")


if __name__ == "__main__":
    main()
