//! # hstorm — heterogeneity-aware stream scheduling
//!
//! A production-shaped reproduction of Nasiri, Nasehi, Divband & Goudarzi,
//! *"A Scheduling Algorithm to Maximize Storm Throughput in Heterogeneous
//! Cluster"* (2020), as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: topology model, heterogeneous
//!   cluster model, the paper's scheduler (Alg. 1 + Alg. 2), the Storm
//!   default Round-Robin baseline, the optimal exhaustive comparator, a
//!   threaded stream-processing engine (the "real cluster" substitute —
//!   see *Dataplane* below), two
//!   large-scale simulators (the closed-form analytic model and a
//!   discrete-event tuple-level simulator, [`simulator::event`], that
//!   adds latency percentiles, queue dynamics and backpressure
//!   verdicts), an online control plane ([`controller`]) that replays
//!   workload traces over virtual time and keeps the topology scheduled
//!   as machines churn and profiles drift, and the experiment harness
//!   that regenerates every figure/table of the paper's evaluation.
//! * **L2 (python/compile/model.py)** — the placement-evaluation model
//!   (rate propagation, eq. 6; CPU prediction, eq. 5; feasibility +
//!   throughput) as a JAX graph, AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the scoring
//!   contraction and the propagation step, validated against a pure-jnp
//!   oracle.
//!
//! Python never runs at schedule or serve time: `make artifacts` lowers
//! the model once; [`runtime`] loads and executes the HLO via PJRT.
//! PJRT execution is optional — it lives behind the off-by-default
//! `pjrt` cargo feature (the default build is pure `std` and evaluates
//! everything through the exact native mirror; see the [`runtime`]
//! module docs for how the in-repo `xla` stub keeps the feature
//! type-checking outside the vendor image).
//!
//! ## Quickstart
//!
//! Scheduling is one API everywhere: build a [`scheduler::Problem`]
//! (the topology + cluster + profiles triple, validated once, caching
//! the expanded evaluation tables), resolve a policy by name through
//! [`scheduler::registry`], and issue a [`scheduler::ScheduleRequest`]
//! (an objective plus constraints):
//!
//! ```no_run
//! use hstorm::cluster::presets;
//! use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
//! use hstorm::topology::benchmarks;
//!
//! let top = benchmarks::linear();
//! let (cluster, profiles) = presets::paper_cluster();
//! let problem = Problem::new(&top, &cluster, &profiles).unwrap();
//! let sched = registry::create("hetero", &PolicyParams::default()).unwrap();
//! let out = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
//! println!("rate={} thpt={} [{}]", out.rate, out.eval.throughput, out.provenance.render());
//! ```
//!
//! Constraints ride on the request — rescheduling around a drained
//! machine is the same call with that machine excluded:
//!
//! ```no_run
//! # use hstorm::cluster::presets;
//! # use hstorm::scheduler::{registry, Constraints, Objective, PolicyParams, Problem, ScheduleRequest};
//! # use hstorm::topology::benchmarks;
//! # let top = benchmarks::linear();
//! # let (cluster, profiles) = presets::paper_cluster();
//! # let problem = Problem::new(&top, &cluster, &profiles).unwrap();
//! # let sched = registry::create("hetero", &PolicyParams::default()).unwrap();
//! let req = ScheduleRequest::new(Objective::MaxThroughput)
//!     .with_constraints(Constraints::new().exclude_machine("i3-0").reserve_headroom(10.0));
//! let out = sched.schedule(&problem, &req).unwrap();
//! assert_eq!(out.placement.tasks_on(1), 0); // nothing lands on i3-0
//! ```
//!
//! Objectives beyond the paper's max-throughput:
//! `Objective::MinMachinesAtRate(r)` packs the fewest machines that
//! still sustain `r` tuples/s, `Objective::BalancedUtilization` breaks
//! throughput ties toward the smallest utilization spread — see the
//! [`scheduler::request`] module docs for exact semantics.
//!
//! **Budgeted, anytime search (API note).**  As of the search-portfolio
//! release a request may also carry a [`scheduler::SearchBudget`]
//! (`.with_budget(...)`: max candidate evaluations, max kernel virtual
//! ops, optional target gap).  Existing call sites need no change — the
//! default budget is unlimited and every prior policy behaves exactly
//! as before — but all policies now *honor* a budget when one is set,
//! and the search policies (`bnb`, `beam`, `anneal`, `portfolio` in the
//! registry) report how they stopped through three new
//! [`scheduler::Provenance`] fields: `bound` (admissible upper bound on
//! the achievable rate), `optimality_gap` (`(bound − rate)/rate`, `0`
//! whenever the space was exhausted) and `terminated`
//! (`Exhausted`/`Budget`/`TargetGap`).  Requests may also seed a
//! `.with_warm_start(placement)` incumbent — the controller does this
//! on every re-plan so budgeted searches refine instead of restart.
//! The deprecated registry aliases `rr` and `exhaustive` still resolve
//! (to `default` and `optimal`) but journal a `deprecated_alias` event
//! once per process; see [`scheduler::search`] for the certificate
//! math.
//!
//! ## Multi-tenant workloads
//!
//! Many topologies share one cluster through a
//! [`scheduler::Workload`]: named tenants, each a (topology, profiles,
//! rate-weight) triple.  A [`scheduler::WorkloadProblem`] validates
//! every tenant once (per-tenant evaluators over a single shared
//! `Arc<Cluster>`), then any registry policy schedules them **jointly**
//! (all tenants co-planned at proportional weighted rates) or by
//! **incremental admission** (each tenant placed against the residual
//! capacity residents leave, residents untouched).  A one-tenant
//! workload is exactly the `Problem` path — identical placement,
//! identical certified rate:
//!
//! ```no_run
//! # use hstorm::cluster::presets;
//! # use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
//! # use hstorm::scheduler::{Workload, WorkloadProblem};
//! # use hstorm::topology::benchmarks;
//! # use std::sync::Arc;
//! let (cluster, profiles) = presets::paper_cluster();
//! let profiles = Arc::new(profiles);
//! let sched = registry::create("hetero", &PolicyParams::default()).unwrap();
//! let req = ScheduleRequest::max_throughput();
//!
//! // classic single-tenant path...
//! let problem = Problem::new(&benchmarks::linear(), &cluster, profiles.as_ref()).unwrap();
//! let solo = sched.schedule(&problem, &req).unwrap();
//!
//! // ...and the same topology as a one-tenant workload: same schedule
//! let wl = Workload::new("solo").tenant("only", benchmarks::linear(), profiles.clone(), 1.0);
//! let wp = WorkloadProblem::new(wl, &cluster).unwrap();
//! let ws = wp.schedule_joint(sched.as_ref(), &req).unwrap();
//! assert_eq!(ws.tenants[0].schedule.placement, solo.placement);
//!
//! // two tenants share the machines; tenant rates follow their weights
//! let wl = Workload::new("duo")
//!     .tenant("search", benchmarks::linear(), profiles.clone(), 1.0)
//!     .tenant("ads", benchmarks::rolling_count(), profiles.clone(), 2.0);
//! let wp = WorkloadProblem::new(wl, &cluster).unwrap();
//! let ws = wp.schedule_joint(sched.as_ref(), &req).unwrap();
//! println!("scale={} ads runs at {}", ws.scale, ws.tenant("ads").unwrap().schedule.rate);
//! ```
//!
//! The event simulator runs merged placements natively (co-located
//! tenants share each machine's round-robin server;
//! [`simulator::event::simulate_grouped`] reports per-tenant
//! throughput/latency/backpressure) and the control plane admits,
//! drains and re-plans tenants over per-tenant traces
//! ([`controller::workload::run_workload`]).
//!
//! ## Dataplane
//!
//! The [`engine`] module *executes* schedules on real threads — one
//! worker per scheduled machine — through a batched ring dataplane:
//! tuples move in `TupleBatch`es over bounded lock-free SPSC rings
//! (one per machine→machine edge), fan-out follows the eq.-6
//! fractional-α split per batch, and service is charged per batch as
//! `n · e_ij` by a calibrated spin-burner, so the per-tuple transport
//! cost is nanoseconds.  Backpressure is credit-based and lossless: a
//! ring's free slots are the credits, a full downstream ring parks the
//! producing task, and the stall propagates to the spout pacer
//! (reported as `credit_stalls`/`throttled`) — the engine never sheds.
//! `EngineConfig::time_scale` compresses profiled service times so one
//! machine reproduces cluster-scale rates (utilization, a wall-clock
//! ratio, stays comparable to eq. 5), and accounting is emit-epoch
//! exact (warmup/drain traffic never pollutes the measured window).
//! `hstorm run` is the CLI surface, `hstorm bench dataplane` writes
//! `BENCH_dataplane.json`, and `bench accuracy --mode execute`
//! re-grounds the paper's §6.2 accuracy claim on executed (not
//! simulated) utilization.  The legacy per-tuple channel engine
//! remains as `Dataplane::Legacy` for comparison; `cargo bench --bench
//! dataplane` races the two.
//!
//! ## Scoring engine
//!
//! Candidate scoring is incremental ([`predict::kernel`]): per-component
//! **row tables** hold each enumerated distribution's per-machine
//! `(a, b)` slope/intercept contribution, the exhaustive optimal search
//! composes candidates by pushing/popping rows into accumulators
//! (`O(nnz)` per step, closed-form `R0*` read off the running state) and
//! shards its outermost loop across threads with a deterministic merge —
//! identical schedule at any thread count — while the hetero refinement
//! and the control plane's breach check probe single-instance deltas in
//! `O(M)` through [`predict::kernel::DeltaEval`].  `hstorm bench
//! sched-perf` races the naive and incremental engines and writes the
//! machine-readable `BENCH_sched.json` (candidates/s, wall time,
//! speedups, same-schedule check per scenario).
//!
//! ## Observability
//!
//! The [`obs`] module is the cross-cutting telemetry layer: log-bucketed
//! [`obs::Histogram`]s (p50/p95/p99/max, mergeable), RAII [`obs::Span`]
//! timers, and a structured [`obs::Journal`] of typed decision events,
//! all hanging off the shared [`metrics::Registry`] so engine counters
//! and scheduler/controller/simulator telemetry export through one
//! snapshot (`hstorm metrics`, `--metrics-out FILE`).  Telemetry is
//! side-channel only — schedules, certified rates and reports are
//! bit-identical with it on or off ([`obs::set_enabled`]).  The journal
//! records:
//!
//! | event                  | emitted by            | payload                                  |
//! |------------------------|-----------------------|------------------------------------------|
//! | `search_started`       | every scheduler       | policy, components, machines             |
//! | `candidate_pruned`     | search engines        | policy, count, reason                    |
//! | `schedule_chosen`      | every scheduler       | policy, backend, rate, evaluated, pruned |
//! | `runner_up`            | hetero/optimal        | policy, label, rate                      |
//! | `breach_detected`      | controller            | policy, step, offered, capacity          |
//! | `replanned`            | controller, workload  | policy, step, cause, latency ms          |
//! | `admission_denied`     | workload controller   | tenant, step, reason                     |
//! | `admission_granted`    | workload controller   | tenant, step                             |
//! | `backpressure_verdict` | event simulator       | rate, backpressure, queue growth, shed   |
//! | `strategy_finished`    | search portfolio      | policy, strategy, rate, evaluated        |
//! | `deprecated_alias`     | policy registry       | alias, canonical (once per process)      |
//!
//! `hstorm explain` turns this into a decision story: the eq.-5
//! bottleneck chain (which component capped `R0*` on which machine,
//! per-machine headroom breakdown — [`obs::explain`]) plus, for
//! controller runs, the breach → re-plan timeline with latencies.
//!
//! ## Correctness & analysis
//!
//! The [`check`] module re-derives every schedule invariant **from
//! scratch** (raw profile-db lookups, not the cached evaluator or the
//! kernel accumulators) and is wired in three ways: `hstorm check` on
//! the CLI, a debug-build hook after every `schedule()` call, and the
//! mutation/property suite in `rust/tests/check_invariants.rs`.  The
//! verified invariants:
//!
//! | invariant                 | statement                                                  |
//! |---------------------------|------------------------------------------------------------|
//! | component presence        | every component has ≥ 1 instance                           |
//! | instance caps             | `count_c ≤ max_instances_c`                                |
//! | exclusions                | excluded machines host zero instances                      |
//! | pins                      | pinned components stay on their allowed machines           |
//! | capacity                  | `a_m·rate + b_m ≤ cap_m − headroom − reserved_m` (+1e-6)   |
//! | rate boundary             | `rate ≤ min_m (cap_m − b_m)/a_m`                           |
//! | utilization agreement     | reported util == from-scratch recomputation (1e-9 rel.)    |
//! | feasibility flag          | `eval.feasible` matches the recomputation                  |
//! | tenant disjointness       | isolated-mode tenants never share a machine                |
//! | combined capacity         | Σ tenant loads fit the unreduced machine budgets           |
//! | workload scale            | `scale == min_t rate_t / weight_t`                         |
//! | determinism               | replaying the provenance-named policy is bit-identical     |
//! | provenance                | a matching `schedule_chosen` journal event exists          |
//! | gap certificate           | `gap ≥ 0`; exhausted ⇒ `gap = 0`; `bound ≥ rate`           |

pub mod check;
pub mod cluster;
pub mod config;
pub mod controller;
pub mod engine;
pub mod error;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod predict;
pub mod profiling;
pub mod resolve;
pub mod runtime;
pub mod scheduler;
pub mod simulator;
pub mod topology;
pub mod util;

pub use error::{Error, Result};
