//! Topology substrate: the Storm programming model (paper §2.2).
//!
//! A *user topology graph* (UTG) is a DAG of components — `Spout`s
//! produce the input stream, `Bolt`s process it.  An *execution topology
//! graph* (ETG) fixes a parallelism degree (instance count) per component.
//! The paper's contribution is that the ETG is an **output** of the
//! scheduler, derived from the cluster's heterogeneous capacity.

pub mod benchmarks;
pub mod builder;
pub mod fanout;

use crate::{Error, Result};

/// What a component does with the stream (paper §2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ComponentKind {
    /// Produces the input stream (`R0` is injected here).
    Spout,
    /// Processes tuples.
    Bolt,
}

/// One vertex of the user topology graph.
#[derive(Debug, Clone)]
pub struct Component {
    /// Human-readable unique name ("spout", "bolt-1", ...).
    pub name: String,
    pub kind: ComponentKind,
    /// Profile key: which row of the profile DB describes this
    /// component's per-tuple cost ("lowCompute", "midCompute", ...).
    pub task_type: String,
    /// Tuple division ratio α (paper eq. 6): average output tuples
    /// emitted per input tuple consumed.
    pub alpha: f64,
    /// External input-rate weight: a spout's stream arrives at
    /// `weight · R0` instead of `R0` (eq. 6 seeds `IR = weight` per unit
    /// rate).  `1.0` for every classic single-tenant topology; the
    /// multi-tenant merge ([`crate::scheduler::workload`]) scales each
    /// tenant's spouts by the tenant's rate-weight so one shared `R0`
    /// knob drives all tenants proportionally.  Ignored on bolts.
    pub weight: f64,
}

/// A user topology graph: components + directed edges (paper Fig. 2a).
#[derive(Debug, Clone)]
pub struct Topology {
    pub name: String,
    pub components: Vec<Component>,
    /// `(from, to)` indices into `components`; `from` feeds `to`.
    pub edges: Vec<(usize, usize)>,
}

impl Topology {
    /// Validate the DAG invariants the schedulers rely on:
    /// non-empty, edges in range, at least one spout, spouts have no
    /// inputs, every bolt is reachable from a spout, acyclic.
    pub fn validate(&self) -> Result<()> {
        let n = self.components.len();
        if n == 0 {
            return Err(Error::Topology("empty topology".into()));
        }
        if n > crate::runtime::dims::MAX_COMPONENTS {
            return Err(Error::Topology(format!(
                "{} components exceeds AOT max {}",
                n,
                crate::runtime::dims::MAX_COMPONENTS
            )));
        }
        for &(a, b) in &self.edges {
            if a >= n || b >= n {
                return Err(Error::Topology(format!("edge ({a},{b}) out of range")));
            }
            if a == b {
                return Err(Error::Topology(format!("self-loop on component {a}")));
            }
        }
        if !self.components.iter().any(|c| c.kind == ComponentKind::Spout) {
            return Err(Error::Topology("no spout".into()));
        }
        for c in &self.components {
            if !(c.weight.is_finite() && c.weight > 0.0) {
                return Err(Error::Topology(format!(
                    "component '{}' has input-rate weight {}; weights must be finite and > 0",
                    c.name, c.weight
                )));
            }
        }
        for (i, c) in self.components.iter().enumerate() {
            if c.kind == ComponentKind::Spout && self.edges.iter().any(|&(_, b)| b == i) {
                return Err(Error::Topology(format!("spout '{}' has an input edge", c.name)));
            }
        }
        // acyclicity + reachability via the topo order
        let order = self.topo_order()?;
        let mut reach = vec![false; n];
        for &i in &order {
            if self.components[i].kind == ComponentKind::Spout {
                reach[i] = true;
            }
            if reach[i] {
                for &(a, b) in &self.edges {
                    if a == i {
                        reach[b] = true;
                    }
                }
            }
        }
        if let Some(i) = reach.iter().position(|r| !r) {
            return Err(Error::Topology(format!(
                "component '{}' unreachable from any spout",
                self.components[i].name
            )));
        }
        // duplicate names break config round-trips and metrics keys
        let mut names: Vec<&str> = self.components.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != n {
            return Err(Error::Topology("duplicate component names".into()));
        }
        Ok(())
    }

    /// Kahn topological order; errors on a cycle.
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        let n = self.components.len();
        let mut indeg = vec![0usize; n];
        for &(_, b) in &self.edges {
            indeg[b] += 1;
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(i) = queue.pop() {
            order.push(i);
            for &(a, b) in &self.edges {
                if a == i {
                    indeg[b] -= 1;
                    if indeg[b] == 0 {
                        queue.push(b);
                    }
                }
            }
        }
        if order.len() != n {
            return Err(Error::Topology("cycle detected".into()));
        }
        Ok(order)
    }

    /// Upstream component indices of `i`.
    pub fn upstream(&self, i: usize) -> Vec<usize> {
        self.edges.iter().filter(|&&(_, b)| b == i).map(|&(a, _)| a).collect()
    }

    /// Downstream component indices of `i`.
    pub fn downstream(&self, i: usize) -> Vec<usize> {
        self.edges.iter().filter(|&&(a, _)| a == i).map(|&(_, b)| b).collect()
    }

    /// Indices of spout components.
    pub fn spouts(&self) -> Vec<usize> {
        self.components
            .iter()
            .enumerate()
            .filter(|(_, c)| c.kind == ComponentKind::Spout)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn n_components(&self) -> usize {
        self.components.len()
    }

    /// Per-component *rate gain*: the eq.-6 fixed point for R0 = 1, i.e.
    /// `IR_c = gain_c * R0` for any topology input rate.  Spouts have
    /// gain equal to their input-rate [`Component::weight`] (each spout
    /// receives `weight · R0`; classic topologies use weight 1); a
    /// downstream component's gain is the sum of its upstream
    /// components' `gain * alpha` (every subscribed consumer group
    /// receives the full stream — Storm semantics).
    pub fn rate_gains(&self) -> Result<Vec<f64>> {
        let order = self.topo_order()?;
        let n = self.n_components();
        let mut gain = vec![0.0f64; n];
        for &i in &order {
            if self.components[i].kind == ComponentKind::Spout {
                gain[i] = self.components[i].weight;
            }
            let out = gain[i] * self.components[i].alpha;
            for &(a, b) in &self.edges {
                if a == i {
                    gain[b] += out;
                }
            }
        }
        Ok(gain)
    }

    /// The longest path length in edges — the DEPTH the AOT propagation
    /// model must cover (asserted against `runtime::dims::DEPTH`).
    pub fn longest_path(&self) -> Result<usize> {
        let order = self.topo_order()?;
        let mut d = vec![0usize; self.n_components()];
        let mut best = 0;
        for &i in &order {
            for &(a, b) in &self.edges {
                if a == i {
                    d[b] = d[b].max(d[i] + 1);
                    best = best.max(d[b]);
                }
            }
        }
        Ok(best)
    }
}

/// An execution topology graph: a UTG plus per-component instance counts
/// (paper Fig. 2b).  Placement (which machine hosts each instance) lives
/// in [`crate::scheduler::Placement`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Etg {
    /// Instance count per component; index-aligned with `Topology::components`.
    pub counts: Vec<usize>,
}

impl Etg {
    /// The minimal ETG: one instance per component (Alg. 1 start state).
    pub fn minimal(top: &Topology) -> Self {
        Etg { counts: vec![1; top.n_components()] }
    }

    pub fn total_tasks(&self) -> usize {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::benchmarks;

    #[test]
    fn linear_is_valid() {
        benchmarks::linear().validate().unwrap();
    }

    #[test]
    fn all_benchmarks_valid() {
        for t in benchmarks::all() {
            t.validate().unwrap_or_else(|e| panic!("{}: {e}", t.name));
        }
    }

    #[test]
    fn cycle_detected() {
        let mut t = benchmarks::linear();
        let n = t.n_components();
        t.edges.push((n - 1, 1)); // back edge
        assert!(matches!(t.validate(), Err(Error::Topology(_))));
    }

    #[test]
    fn spout_with_input_rejected() {
        let mut t = benchmarks::linear();
        t.edges.push((1, 0));
        assert!(t.validate().is_err());
    }

    #[test]
    fn unreachable_component_rejected() {
        let mut t = benchmarks::linear();
        t.components.push(Component {
            name: "orphan".into(),
            kind: ComponentKind::Bolt,
            task_type: "lowCompute".into(),
            alpha: 1.0,
            weight: 1.0,
        });
        assert!(t.validate().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut t = benchmarks::linear();
        let name = t.components[1].name.clone();
        t.components[2].name = name;
        assert!(t.validate().is_err());
    }

    #[test]
    fn linear_gains_all_one() {
        let t = benchmarks::linear();
        let g = t.rate_gains().unwrap();
        for v in g {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn diamond_gain_sums_at_sink() {
        let t = benchmarks::diamond();
        let g = t.rate_gains().unwrap();
        // sink receives a full copy from each parallel branch
        let sink = t.n_components() - 1;
        let branches = t.upstream(sink).len() as f64;
        assert!((g[sink] - branches).abs() < 1e-12, "gain={}", g[sink]);
    }

    #[test]
    fn star_multi_spout_gain() {
        let t = benchmarks::star();
        let g = t.rate_gains().unwrap();
        let center = t
            .components
            .iter()
            .position(|c| c.name == "center")
            .unwrap();
        // every spout contributes R0 to the center
        assert!((g[center] - t.spouts().len() as f64).abs() < 1e-12);
    }

    #[test]
    fn spout_weight_scales_gain() {
        let mut t = benchmarks::linear();
        t.components[0].weight = 2.5;
        t.validate().unwrap();
        let g = t.rate_gains().unwrap();
        // the spout and everything downstream scale by the input weight
        for v in g {
            assert!((v - 2.5).abs() < 1e-12, "gain {v}");
        }
        // a weighted spout in a multi-spout topology scales only its
        // own contribution
        let mut s = benchmarks::star();
        s.components[0].weight = 3.0;
        let g = s.rate_gains().unwrap();
        let center = s.components.iter().position(|c| c.name == "center").unwrap();
        assert!((g[center] - 4.0).abs() < 1e-12, "center gain {}", g[center]);
    }

    #[test]
    fn bad_weight_rejected() {
        for w in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let mut t = benchmarks::linear();
            t.components[0].weight = w;
            assert!(t.validate().is_err(), "weight {w} accepted");
        }
    }

    #[test]
    fn alpha_scales_gain() {
        let mut t = benchmarks::linear();
        for c in &mut t.components {
            c.alpha = 0.5;
        }
        let g = t.rate_gains().unwrap();
        assert!((g[0] - 1.0).abs() < 1e-12);
        assert!((g[1] - 0.5).abs() < 1e-12);
        assert!((g[2] - 0.25).abs() < 1e-12);
    }

    #[test]
    fn topo_order_is_topological() {
        for t in benchmarks::all() {
            let order = t.topo_order().unwrap();
            let pos: std::collections::HashMap<usize, usize> =
                order.iter().enumerate().map(|(p, &i)| (i, p)).collect();
            for &(a, b) in &t.edges {
                assert!(pos[&a] < pos[&b], "{}: edge ({a},{b}) violates order", t.name);
            }
        }
    }

    #[test]
    fn longest_path_linear() {
        let t = benchmarks::linear();
        assert_eq!(t.longest_path().unwrap(), t.n_components() - 1);
    }

    #[test]
    fn minimal_etg() {
        let t = benchmarks::diamond();
        let e = Etg::minimal(&t);
        assert_eq!(e.total_tasks(), t.n_components());
    }
}
