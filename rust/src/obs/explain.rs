//! Decision provenance: reconstruct *why* a schedule's certified rate
//! is what it is, straight from the eq.-5 model.
//!
//! Per machine, utilization is linear in the topology input rate:
//! `util_m(R0) = a_m * R0 + b_m` with
//! `a_m = sum_c x[c][m] * e[c][m] * gain[c] / count[c]` (the rate
//! slope) and `b_m = sum_c x[c][m] * met[c][m]` (the fixed MET floor).
//! Each loaded machine therefore caps the rate at
//! `(cap_m - b_m) / a_m`; the machine attaining the minimum is the
//! **bottleneck**, and the component contributing the most slope on it
//! is the vertex the paper's Alg. 2 would take the next instance from.
//! `hstorm explain` renders that chain — bottleneck component, machine
//! and residual headroom — plus the per-machine breakdown and the
//! journal-backed search statistics.

use crate::cluster::Cluster;
use crate::predict::Evaluator;
use crate::scheduler::Schedule;
use crate::topology::Topology;
use crate::util::json::{self, Value};

use super::journal::{Entry, Event};

/// One machine's linear eq.-5 decomposition at the certified rate.
#[derive(Debug, Clone)]
pub struct MachineBreakdown {
    pub machine: String,
    /// Rate slope `a_m` (utilization points per tuple/s).
    pub slope: f64,
    /// Fixed MET floor `b_m` (utilization points).
    pub intercept: f64,
    /// Utilization budget `cap_m`.
    pub cap: f64,
    /// The rate at which this machine saturates, `(cap - b) / a`;
    /// `None` for unloaded machines (zero slope).
    pub rate_cap: Option<f64>,
    /// Predicted utilization at the schedule's certified rate.
    pub util_at_rate: f64,
    /// Residual budget at the certified rate (utilization points).
    pub headroom: f64,
    /// Tasks hosted.
    pub tasks: usize,
    /// Component contributing the most slope, with its share of `a_m`.
    pub dominant: Option<(String, f64)>,
}

/// The machine/component pair that determined `R0*`.
#[derive(Debug, Clone)]
pub struct Bottleneck {
    pub machine: String,
    pub component: String,
    /// Residual headroom on the bottleneck machine at `R0*` — ~0 by
    /// construction, reported so the claim is checkable.
    pub headroom: f64,
    /// The rate this machine caps the topology at.
    pub rate_cap: f64,
}

/// A schedule's full decision story.
#[derive(Debug, Clone)]
pub struct Explanation {
    pub policy: String,
    pub objective: String,
    pub backend: String,
    /// Certified max stable rate (tuples/s).
    pub rate: f64,
    /// Candidate placements the search evaluated (from `Provenance`).
    pub evaluated: u64,
    pub wall_ms: f64,
    /// Certified rate upper bound, when the search proved one.
    pub bound: Option<f64>,
    /// Certified relative optimality gap `(bound - rate) / rate`.
    pub optimality_gap: Option<f64>,
    /// Why the search stopped (`Termination::name`).
    pub terminated: &'static str,
    pub bottleneck: Option<Bottleneck>,
    pub machines: Vec<MachineBreakdown>,
}

/// Decompose `schedule` against the eq.-5 model.  The evaluator must
/// be the one the schedule was certified under (same constraint
/// projection), which all CLI/default paths satisfy.
pub fn analyze(
    top: &Topology,
    cluster: &Cluster,
    ev: &Evaluator,
    schedule: &Schedule,
) -> Explanation {
    let p = &schedule.placement;
    let counts = p.counts();
    let n_m = ev.n_machines();
    let n_c = ev.n_components();

    let mut machines = Vec::with_capacity(n_m);
    let mut bottleneck: Option<Bottleneck> = None;
    for m in 0..n_m {
        let mut slope = 0.0;
        let mut intercept = 0.0;
        let mut dominant: Option<(usize, f64)> = None;
        for c in 0..n_c {
            if p.x[c][m] == 0 {
                continue;
            }
            let contrib = p.x[c][m] as f64 * ev.e_m[c][m] * ev.gains[c] / counts[c].max(1) as f64;
            slope += contrib;
            intercept += p.x[c][m] as f64 * ev.met_m[c][m];
            if dominant.map_or(true, |(_, best)| contrib > best) {
                dominant = Some((c, contrib));
            }
        }
        let rate_cap = if slope > 0.0 { Some((ev.cap[m] - intercept) / slope) } else { None };
        let util_at_rate = slope * schedule.rate + intercept;
        let row = MachineBreakdown {
            machine: cluster.machines[m].name.clone(),
            slope,
            intercept,
            cap: ev.cap[m],
            rate_cap,
            util_at_rate,
            headroom: ev.cap[m] - util_at_rate,
            tasks: p.tasks_on(m),
            dominant: dominant.map(|(c, contrib)| {
                (top.components[c].name.clone(), if slope > 0.0 { contrib / slope } else { 0.0 })
            }),
        };
        if let (Some(rc), Some((comp, _))) = (row.rate_cap, row.dominant.as_ref()) {
            if bottleneck.as_ref().map_or(true, |b| rc < b.rate_cap) {
                bottleneck = Some(Bottleneck {
                    machine: row.machine.clone(),
                    component: comp.clone(),
                    headroom: row.headroom,
                    rate_cap: rc,
                });
            }
        }
        machines.push(row);
    }

    Explanation {
        policy: schedule.provenance.policy.clone(),
        objective: schedule.provenance.objective.clone(),
        backend: schedule.provenance.backend.clone(),
        rate: schedule.rate,
        evaluated: schedule.provenance.placements_evaluated,
        wall_ms: schedule.provenance.wall.as_secs_f64() * 1e3,
        bound: schedule.provenance.bound,
        optimality_gap: schedule.provenance.optimality_gap,
        terminated: schedule.provenance.terminated.name(),
        bottleneck,
        machines,
    }
}

/// Render an [`Explanation`] as the `hstorm explain` text block.
pub fn render(x: &Explanation) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "explain · policy={} · objective={} · backend={}\n",
        x.policy, x.objective, x.backend
    ));
    out.push_str(&format!("  certified rate R0*   : {:.3} tuples/s\n", x.rate));
    out.push_str(&format!(
        "  candidates evaluated : {}  (search wall {:.1} ms)\n",
        x.evaluated, x.wall_ms
    ));
    match (x.bound, x.optimality_gap) {
        (Some(bound), Some(gap)) => out.push_str(&format!(
            "  optimality           : bound {:.3}, gap {:.2}%  (terminated: {})\n",
            bound,
            gap * 100.0,
            x.terminated
        )),
        _ => out.push_str(&format!(
            "  optimality           : no certificate  (terminated: {})\n",
            x.terminated
        )),
    }
    match &x.bottleneck {
        Some(b) => out.push_str(&format!(
            "  bottleneck           : component '{}' on machine '{}' \
             (caps R0* at {:.3}, residual headroom {:.2} pts)\n",
            b.component, b.machine, b.rate_cap, b.headroom
        )),
        None => out.push_str("  bottleneck           : none (no machine carries rate load)\n"),
    }
    out.push_str(
        "  machine          tasks   slope/r     fixed     util@R0*       cap   headroom\n",
    );
    for m in &x.machines {
        let marker = match &x.bottleneck {
            Some(b) if b.machine == m.machine => "  <- bottleneck",
            _ => "",
        };
        let dom = match &m.dominant {
            Some((c, share)) => format!("  [{} {:.0}% of slope]", c, share * 100.0),
            None => String::new(),
        };
        out.push_str(&format!(
            "  {:<15} {:>5}  {:>8.5}  {:>8.3}  {:>11.3}  {:>8.1}  {:>9.3}{dom}{marker}\n",
            m.machine, m.tasks, m.slope, m.intercept, m.util_at_rate, m.cap, m.headroom
        ));
    }
    out
}

/// Render the controller's breach -> re-plan timeline (plus admission
/// decisions) for one policy from retained journal entries.
pub fn render_timeline(entries: &[Entry], policy: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("timeline · policy={policy}\n"));
    let mut any = false;
    for e in entries {
        let line = match &e.event {
            Event::BreachDetected { policy: p, step, offered, capacity } if p == policy => {
                Some(format!(
                    "  step {step:>5}  breach     offered {offered:.2} > capacity {capacity:.2}"
                ))
            }
            Event::Replanned { policy: p, step, cause } if p == policy => {
                Some(format!("  step {step:>5}  re-plan    cause={cause}"))
            }
            Event::AdmissionDenied { tenant, step, reason } if policy == "workload" => {
                Some(format!("  step {step:>5}  denied     tenant={tenant}  {reason}"))
            }
            Event::AdmissionGranted { tenant, step } if policy == "workload" => {
                Some(format!("  step {step:>5}  admitted   tenant={tenant}"))
            }
            _ => None,
        };
        if let Some(l) = line {
            out.push_str(&l);
            out.push('\n');
            any = true;
        }
    }
    if !any {
        out.push_str("  (no breach/re-plan/admission events recorded)\n");
    }
    out
}

/// JSON form of an [`Explanation`] (used by `hstorm explain --json`).
pub fn to_json(x: &Explanation) -> Value {
    let machines = x
        .machines
        .iter()
        .map(|m| {
            json::obj(vec![
                ("machine", json::s(&m.machine)),
                ("tasks", json::num(m.tasks as f64)),
                ("slope", json::num(m.slope)),
                ("intercept", json::num(m.intercept)),
                ("cap", json::num(m.cap)),
                ("rate_cap", m.rate_cap.map(json::num).unwrap_or(Value::Null)),
                ("util_at_rate", json::num(m.util_at_rate)),
                ("headroom", json::num(m.headroom)),
            ])
        })
        .collect();
    json::obj(vec![
        ("policy", json::s(&x.policy)),
        ("objective", json::s(&x.objective)),
        ("backend", json::s(&x.backend)),
        ("rate", json::num(x.rate)),
        ("evaluated", json::num(x.evaluated as f64)),
        ("wall_ms", json::num(x.wall_ms)),
        ("bound", x.bound.map(json::num).unwrap_or(Value::Null)),
        ("optimality_gap", x.optimality_gap.map(json::num).unwrap_or(Value::Null)),
        ("terminated", json::s(x.terminated)),
        (
            "bottleneck",
            match &x.bottleneck {
                Some(b) => json::obj(vec![
                    ("machine", json::s(&b.machine)),
                    ("component", json::s(&b.component)),
                    ("headroom", json::num(b.headroom)),
                    ("rate_cap", json::num(b.rate_cap)),
                ]),
                None => Value::Null,
            },
        ),
        ("machines", Value::Arr(machines)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::{hetero::HeteroScheduler, Problem, ScheduleRequest, Scheduler};
    use crate::topology::benchmarks;

    fn schedule_linear() -> (Problem, Schedule, Topology, Cluster) {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        let s = HeteroScheduler::default()
            .schedule(&problem, &ScheduleRequest::max_throughput())
            .unwrap();
        (problem, s, top, cluster)
    }

    #[test]
    fn bottleneck_machine_caps_the_certified_rate() {
        let (problem, s, top, cluster) = schedule_linear();
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        let b = x.bottleneck.as_ref().expect("loaded schedule must have a bottleneck");
        // the binding machine's rate cap IS the certified max stable rate
        assert!(
            (b.rate_cap - s.rate).abs() < 1e-6,
            "bottleneck caps at {} but certified rate is {}",
            b.rate_cap,
            s.rate
        );
        // and its residual headroom at R0* is zero by construction
        assert!(b.headroom.abs() < 1e-6, "headroom {}", b.headroom);
        // every other loaded machine caps at a rate >= R0*
        for m in &x.machines {
            if let Some(rc) = m.rate_cap {
                assert!(rc >= b.rate_cap - 1e-9, "{}: caps at {rc} < R0*", m.machine);
            }
        }
    }

    #[test]
    fn explanation_mirrors_provenance() {
        let (problem, s, top, cluster) = schedule_linear();
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        assert_eq!(x.policy, s.provenance.policy);
        assert_eq!(x.evaluated, s.provenance.placements_evaluated);
        assert_eq!(x.backend, s.provenance.backend);
        assert_eq!(x.machines.len(), cluster.n_machines());
        assert_eq!(x.bound, s.provenance.bound);
        assert_eq!(x.optimality_gap, s.provenance.optimality_gap);
        assert_eq!(x.terminated, s.provenance.terminated.name());
    }

    #[test]
    fn render_shows_gap_certificate_when_present() {
        use crate::scheduler::Termination;
        let (problem, mut s, top, cluster) = schedule_linear();
        // a heuristic carries no certificate
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        assert!(render(&x).contains("no certificate"), "{}", render(&x));
        // a budgeted search's certificate renders bound, gap and cause
        s.provenance.bound = Some(s.rate * 1.05);
        s.provenance.optimality_gap = Some(0.05);
        s.provenance.terminated = Termination::Budget;
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        let text = render(&x);
        assert!(text.contains("gap 5.00%"), "{text}");
        assert!(text.contains("terminated: budget"), "{text}");
        let v = to_json(&x);
        assert_eq!(v.num_field("optimality_gap").unwrap(), 0.05);
        assert_eq!(v.str_field("terminated").unwrap(), "budget");
    }

    #[test]
    fn render_names_bottleneck_component_machine_and_headroom() {
        let (problem, s, top, cluster) = schedule_linear();
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        let text = render(&x);
        let b = x.bottleneck.as_ref().unwrap();
        assert!(text.contains("bottleneck"), "{text}");
        assert!(text.contains(&format!("'{}'", b.component)), "{text}");
        assert!(text.contains(&format!("'{}'", b.machine)), "{text}");
        assert!(text.contains("residual headroom"), "{text}");
        assert!(text.contains(&format!("candidates evaluated : {}", x.evaluated)), "{text}");
    }

    #[test]
    fn to_json_roundtrips_the_key_fields() {
        let (problem, s, top, cluster) = schedule_linear();
        let x = analyze(&top, &cluster, problem.evaluator(), &s);
        let v = to_json(&x);
        assert_eq!(v.num_field("evaluated").unwrap(), x.evaluated as f64);
        assert_eq!(v.str_field("policy").unwrap(), x.policy);
        assert!(v.get("bottleneck").unwrap().str_field("machine").is_ok());
    }

    #[test]
    fn timeline_renders_breach_and_replan_rows() {
        let entries = vec![
            Entry {
                seq: 0,
                event: Event::BreachDetected {
                    policy: "reactive".into(),
                    step: 12,
                    offered: 140.0,
                    capacity: 120.0,
                },
            },
            Entry {
                seq: 1,
                event: Event::Replanned {
                    policy: "reactive".into(),
                    step: 12,
                    cause: "infeasible".into(),
                },
            },
            Entry {
                seq: 2,
                event: Event::Replanned { policy: "oracle".into(), step: 3, cause: "oracle".into() },
            },
        ];
        let text = render_timeline(&entries, "reactive");
        assert!(text.contains("breach"), "{text}");
        assert!(text.contains("cause=infeasible"), "{text}");
        assert!(!text.contains("oracle"), "other policies filtered out: {text}");
        let empty = render_timeline(&entries, "static");
        assert!(empty.contains("no breach"), "{empty}");
    }
}
