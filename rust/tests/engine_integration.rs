//! Engine ↔ scheduler ↔ predictor integration: run real schedules on the
//! stream engine and check the measured numbers track the model — the
//! implementation-vs-simulation loop of paper §6.3 — plus failure
//! injection (overload, misconfiguration).

use std::time::Duration;

use hstorm::cluster::presets;
use hstorm::engine::{self, EngineConfig};
use hstorm::predict::{Evaluator, Placement};
use hstorm::scheduler::default_rr::DefaultScheduler;
use hstorm::scheduler::hetero::HeteroScheduler;
use hstorm::scheduler::{Problem, Schedule, ScheduleRequest, Scheduler};
use hstorm::simulator;
use hstorm::topology::{benchmarks, Etg, Topology};

fn cfg() -> EngineConfig {
    EngineConfig {
        duration: Duration::from_millis(900),
        warmup: Duration::from_millis(300),
        time_scale: 0.2,
        ..Default::default()
    }
}

type World = (Schedule, hstorm::cluster::Cluster, hstorm::cluster::profile::ProfileDb);

fn hetero(top: &Topology) -> World {
    let (cluster, db) = presets::paper_cluster();
    let problem = Problem::new(top, &cluster, &db).unwrap();
    let s = HeteroScheduler::default()
        .schedule(&problem, &ScheduleRequest::max_throughput())
        .unwrap();
    (s, cluster, db)
}

#[test]
fn hetero_schedule_runs_at_certified_rate() {
    for top in benchmarks::micro() {
        let (s, cluster, db) = hetero(&top);
        let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate, &cfg()).unwrap();
        // measured throughput within 20% of the model in a short window
        let rel = (rep.throughput - s.eval.throughput).abs() / s.eval.throughput;
        assert!(
            rel < 0.20,
            "{}: measured {} vs predicted {} (rel {rel:.2})",
            top.name,
            rep.throughput,
            s.eval.throughput
        );
        // certified rate must not melt the engine: modest shedding only
        assert!(
            (rep.shed as f64) < 0.05 * rep.emitted_rate * rep.window + 50.0,
            "{}: shed {} of ~{}",
            top.name,
            rep.shed,
            rep.emitted_rate * rep.window
        );
    }
}

#[test]
fn engine_matches_analytic_simulator() {
    let top = benchmarks::diamond();
    let (s, cluster, db) = hetero(&top);
    let problem = Problem::new(&top, &cluster, &db).unwrap();
    let sim = simulator::simulate(&problem, &s.placement, Some(s.rate)).unwrap();
    let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate, &cfg()).unwrap();
    let rel = (rep.throughput - sim.throughput).abs() / sim.throughput;
    // the paper reports <= 13% impl-vs-sim difference
    assert!(rel < 0.15, "impl {} vs sim {} (rel {rel:.2})", rep.throughput, sim.throughput);
}

#[test]
fn proposed_beats_default_on_engine() {
    let top = benchmarks::linear();
    let (ours, cluster, db) = hetero(&top);
    let problem = Problem::new(&top, &cluster, &db).unwrap();
    let etg = Etg { counts: ours.placement.counts() };
    let def = DefaultScheduler::with_etg(etg)
        .schedule(&problem, &ScheduleRequest::max_throughput())
        .unwrap();
    let ours_rep = engine::run(&top, &cluster, &db, &ours.placement, ours.rate, &cfg()).unwrap();
    let def_rep = engine::run(&top, &cluster, &db, &def.placement, def.rate, &cfg()).unwrap();
    assert!(
        ours_rep.throughput > def_rep.throughput,
        "proposed {} <= default {}",
        ours_rep.throughput,
        def_rep.throughput
    );
}

#[test]
fn overload_injection_degrades_gracefully() {
    let top = benchmarks::linear();
    let (s, cluster, db) = hetero(&top);
    // drive the certified schedule at 3x its rate: the ring dataplane
    // must exhaust credits and throttle the spout — never shed, never
    // crash or deadlock.  Small batches/rings keep the warmup-epoch
    // backlog tiny so the measured window reflects steady state.
    let hot = EngineConfig { batch: 8, ring_capacity: 4, ..cfg() };
    let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate * 3.0, &hot).unwrap();
    assert_eq!(rep.shed, 0, "ring dataplane must be lossless");
    assert!(rep.throttled, "expected spout throttling at 3x rate");
    assert!(rep.credit_stalls > 0, "expected credit exhaustion at 3x rate");
    // the emitted rate is held near capacity, not the offered 3x
    assert!(
        rep.emitted_rate < s.rate * 3.0 * 0.80,
        "spout not throttled: emitted {} of offered {}",
        rep.emitted_rate,
        s.rate * 3.0
    );
    // throughput still close to the certified capacity (within 30%)
    let rel = (rep.throughput - s.eval.throughput).abs() / s.eval.throughput;
    assert!(rel < 0.30, "capacity collapsed: {} vs {}", rep.throughput, s.eval.throughput);
}

#[test]
fn overload_injection_sheds_on_legacy_dataplane() {
    let top = benchmarks::linear();
    let (s, cluster, db) = hetero(&top);
    // the legacy per-tuple dataplane keeps its drop-at-spout semantics
    let hot = EngineConfig {
        max_pending: 64,
        dataplane: engine::Dataplane::Legacy,
        ..cfg()
    };
    let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate * 3.0, &hot).unwrap();
    assert!(rep.shed > 0, "expected load shedding at 3x rate");
    let rel = (rep.throughput - s.eval.throughput).abs() / s.eval.throughput;
    assert!(rel < 0.30, "capacity collapsed: {} vs {}", rep.throughput, s.eval.throughput);
}

#[test]
fn noise_injection_keeps_prediction_close() {
    let top = benchmarks::star();
    let (s, cluster, db) = hetero(&top);
    let noisy = EngineConfig { noise: 0.15, ..cfg() };
    let rep = engine::run(&top, &cluster, &db, &s.placement, s.rate, &noisy).unwrap();
    let ev = Evaluator::new(&top, &cluster, &db).unwrap();
    let pred = ev.evaluate(&s.placement, s.rate).unwrap();
    for m in 0..cluster.n_machines() {
        let err = (rep.util[m] - pred.util[m]).abs();
        assert!(err < 15.0, "machine {m}: {err} pp error under 15% noise");
    }
}

#[test]
fn misconfigured_placement_rejected() {
    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::linear();
    // empty placement
    let p = Placement::empty(top.n_components(), cluster.n_machines());
    assert!(engine::run(&top, &cluster, &db, &p, 10.0, &cfg()).is_err());
    // wrong shape
    let p = Placement::empty(top.n_components() + 1, cluster.n_machines());
    assert!(engine::run(&top, &cluster, &db, &p, 10.0, &cfg()).is_err());
}

#[test]
fn zero_rate_runs_and_reports_met_only() {
    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::linear();
    let mut p = Placement::empty(top.n_components(), cluster.n_machines());
    for c in 0..top.n_components() {
        p.x[c][c % 3] = 1;
    }
    let rep = engine::run(&top, &cluster, &db, &p, 0.0, &cfg()).unwrap();
    assert_eq!(rep.shed, 0);
    assert!(rep.throughput < 5.0, "throughput {} at zero rate", rep.throughput);
    // MET background burn still shows up as nonzero utilization
    assert!(rep.util.iter().any(|u| *u > 0.5), "no MET burn visible: {:?}", rep.util);
}
