//! Loom models over the production atomic cores (see `src/lib.rs` for
//! how the exact `rust/src` source files end up compiled against
//! loom's primitives).  Every model uses a tiny bucket grid
//! (`with_buckets(8)`) so the checker tracks a handful of atomics, and
//! two threads with one operation each — loom explores every
//! interleaving of the cores' CAS loops and lock acquisitions.
//!
//! Quantile assertions are bounds, not exact values: the reduced grid
//! clamps large samples into its last bucket, so only the
//! `min <= q <= max` envelope (which `Histogram::quantile` guarantees
//! by construction) is grid-independent.

use hstorm_loom::histogram_core::Histogram;
use hstorm_loom::meanstat_core::MeanStat;
use loom::sync::Arc;
use loom::thread;

#[test]
fn histogram_concurrent_records_lose_nothing() {
    loom::model(|| {
        let h = Arc::new(Histogram::with_buckets(8));
        let h2 = h.clone();
        let t = thread::spawn(move || h2.observe(1.0));
        h.observe(2.0);
        t.join().unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum(), 3.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 2.0);
        let p100 = h.quantile(1.0);
        assert!(p100 >= h.min() && p100 <= h.max(), "p100 {p100} out of envelope");
    });
}

#[test]
fn histogram_quantile_is_bounded_during_concurrent_record() {
    loom::model(|| {
        let h = Arc::new(Histogram::with_buckets(8));
        h.observe(1.0);
        let h2 = h.clone();
        let t = thread::spawn(move || h2.observe(4.0));
        // racing reader: whatever prefix of the writer's atomics landed,
        // the quantile must stay finite, non-negative and within the
        // currently-visible extremes
        let p50 = h.quantile(0.5);
        assert!(p50.is_finite() && p50 >= 0.0, "torn quantile {p50}");
        t.join().unwrap();
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 4.0);
    });
}

#[test]
fn histogram_merge_is_complete_against_concurrent_record() {
    loom::model(|| {
        let a = Arc::new(Histogram::with_buckets(8));
        let b = Histogram::with_buckets(8);
        b.observe(4.0);
        let a2 = a.clone();
        let t = thread::spawn(move || a2.observe(1.0));
        a.merge_from(&b);
        t.join().unwrap();
        assert_eq!(a.count(), 2);
        assert_eq!(a.sum(), 5.0);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
        let p100 = a.quantile(1.0);
        assert!(p100 >= a.min() && p100 <= a.max(), "p100 {p100} out of envelope");
    });
}

#[test]
fn meanstat_reset_never_tears_a_sample() {
    loom::model(|| {
        let m = Arc::new(MeanStat::new());
        let m2 = m.clone();
        let t = thread::spawn(move || m2.observe(0.5));
        m.reset();
        t.join().unwrap();
        // the reset gate makes observe atomic against reset: the sample
        // either survives whole or is wiped whole — never a half-applied
        // (sum, count) pair
        match m.mean() {
            None => assert_eq!(m.count(), 0, "count survived a wiped sample"),
            Some(mean) => {
                assert_eq!(m.count(), 1);
                assert!((mean - 0.5).abs() < 1e-12, "torn reset: mean {mean}");
            }
        }
    });
}

#[test]
fn meanstat_concurrent_observes_accumulate_exactly() {
    loom::model(|| {
        let m = Arc::new(MeanStat::new());
        let m2 = m.clone();
        let t = thread::spawn(move || m2.observe(0.25));
        m.observe(0.5);
        t.join().unwrap();
        assert_eq!(m.count(), 2);
        let mean = m.mean().unwrap();
        assert!((mean - 0.375).abs() < 1e-12, "lost update: mean {mean}");
    });
}
