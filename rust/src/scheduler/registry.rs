//! Policy registry: the single place a scheduler *name* resolves to an
//! implementation.
//!
//! The CLI (`--scheduler`), the JSON config runner (`"scheduler":`),
//! the experiment harness and the control plane all construct policies
//! through [`create`], so the set of valid names — and their spellings —
//! cannot drift between entry points.  `hstorm schedule --list-policies`
//! prints [`describe_all`], which now includes each policy's parameter
//! schema ([`ParamSpec`]).  Deprecated aliases keep resolving but warn
//! once per process through the journal (`deprecated_alias`).

use std::collections::BTreeSet;
use std::sync::Mutex;

use super::default_rr::{DefaultScheduler, EtgSource};
use super::hetero::HeteroScheduler;
use super::optimal::{OptimalScheduler, SearchSpace};
use super::search::portfolio::StrategyMix;
use super::search::{AnnealScheduler, BeamScheduler, BnbScheduler, PortfolioScheduler};
use super::{Scheduler, SearchBudget};
use crate::{Error, Result};

/// Tunables a policy factory may consume.  Every field has the
/// documented default; policies ignore the fields that do not apply to
/// them (e.g. `r0` is meaningless to the optimal search).
#[derive(Debug, Clone)]
pub struct PolicyParams {
    /// Initial topology input rate `R0` for Alg. 2 (hetero; also the
    /// hetero pass inside the default policy's fair-comparison ETG).
    pub r0: f64,
    /// Post-pass refinement on/off (hetero).
    pub refine: bool,
    /// Upper bound on executors per worker, the paper's `k_j` (hetero).
    pub max_tasks_per_machine: usize,
    /// Instance-count bound on the design space (optimal/search).
    pub max_instances_per_component: usize,
    /// Seed the search with the heuristics' solutions (optimal/search).
    pub seed_heuristics: bool,
    /// `Some((candidates, seed))` switches the optimal search to
    /// uniform sampling (optimal).
    pub sampled: Option<(usize, u64)>,
    /// Place the minimal user graph instead of the proposed scheduler's
    /// ETG (default policy; the paper's §6.3 fair-comparison protocol
    /// uses the proposed ETG, which is the default here).
    pub minimal_etg: bool,
    /// Default candidate budget for the search policies (`None`:
    /// unlimited; a budget on the [`super::ScheduleRequest`] wins).
    pub budget_candidates: Option<u64>,
    /// Default virtual-op budget for the search policies.
    pub budget_vops: Option<u64>,
    /// Default target optimality gap (fraction; search policies stop
    /// once the incumbent certifies within it).
    pub target_gap: Option<f64>,
    /// Portfolio budget shares (normalized at run time).
    pub mix_bnb: f64,
    pub mix_beam: f64,
    pub mix_anneal: f64,
    /// Beam width (beam/portfolio).
    pub beam_width: usize,
    /// Annealing restarts/steps/seed (anneal/portfolio).
    pub anneal_restarts: usize,
    pub anneal_steps: usize,
    pub anneal_seed: u64,
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams {
            r0: 8.0,
            refine: true,
            max_tasks_per_machine: 32,
            max_instances_per_component: 3,
            seed_heuristics: true,
            sampled: None,
            minimal_etg: false,
            budget_candidates: None,
            budget_vops: None,
            target_gap: None,
            mix_bnb: 0.5,
            mix_beam: 0.25,
            mix_anneal: 0.25,
            beam_width: 8,
            anneal_restarts: 4,
            anneal_steps: 400,
            anneal_seed: 0xA11E_A1,
        }
    }
}

fn parse<T: std::str::FromStr>(key: &str, value: &str, ty: &str) -> Result<T> {
    value.parse::<T>().map_err(|_| {
        Error::Config(format!("invalid value '{value}' for parameter '{key}' (expected {ty})"))
    })
}

impl PolicyParams {
    /// The default [`SearchBudget`] these params encode (a budget set on
    /// the request overrides it).
    pub fn budget(&self) -> SearchBudget {
        SearchBudget {
            max_candidates: self.budget_candidates,
            max_virtual_ops: self.budget_vops,
            target_gap: self.target_gap,
        }
    }

    /// Set one parameter from its kebab-case key (the CLI's
    /// `--param key=value` and the JSON config surface).  Unknown keys
    /// and malformed values fail loudly — a typo must never silently
    /// fall back to a default.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "r0" => self.r0 = parse(key, value, "float")?,
            "refine" => self.refine = parse(key, value, "bool")?,
            "max-tasks-per-machine" => {
                self.max_tasks_per_machine = parse(key, value, "integer")?
            }
            "max-instances" => self.max_instances_per_component = parse(key, value, "integer")?,
            "seed-heuristics" => self.seed_heuristics = parse(key, value, "bool")?,
            "minimal-etg" => self.minimal_etg = parse(key, value, "bool")?,
            "budget-candidates" => {
                self.budget_candidates = Some(parse(key, value, "integer")?)
            }
            "budget-vops" => self.budget_vops = Some(parse(key, value, "integer")?),
            "target-gap" => self.target_gap = Some(parse(key, value, "float")?),
            "mix-bnb" => self.mix_bnb = parse(key, value, "float")?,
            "mix-beam" => self.mix_beam = parse(key, value, "float")?,
            "mix-anneal" => self.mix_anneal = parse(key, value, "float")?,
            "beam-width" => self.beam_width = parse(key, value, "integer")?,
            "anneal-restarts" => self.anneal_restarts = parse(key, value, "integer")?,
            "anneal-steps" => self.anneal_steps = parse(key, value, "integer")?,
            "anneal-seed" => self.anneal_seed = parse(key, value, "integer")?,
            _ => {
                return Err(Error::Config(format!(
                    "unknown policy parameter '{key}' (valid: r0|refine|\
                     max-tasks-per-machine|max-instances|seed-heuristics|minimal-etg|\
                     budget-candidates|budget-vops|target-gap|mix-bnb|mix-beam|mix-anneal|\
                     beam-width|anneal-restarts|anneal-steps|anneal-seed)"
                )))
            }
        }
        Ok(())
    }
}

/// One entry of a policy's parameter schema (rendered by
/// [`describe_all`]; `default` is the rendered default value).
pub struct ParamSpec {
    pub name: &'static str,
    pub ty: &'static str,
    pub default: &'static str,
    pub doc: &'static str,
}

const P_MAX_INSTANCES: ParamSpec = ParamSpec {
    name: "max-instances",
    ty: "integer",
    default: "3",
    doc: "instance-count bound on the design space",
};
const P_SEED_HEURISTICS: ParamSpec = ParamSpec {
    name: "seed-heuristics",
    ty: "bool",
    default: "true",
    doc: "fold the heuristics' solutions into the candidate set",
};
const P_BUDGET: [ParamSpec; 3] = [
    ParamSpec {
        name: "budget-candidates",
        ty: "integer",
        default: "unlimited",
        doc: "default candidate budget (a request budget wins)",
    },
    ParamSpec {
        name: "budget-vops",
        ty: "integer",
        default: "unlimited",
        doc: "default virtual-op budget (bound probes included)",
    },
    ParamSpec {
        name: "target-gap",
        ty: "float",
        default: "none",
        doc: "stop once the certified gap falls within this fraction",
    },
];

static PARAMS_HETERO: &[ParamSpec] = &[
    ParamSpec {
        name: "r0",
        ty: "float",
        default: "8.0",
        doc: "initial topology input rate for Alg. 2",
    },
    ParamSpec {
        name: "refine",
        ty: "bool",
        default: "true",
        doc: "post-pass refinement on/off",
    },
    ParamSpec {
        name: "max-tasks-per-machine",
        ty: "integer",
        default: "32",
        doc: "upper bound on executors per worker (paper's k_j)",
    },
];
static PARAMS_DEFAULT: &[ParamSpec] = &[ParamSpec {
    name: "minimal-etg",
    ty: "bool",
    default: "false",
    doc: "place the minimal user graph instead of the proposed ETG",
}];
static PARAMS_OPTIMAL: &[ParamSpec] = &[P_MAX_INSTANCES, P_SEED_HEURISTICS];
static PARAMS_BNB: &[ParamSpec] = &[
    P_MAX_INSTANCES,
    P_SEED_HEURISTICS,
    P_BUDGET[0],
    P_BUDGET[1],
    P_BUDGET[2],
];
static PARAMS_BEAM: &[ParamSpec] = &[
    P_MAX_INSTANCES,
    P_SEED_HEURISTICS,
    ParamSpec {
        name: "beam-width",
        ty: "integer",
        default: "8",
        doc: "partial candidates kept per level",
    },
    P_BUDGET[0],
    P_BUDGET[1],
];
static PARAMS_ANNEAL: &[ParamSpec] = &[
    P_MAX_INSTANCES,
    ParamSpec {
        name: "anneal-restarts",
        ty: "integer",
        default: "4",
        doc: "independent restarts from the base placement",
    },
    ParamSpec {
        name: "anneal-steps",
        ty: "integer",
        default: "400",
        doc: "annealing steps per restart",
    },
    ParamSpec {
        name: "anneal-seed",
        ty: "integer",
        default: "10558113",
        doc: "deterministic RNG seed",
    },
    P_BUDGET[0],
    P_BUDGET[1],
];
static PARAMS_PORTFOLIO: &[ParamSpec] = &[
    P_MAX_INSTANCES,
    ParamSpec {
        name: "mix-bnb",
        ty: "float",
        default: "0.5",
        doc: "budget share of the branch-and-bound stage",
    },
    ParamSpec {
        name: "mix-beam",
        ty: "float",
        default: "0.25",
        doc: "budget share of the beam stage",
    },
    ParamSpec {
        name: "mix-anneal",
        ty: "float",
        default: "0.25",
        doc: "budget share of the annealing stage",
    },
    ParamSpec {
        name: "beam-width",
        ty: "integer",
        default: "8",
        doc: "beam width of the beam stage",
    },
    P_BUDGET[0],
    P_BUDGET[1],
    P_BUDGET[2],
];

/// One registry row.
pub struct PolicyInfo {
    /// Canonical name ([`Scheduler::name`] of the built policy).
    pub name: &'static str,
    /// Accepted alternative spellings.
    pub aliases: &'static [&'static str],
    /// Spellings that still resolve but journal a `deprecated_alias`
    /// warning once per process.
    pub deprecated: &'static [&'static str],
    /// One-line description for `--list-policies`.
    pub summary: &'static str,
    /// Parameter schema rendered by [`describe_all`].
    pub params: &'static [ParamSpec],
    factory: fn(&PolicyParams) -> Box<dyn Scheduler>,
}

fn make_hetero(p: &PolicyParams) -> HeteroScheduler {
    HeteroScheduler {
        r0: p.r0,
        max_tasks_per_machine: p.max_tasks_per_machine,
        refine: p.refine,
        ..Default::default()
    }
}

static POLICIES: &[PolicyInfo] = &[
    PolicyInfo {
        name: "hetero",
        aliases: &["proposed"],
        deprecated: &[],
        summary: "the paper's heterogeneity-aware scheduler (Alg. 1 + Alg. 2 + refinement)",
        params: PARAMS_HETERO,
        factory: |p| Box::new(make_hetero(p)),
    },
    PolicyInfo {
        name: "default",
        aliases: &["default-rr"],
        deprecated: &["rr"],
        summary: "Storm's Round-Robin baseline (places the proposed ETG unless minimal_etg)",
        params: PARAMS_DEFAULT,
        factory: |p| {
            let source = if p.minimal_etg {
                EtgSource::Minimal
            } else {
                EtgSource::Proposed(make_hetero(p))
            };
            Box::new(DefaultScheduler { etg: source })
        },
    },
    PolicyInfo {
        name: "optimal",
        aliases: &[],
        deprecated: &["exhaustive"],
        summary: "bounded exhaustive/sampled search over the placement design space",
        params: PARAMS_OPTIMAL,
        factory: |p| {
            Box::new(OptimalScheduler {
                max_instances_per_component: p.max_instances_per_component,
                space: match p.sampled {
                    Some((candidates, seed)) => SearchSpace::Sampled { candidates, seed },
                    None => SearchSpace::Exhaustive,
                },
                seed_heuristics: p.seed_heuristics,
                ..Default::default()
            })
        },
    },
    PolicyInfo {
        name: "bnb",
        aliases: &["branch-and-bound"],
        deprecated: &[],
        summary: "branch-and-bound: exhaustive-identical fold with admissible bound pruning",
        params: PARAMS_BNB,
        factory: |p| {
            Box::new(BnbScheduler {
                max_instances_per_component: p.max_instances_per_component,
                seed_heuristics: p.seed_heuristics,
                budget: p.budget(),
                ..Default::default()
            })
        },
    },
    PolicyInfo {
        name: "beam",
        aliases: &[],
        deprecated: &[],
        summary: "beam search over per-component rows, bound-ranked, budget-degradable",
        params: PARAMS_BEAM,
        factory: |p| {
            Box::new(BeamScheduler {
                max_instances_per_component: p.max_instances_per_component,
                width: p.beam_width,
                seed_heuristics: p.seed_heuristics,
                budget: p.budget(),
            })
        },
    },
    PolicyInfo {
        name: "anneal",
        aliases: &["local-search"],
        deprecated: &[],
        summary: "seeded simulated annealing over O(1) placement deltas (deterministic replay)",
        params: PARAMS_ANNEAL,
        factory: |p| {
            Box::new(AnnealScheduler {
                max_instances_per_component: p.max_instances_per_component,
                restarts: p.anneal_restarts,
                steps: p.anneal_steps,
                seed: p.anneal_seed,
                budget: p.budget(),
            })
        },
    },
    PolicyInfo {
        name: "portfolio",
        aliases: &[],
        deprecated: &[],
        summary: "bnb + beam + anneal racing under one budget, with a certified optimality gap",
        params: PARAMS_PORTFOLIO,
        factory: |p| {
            Box::new(PortfolioScheduler {
                max_instances_per_component: p.max_instances_per_component,
                mix: StrategyMix { bnb: p.mix_bnb, beam: p.mix_beam, anneal: p.mix_anneal },
                width: p.beam_width,
                restarts: p.anneal_restarts,
                steps: p.anneal_steps,
                seed: p.anneal_seed,
                budget: p.budget(),
                ..Default::default()
            })
        },
    },
];

/// Every registered policy, canonical-name order.
pub fn policies() -> &'static [PolicyInfo] {
    POLICIES
}

/// Canonical policy names.
pub fn names() -> Vec<&'static str> {
    POLICIES.iter().map(|p| p.name).collect()
}

/// Deprecated spellings already warned about (once per process).
static WARNED: Mutex<BTreeSet<String>> = Mutex::new(BTreeSet::new());

fn warn_deprecated(alias: &str, canonical: &'static str) {
    let mut warned = WARNED.lock().unwrap_or_else(|e| e.into_inner());
    if !warned.insert(alias.to_string()) {
        return;
    }
    if crate::obs::enabled() {
        crate::obs::global().journal().record(crate::obs::Event::DeprecatedAlias {
            alias: alias.into(),
            canonical: canonical.into(),
        });
    }
}

/// Shared row lookup: one registry scan serves both [`canonical`] and
/// [`create`], so neither needs a second fallible lookup.  Deprecated
/// spellings resolve with a once-per-process journal warning.
fn lookup(name: &str) -> Result<&'static PolicyInfo> {
    if let Some(p) = POLICIES.iter().find(|p| p.name == name || p.aliases.contains(&name)) {
        return Ok(p);
    }
    if let Some(p) = POLICIES.iter().find(|p| p.deprecated.contains(&name)) {
        warn_deprecated(name, p.name);
        return Ok(p);
    }
    Err(Error::Config(format!(
        "unknown scheduler policy '{name}' (valid: {})",
        names().join("|")
    )))
}

/// Resolve `name` (canonical, alias, or deprecated alias) to its
/// canonical name.
pub fn canonical(name: &str) -> Result<&'static str> {
    lookup(name).map(|p| p.name)
}

/// Construct the policy registered under `name` (canonical or alias).
pub fn create(name: &str, params: &PolicyParams) -> Result<Box<dyn Scheduler>> {
    lookup(name).map(|info| (info.factory)(params))
}

/// Multi-line listing for `hstorm schedule --list-policies`: summary,
/// aliases, deprecated spellings and the per-policy parameter schema.
pub fn describe_all() -> String {
    let mut out = String::from("registered scheduling policies:\n");
    for p in POLICIES {
        let aliases = if p.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", p.aliases.join(", "))
        };
        let deprecated = if p.deprecated.is_empty() {
            String::new()
        } else {
            format!(" (deprecated: {})", p.deprecated.join(", "))
        };
        out.push_str(&format!("  {:<10}{aliases}{deprecated}\n      {}\n", p.name, p.summary));
        for spec in p.params {
            out.push_str(&format!(
                "        {} ({}, default {}) — {}\n",
                spec.name, spec.ty, spec.default, spec.doc
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_and_aliases_resolve() {
        assert_eq!(canonical("hetero").unwrap(), "hetero");
        assert_eq!(canonical("proposed").unwrap(), "hetero");
        assert_eq!(canonical("default-rr").unwrap(), "default");
        assert_eq!(canonical("branch-and-bound").unwrap(), "bnb");
        assert_eq!(canonical("local-search").unwrap(), "anneal");
        // deprecated spellings still resolve (with a one-time warning)
        assert_eq!(canonical("rr").unwrap(), "default");
        assert_eq!(canonical("exhaustive").unwrap(), "optimal");
        let err = canonical("round-robin").unwrap_err().to_string();
        assert!(err.contains("hetero") && err.contains("optimal"), "{err}");
        assert!(err.contains("portfolio"), "{err}");
    }

    #[test]
    fn create_builds_named_policy() {
        for info in policies() {
            let s = create(info.name, &PolicyParams::default()).unwrap();
            assert_eq!(s.name(), info.name);
            for alias in info.aliases.iter().chain(info.deprecated) {
                assert_eq!(create(alias, &PolicyParams::default()).unwrap().name(), info.name);
            }
        }
        assert!(create("nope", &PolicyParams::default()).is_err());
    }

    #[test]
    fn describe_all_mentions_every_policy_and_schema() {
        let d = describe_all();
        for info in policies() {
            assert!(d.contains(info.name), "{d}");
            for spec in info.params {
                assert!(d.contains(spec.name), "missing param {} in:\n{d}", spec.name);
            }
        }
        assert!(d.contains("deprecated: rr"), "{d}");
    }

    #[test]
    fn params_set_parses_and_rejects_loudly() {
        let mut p = PolicyParams::default();
        p.set("budget-candidates", "5000").unwrap();
        p.set("target-gap", "0.1").unwrap();
        p.set("mix-bnb", "0.7").unwrap();
        p.set("beam-width", "16").unwrap();
        p.set("anneal-seed", "42").unwrap();
        assert_eq!(p.budget_candidates, Some(5000));
        assert_eq!(p.budget().max_candidates, Some(5000));
        assert_eq!(p.target_gap, Some(0.1));
        assert_eq!(p.mix_bnb, 0.7);
        assert_eq!(p.beam_width, 16);
        assert_eq!(p.anneal_seed, 42);

        let err = p.set("beam-widht", "16").unwrap_err().to_string();
        assert!(err.contains("unknown policy parameter"), "{err}");
        assert!(err.contains("beam-width"), "typo error must list valid keys: {err}");
        let err = p.set("beam-width", "wide").unwrap_err().to_string();
        assert!(err.contains("invalid value"), "{err}");
    }

    #[test]
    fn deprecated_alias_warns_once() {
        // drain any earlier state: resolving twice must journal at most
        // one deprecated_alias event for this spelling
        let before = crate::obs::global()
            .journal()
            .entries()
            .iter()
            .filter(|e| matches!(e.event, crate::obs::Event::DeprecatedAlias { .. }))
            .count();
        canonical("exhaustive").unwrap();
        canonical("exhaustive").unwrap();
        let after = crate::obs::global()
            .journal()
            .entries()
            .iter()
            .filter(|e| matches!(e.event, crate::obs::Event::DeprecatedAlias { .. }))
            .count();
        assert!(after <= before + 1, "deprecated alias warned more than once");
    }
}
