//! Log-bucketed histogram and RAII span timer.
//!
//! The histogram spends one atomic add per observation on a
//! power-of-two bucket grid: 64 sub-buckets per octave over
//! `2^-32 .. 2^32` (4096 buckets), giving ~1.1% relative quantile
//! error across 19 decades — microsecond span timings and
//! multi-second controller horizons share one layout.  Count, exact
//! sum and exact min/max ride alongside the buckets, so `mean` and
//! `max` are exact while `p50/p95/p99` are bucketed.  Everything is
//! lock-free and mergeable, matching the shard-and-merge shape of the
//! parallel kernel search.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Sub-buckets per octave (power of two so the index math is exact).
const SUB: f64 = 64.0;
/// Octaves below 1.0 covered by the grid.
const OCTAVES_BELOW: f64 = 32.0;
/// Total bucket count: 64 octaves x 64 sub-buckets.
pub const N_BUCKETS: usize = 4096;

/// Lock-free log-bucketed histogram of non-negative `f64` samples.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Exact sum, stored as `f64` bits and updated with a CAS loop.
    sum_bits: AtomicU64,
    /// Exact extremes as `f64` bits; valid because non-negative IEEE-754
    /// doubles order the same as their bit patterns.
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }
}

fn bucket_of(v: f64) -> usize {
    if v <= 0.0 || !v.is_finite() {
        return if v.is_finite() { 0 } else { N_BUCKETS - 1 };
    }
    let idx = (v.log2() + OCTAVES_BELOW) * SUB;
    (idx.max(0.0) as usize).min(N_BUCKETS - 1)
}

/// Geometric midpoint of bucket `i` — the representative a quantile
/// lookup reports before clamping to the observed `[min, max]`.
fn representative(i: usize) -> f64 {
    ((i as f64 + 0.5) / SUB - OCTAVES_BELOW).exp2()
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.  Negative samples clamp to bucket zero; the
    /// exact sum/min/max still see the clamped value so the invariants
    /// `min <= mean <= max` and `p50 <= max` hold by construction.
    pub fn observe(&self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { return };
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.min_bits.fetch_min(v.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(v.to_bits(), Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Exact mean; 0.0 with no samples.
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Exact minimum; 0.0 with no samples.
    pub fn min(&self) -> f64 {
        let v = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        if v.is_finite() {
            v
        } else {
            0.0
        }
    }

    /// Exact maximum; 0.0 with no samples.
    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Nearest-rank quantile (`q` in `[0, 1]`) over the bucket grid.
    /// The bucket's geometric midpoint is clamped to the observed
    /// `[min, max]`, so quantiles are monotone in `q`, `p100 == max`
    /// exactly, and every quantile is positive when `min > 0`.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                return representative(i).clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    /// Fold another histogram into this one (bucket-wise add, exact
    /// sum/extremes combine).  Used by shard-and-merge consumers.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            let v = theirs.load(Ordering::Relaxed);
            if v > 0 {
                mine.fetch_add(v, Ordering::Relaxed);
            }
        }
        let n = other.count.load(Ordering::Relaxed);
        if n == 0 {
            return;
        }
        self.count.fetch_add(n, Ordering::Relaxed);
        self.min_bits.fetch_min(other.min_bits.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max_bits.fetch_max(other.max_bits.load(Ordering::Relaxed), Ordering::Relaxed);
        let add = other.sum();
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + add).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// RAII span timer: measures wall time from construction to drop and
/// observes it (in seconds) into the backing histogram.  A span
/// started while telemetry is disabled ([`super::enabled`]) is a
/// no-op, so hot paths pay nothing for the disabled baseline.
#[derive(Debug)]
pub struct Span {
    armed: Option<(Arc<Histogram>, Instant)>,
}

impl Span {
    /// Start timing into `hist`, honoring the global telemetry switch.
    pub fn start(hist: Arc<Histogram>) -> Span {
        if super::enabled() {
            Span { armed: Some((hist, Instant::now())) }
        } else {
            Span { armed: None }
        }
    }

    /// A span that records nothing (explicit no-op).
    pub fn disabled() -> Span {
        Span { armed: None }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((hist, started)) = self.armed.take() {
            hist.observe(started.elapsed().as_secs_f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.quantile(0.5), 0.0);
    }

    #[test]
    fn mean_and_extremes_are_exact() {
        let h = Histogram::new();
        for v in [0.010, 0.020, 0.030] {
            h.observe(v);
        }
        assert_eq!(h.count(), 3);
        assert!((h.mean() - 0.020).abs() < 1e-12);
        assert_eq!(h.min(), 0.010);
        assert_eq!(h.max(), 0.030);
    }

    #[test]
    fn quantiles_are_monotone_and_bounded() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 / 1000.0);
        }
        let mut last = 0.0;
        for q in [0.10, 0.50, 0.90, 0.95, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(v >= last, "q{q}: {v} < {last}");
            assert!(v >= h.min() && v <= h.max(), "q{q} out of range: {v}");
            last = v;
        }
        assert_eq!(h.quantile(1.0), h.max());
    }

    #[test]
    fn quantile_relative_error_within_bucket_width() {
        // 64 sub-buckets per octave -> representative within ~1.1% of
        // any sample in the bucket
        let h = Histogram::new();
        for i in 0..10_000 {
            h.observe(1e-3 * (1.0 + i as f64 / 10_000.0));
        }
        let p50 = h.quantile(0.5);
        let exact = 1.5e-3;
        assert!((p50 - exact).abs() / exact < 0.02, "p50 {p50} vs {exact}");
    }

    #[test]
    fn negative_and_zero_samples_clamp_to_floor_bucket() {
        let h = Histogram::new();
        h.observe(-1.0);
        h.observe(0.0);
        h.observe(5.0);
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 5.0);
        assert!(h.quantile(0.01) >= 0.0);
    }

    #[test]
    fn merge_combines_counts_sums_and_extremes() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.observe(1.0);
        a.observe(2.0);
        b.observe(0.5);
        b.observe(8.0);
        a.merge_from(&b);
        assert_eq!(a.count(), 4);
        assert!((a.sum() - 11.5).abs() < 1e-12);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.max(), 8.0);
        // merging an empty histogram changes nothing
        a.merge_from(&Histogram::new());
        assert_eq!(a.count(), 4);
        assert_eq!(a.min(), 0.5);
    }

    #[test]
    fn span_observes_elapsed_seconds_on_drop() {
        let h = Arc::new(Histogram::new());
        {
            let _s = Span::start(h.clone());
        }
        assert_eq!(h.count(), 1);
        assert!(h.max() >= 0.0);
        // a disabled span records nothing
        {
            let _s = Span::disabled();
        }
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn concurrent_observers_lose_nothing() {
        let h = Arc::new(Histogram::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let h = h.clone();
                scope.spawn(move || {
                    for _ in 0..10_000 {
                        h.observe(0.25);
                    }
                });
            }
        });
        assert_eq!(h.count(), 40_000);
        assert!((h.sum() - 10_000.0).abs() < 1e-6);
        assert_eq!(h.min(), 0.25);
        assert_eq!(h.max(), 0.25);
    }
}
