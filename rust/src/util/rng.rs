//! Deterministic PRNG: SplitMix64 (Steele et al., "Fast splittable
//! pseudorandom number generators", OOPSLA 2014).
//!
//! Small, fast, and good enough for workload generation, sampled search
//! and property testing.  Deterministic by seed — every randomized
//! experiment in this repo reports its seed.

/// SplitMix64 state.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (split).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..1000 {
            seen[r.range(0, 3)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut a = Rng::new(11);
        let mut s1 = a.split();
        let mut s2 = a.split();
        assert_ne!(s1.next_u64(), s2.next_u64());
    }
}
