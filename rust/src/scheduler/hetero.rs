//! The paper's heterogeneity-aware scheduler (§5, Algorithms 1 & 2).
//!
//! Phase 1 — `FirstAssignment` (Alg. 1): take one instance of every
//! component and map it to the machine with the least predicted TCU
//! (eq. 5) at the initial rate `R0`.
//!
//! Phase 2 — `MaximizeThroughput` (Alg. 2): repeatedly
//!
//! 1. predict machine utilizations at the current rate;
//! 2. if nothing is over-utilized, checkpoint the state as the latest
//!    stable schedule and raise the rate by `Current_IR / Scale`;
//! 3. otherwise take a **new instance of the hottest task's component**
//!    on the first over-utilized machine and place it on the most
//!    suitable machine with enough capacity;
//! 4. if no machine can host it, halve the rate increment (`Scale *= 2`),
//!    roll back to the last stable schedule, and retry;
//! 5. terminate when `Current_IR <= Scale` — no capacity is left and the
//!    increment has collapsed.
//!
//! Rollback detail: the paper's pseudo-code restores `Current_ETG` from
//! `Final_ETG` but leaves `Current_IR` implicit; we restore the last
//! stable rate and re-apply the (now smaller) increment, which preserves
//! the intent — retry from the stable state with a finer step — and
//! guarantees termination (documented in DESIGN.md).
//!
//! Request constraints are honored *inside* the search: Alg. 1 and the
//! host selection skip excluded/pinned-away machines, instance growth
//! stops at a component's `max_instances` cap, and over-utilization is
//! judged against headroom-reduced budgets.  Placement evaluations go
//! through a [`PlacementScorer`], so the same algorithm runs against the
//! PJRT-compiled AOT model (the production path) or the native mirror.

use std::time::Instant;

use super::problem::ResolvedConstraints;
use super::{apply_objective, Problem, Provenance, Schedule, ScheduleRequest, Scheduler};
use crate::predict::kernel;
use crate::predict::{Evaluation, Evaluator, Placement};
use crate::runtime::scorer::{NativeScorer, PlacementScorer, ScoreRow};
use crate::topology::Topology;
use crate::{Error, Result};

/// Tunables for the paper's algorithm.
#[derive(Debug, Clone)]
pub struct HeteroScheduler {
    /// Topology initial input rate `R0` (tuples/s).  The paper starts its
    /// profiling-style runs at 8 tuple/s.
    pub r0: f64,
    /// Upper bound on executors per worker (the paper's `k_j`).
    pub max_tasks_per_machine: usize,
    /// Safety bound on Alg. 2 iterations.
    pub max_iterations: usize,
    /// Post-pass refinement (the paper's §8 "possible improvements of the
    /// scheduler efficiency" future work): greedily prune instances whose
    /// MET overhead outweighs their share, and hill-climb single-instance
    /// moves, as long as the max stable rate improves.
    pub refine: bool,
}

impl Default for HeteroScheduler {
    fn default() -> Self {
        HeteroScheduler {
            r0: 8.0,
            max_tasks_per_machine: 32,
            max_iterations: 100_000,
            refine: true,
        }
    }
}

impl HeteroScheduler {
    pub fn with_r0(r0: f64) -> Self {
        HeteroScheduler { r0, ..Default::default() }
    }

    /// Greedy refinement: (a) drop instances whose removal raises the max
    /// stable rate (their MET cost exceeded their sharing benefit);
    /// (b) move single instances to better hosts while the rate improves.
    ///
    /// Runs on [`kernel::DeltaEval`], the shared incremental eq.-5 state:
    /// every candidate prune/move is probed in `O(machines)` against the
    /// maintained per-machine slope/intercept, and an accepted delta
    /// recomputes only the affected machine columns — no placement
    /// clones, no `counts()` allocations (§Perf in EXPERIMENTS.md: this
    /// took the 180-machine schedule from ~712 ms to the recorded
    /// figure; the kernel rewires it onto the engine the optimal search
    /// shares).
    fn refine_placement(
        &self,
        ev: &Evaluator,
        rc: &ResolvedConstraints,
        p: Placement,
        evaluated: &mut u64,
    ) -> Result<Placement> {
        let n_m = ev.n_machines();
        let n_c = p.n_components();
        let mut de = kernel::DeltaEval::new(ev, &p)?;

        loop {
            let mut best_rate = de.rate();
            *evaluated += 1;
            let mut improved = false;

            // (a) prune: removing one instance of c from machine `drop_m`
            // re-shares the stream over n-1 instances (slope of every
            // machine hosting c changes)
            'prune: for c in 0..n_c {
                if de.count(c) <= 1 {
                    continue;
                }
                for drop_m in 0..n_m {
                    if de.get(c, drop_m) == 0 {
                        continue;
                    }
                    let r = de.rate_removing(c, drop_m);
                    *evaluated += 1;
                    if r > best_rate * (1.0 + 1e-9) {
                        de.apply_remove(c, drop_m);
                        improved = true;
                        break 'prune; // shares changed: restart the sweep
                    }
                }
            }
            if improved {
                continue;
            }

            // (b) single-instance moves (count unchanged: only from/to move)
            'moves: for c in 0..n_c {
                for from in 0..n_m {
                    if de.get(c, from) == 0 {
                        continue;
                    }
                    for to in 0..n_m {
                        if to == from
                            || !rc.allows(c, to)
                            || de.tasks_on(to) as usize >= self.max_tasks_per_machine
                        {
                            continue;
                        }
                        let r = de.rate_with_move(c, from, to);
                        *evaluated += 1;
                        if r > best_rate * (1.0 + 1e-9) {
                            de.apply_move(c, from, to);
                            best_rate = r;
                            improved = true;
                            if de.get(c, from) == 0 {
                                continue 'moves;
                            }
                        }
                    }
                }
            }
            if !improved {
                return Ok(de.placement());
            }
        }
    }

    /// Alg. 1: one instance per component on its least-TCU machine
    /// (among machines the constraints allow for the component and that
    /// stay under the per-worker task bound `k_j`).  Machines whose
    /// remaining budget cannot absorb the instance's TCU at `R0` are
    /// deprioritized: with the usual budgets (caps near 100 and seed
    /// TCUs of a few points at the default `R0`) every machine fits and
    /// the selection is exactly the paper's, but under reserved
    /// residual capacities (incremental tenant admission) — or extreme
    /// headroom requests that leave less budget than one seed TCU —
    /// the seed avoids starting on a machine that is already full,
    /// falling back to plain least-TCU only when nothing fits.
    pub fn first_assignment(
        &self,
        ev: &Evaluator,
        top: &Topology,
        rc: &ResolvedConstraints,
    ) -> Result<Placement> {
        let order = top.topo_order()?;
        let mut p = Placement::empty(ev.n_components(), ev.n_machines());
        let mut seeded = vec![0.0f64; ev.n_machines()]; // util of placed seeds at R0
        for &c in &order {
            let mut best_fit: Option<(usize, f64)> = None;
            let mut best_any: Option<(usize, f64)> = None;
            for m in 0..ev.n_machines() {
                if !rc.allows(c, m) || p.tasks_on(m) >= self.max_tasks_per_machine {
                    continue;
                }
                let tcu = ev.tcu_one(c, m, 1, self.r0);
                if best_any.map_or(true, |(_, t)| tcu < t) {
                    best_any = Some((m, tcu));
                }
                if seeded[m] + tcu <= ev.cap[m] + 1e-9
                    && best_fit.map_or(true, |(_, t)| tcu < t)
                {
                    best_fit = Some((m, tcu));
                }
            }
            let (best_m, tcu) = best_fit.or(best_any).ok_or_else(|| {
                Error::Schedule(format!(
                    "no allowed machine with free slots for component {c} during FirstAssignment \
                     (k_j = {}, constraints applied)",
                    self.max_tasks_per_machine
                ))
            })?;
            p.x[c][best_m] = 1;
            seeded[best_m] += tcu;
        }
        Ok(p)
    }

    /// The hottest task (component index) on machine `m`: the instance
    /// with the highest predicted TCU among tasks placed on `m`.
    fn hottest_on(&self, ev: &Evaluator, p: &Placement, m: usize, rate: f64) -> Option<usize> {
        let counts = p.counts();
        let mut best: Option<(usize, f64)> = None;
        for c in 0..p.n_components() {
            if p.x[c][m] == 0 {
                continue;
            }
            let tcu = ev.tcu_one(c, m, counts[c], rate);
            if best.map_or(true, |(_, t)| tcu > t) {
                best = Some((c, tcu));
            }
        }
        best.map(|(c, _)| c)
    }

    /// Find the most suitable machine for a new instance of component
    /// `c`: among allowed machines that (a) stay under their task bound
    /// and (b) stay within capacity *after* the instance is added
    /// (evaluated through the scorer, so rate re-sharing is accounted
    /// for), pick the one giving the new instance the least TCU.
    /// Returns `None` when no host qualifies or the component already
    /// sits at its instance cap.
    fn best_host(
        &self,
        ev: &Evaluator,
        rc: &ResolvedConstraints,
        scorer: &dyn PlacementScorer,
        p: &Placement,
        c: usize,
        rate: f64,
        evaluated: &mut u64,
    ) -> Result<Option<(usize, Placement)>> {
        let n_machines = ev.n_machines();
        let n_before = p.count(c);
        if n_before >= rc.max_instances[c] {
            return Ok(None); // instance cap reached: treat as "no capacity"
        }
        let n_after = n_before + 1;

        if scorer.backend() == "native" {
            // Fast path: the candidate's host utilization differs from the
            // base evaluation only in component c's terms (the stream
            // re-shares n -> n+1), so each candidate is O(1) given one base
            // evaluation — no placement clones (§Perf).
            let base = scorer.score_one(p, rate)?;
            *evaluated += 1;
            let ir = ev.gains[c] * rate;
            let share_old = ir / n_before.max(1) as f64;
            let share_new = ir / n_after as f64;
            let mut best: Option<(usize, f64)> = None;
            for m in 0..n_machines {
                if !rc.allows(c, m) || p.tasks_on(m) >= self.max_tasks_per_machine {
                    continue;
                }
                let k = p.x[c][m] as f64;
                let util_after = base.util[m] - k * ev.e_m[c][m] * share_old
                    + (k + 1.0) * ev.e_m[c][m] * share_new
                    + ev.met_m[c][m];
                if util_after > ev.cap[m] + 1e-6 {
                    continue;
                }
                let headroom = ev.cap[m] - util_after;
                let tcu = ev.tcu_one(c, m, n_after, rate);
                let score = -headroom + tcu * 1e-3;
                if best.map_or(true, |(_, s)| score < s) {
                    best = Some((m, score));
                }
            }
            return Ok(best.map(|(m, _)| {
                let mut q = p.clone();
                q.x[c][m] += 1;
                (m, q)
            }));
        }

        // PJRT path: build every candidate and score them in one batch
        // (a single scorer_b256 execution).
        let mut cands: Vec<(usize, Placement)> = Vec::new();
        for m in 0..n_machines {
            if !rc.allows(c, m) || p.tasks_on(m) >= self.max_tasks_per_machine {
                continue;
            }
            let mut q = p.clone();
            q.x[c][m] += 1;
            cands.push((m, q));
        }
        if cands.is_empty() {
            return Ok(None);
        }
        let placements: Vec<Placement> = cands.iter().map(|(_, q)| q.clone()).collect();
        let rates = vec![rate; placements.len()];
        let rows = scorer.score_batch(&placements, &rates)?;
        *evaluated += rows.len() as u64;
        let mut best: Option<(usize, f64, usize)> = None; // (machine, score, cand idx)
        for (i, ((m, _), row)) in cands.iter().zip(&rows).enumerate() {
            // the host itself must end up within budget
            if row.util[*m] > ev.cap[*m] + 1e-6 {
                continue;
            }
            // "most suitable machine": the host keeping the most headroom
            // after absorbing the instance, tie-broken by the instance's
            // own TCU (favors fast machines at equal headroom).
            let headroom = ev.cap[*m] - row.util[*m];
            let tcu = ev.tcu_one(c, *m, n_after, rate);
            let score = -headroom + tcu * 1e-3;
            if best.map_or(true, |(_, s, _)| score < s) {
                best = Some((*m, score, i));
            }
        }
        Ok(best.map(|(m, _, i)| (m, cands.swap_remove(i).1)))
    }

    /// First over-utilized machine under `row`, if any.
    fn first_over(&self, ev: &Evaluator, row: &ScoreRow) -> Option<usize> {
        row.util
            .iter()
            .enumerate()
            .find(|(m, &u)| u > ev.cap[*m] + 1e-6)
            .map(|(m, _)| m)
    }

    /// Alg. 1 + Alg. 2 + refinement: the constrained max-throughput
    /// search, returning the placement and its certified rate.
    fn maximize(
        &self,
        ev: &Evaluator,
        top: &Topology,
        cluster: &crate::cluster::Cluster,
        rc: &ResolvedConstraints,
        scorer: &dyn PlacementScorer,
        evaluated: &mut u64,
    ) -> Result<(Placement, f64)> {
        let mut placement = self.first_assignment(ev, top, rc)?;
        let mut scale = 1.0f64;
        let mut current_ir = self.r0;
        let mut final_state: Option<(Placement, f64)> = None;

        for _ in 0..self.max_iterations {
            let row = scorer.score_one(&placement, current_ir)?;
            *evaluated += 1;
            match self.first_over(ev, &row) {
                None => {
                    // stable: checkpoint and raise the rate
                    final_state = Some((placement.clone(), current_ir));
                    current_ir += current_ir / scale;
                }
                Some(m_over) => {
                    let hottest =
                        self.hottest_on(ev, &placement, m_over, current_ir).ok_or_else(|| {
                            Error::Schedule("over-utilized machine hosts no tasks".into())
                        })?;
                    let host =
                        self.best_host(ev, rc, scorer, &placement, hottest, current_ir, evaluated)?;
                    match host {
                        Some((_, q)) => {
                            placement = q;
                        }
                        None => {
                            // no capacity left anywhere (or caps reached)
                            if current_ir > scale {
                                if let Some((fp, fr)) = &final_state {
                                    scale *= 2.0;
                                    placement = fp.clone();
                                    current_ir = fr + fr / scale;
                                } else {
                                    // initial rate was never feasible
                                    return Err(Error::Schedule(format!(
                                        "initial rate R0={} infeasible on this cluster under the \
                                         request's constraints",
                                        self.r0
                                    )));
                                }
                            } else {
                                break;
                            }
                        }
                    }
                }
            }
        }

        let (mut placement, mut rate) =
            final_state.ok_or_else(|| Error::Schedule("no stable schedule found".into()))?;
        if self.refine {
            placement = self.refine_placement(ev, rc, placement, evaluated)?;
            // Also refine from the Round-Robin assignment of the same ETG:
            // greedy growth can land in a local optimum the RR seed
            // escapes, and this guarantees the proposed schedule never
            // loses to the default scheduler on its own instance counts.
            let etg = crate::topology::Etg { counts: placement.counts() };
            if let Ok(rr) =
                crate::scheduler::default_rr::DefaultScheduler::assign_constrained(
                    top, cluster, &etg, rc,
                )
            {
                let rr_refined = self.refine_placement(ev, rc, rr, evaluated)?;
                if ev.max_stable_rate(&rr_refined)? > ev.max_stable_rate(&placement)? {
                    placement = rr_refined;
                }
            }
            rate = ev.max_stable_rate(&placement)?.max(rate);
        }
        Ok((placement, rate))
    }

    /// Solve an already-resolved request against one scorer.
    fn solve(
        &self,
        problem: &Problem,
        req: &ScheduleRequest,
        rc: &ResolvedConstraints,
        ev: &Evaluator,
        scorer: &dyn PlacementScorer,
    ) -> Result<Schedule> {
        let started = Instant::now();
        let mut evaluated = 0u64;
        if crate::obs::enabled() {
            crate::obs::global().journal().record(crate::obs::Event::SearchStarted {
                policy: self.name().into(),
                components: problem.topology().n_components(),
                machines: problem.cluster().n_machines(),
            });
        }
        let (placement, rate) =
            self.maximize(ev, problem.topology(), problem.cluster(), rc, scorer, &mut evaluated)?;
        let row = scorer.score_one(&placement, rate)?;
        evaluated += 1;
        let eval = Evaluation {
            util: row.util,
            throughput: row.throughput,
            feasible: row.feasible,
            ir_comp: row.ir_comp,
        };
        let pre_objective_rate = rate;
        let s = Schedule { placement, rate, eval, provenance: Provenance::default() };
        let mut s = apply_objective(
            ev,
            rc,
            &req.objective,
            s,
            self.max_tasks_per_machine,
            &mut evaluated,
        )?;
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: scorer.backend().into(),
            wall: started.elapsed(),
            ..Default::default()
        };
        if crate::obs::enabled() && (pre_objective_rate - s.rate).abs() > 1e-9 {
            crate::obs::global().journal().record(crate::obs::Event::RunnerUp {
                policy: self.name().into(),
                label: "pre-objective".into(),
                rate: pre_objective_rate,
            });
        }
        crate::scheduler::record_schedule_telemetry(&s, 0);
        crate::scheduler::debug_validate(problem, req, &s);
        Ok(s)
    }

    /// Solve the request with an explicit scorer (the PJRT path in
    /// production; tests cross-check it against the native mirror).
    pub fn schedule_with_scorer(
        &self,
        problem: &Problem,
        req: &ScheduleRequest,
        scorer: &dyn PlacementScorer,
    ) -> Result<Schedule> {
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        self.solve(problem, req, &rc, &ev, scorer)
    }
}

impl Scheduler for HeteroScheduler {
    fn name(&self) -> &'static str {
        "hetero"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        match problem.scorer() {
            Some(scorer) => self.solve(problem, req, &rc, &ev, scorer),
            None => {
                let scorer = NativeScorer::from_evaluator(ev.into_owned());
                self.solve(problem, req, &rc, scorer.evaluator(), &scorer)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::Constraints;
    use crate::topology::benchmarks;

    fn problem(top: &Topology) -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(top, &cluster, &db).unwrap()
    }

    fn run(top: &Topology) -> (Schedule, Problem) {
        let p = problem(top);
        let s =
            HeteroScheduler::default().schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        (s, p)
    }

    #[test]
    fn first_assignment_prefers_least_tcu() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let rc = p.resolve(&Constraints::new()).unwrap();
        let hs = HeteroScheduler::default();
        let pl = hs.first_assignment(p.evaluator(), &top, &rc).unwrap();
        // Table 3: the Pentium worker (machine 0) has the lowest e for
        // every micro-benchmark task type, so everything starts there.
        for c in 0..top.n_components() {
            assert_eq!(pl.x[c][0], 1, "component {c}");
            assert_eq!(pl.count(c), 1);
        }
    }

    #[test]
    fn first_assignment_respects_exclusion() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let rc = p.resolve(&Constraints::new().exclude_machine("pentium-0")).unwrap();
        let hs = HeteroScheduler::default();
        let pl = hs.first_assignment(p.evaluator(), &top, &rc).unwrap();
        for c in 0..top.n_components() {
            assert_eq!(pl.x[c][0], 0, "component {c} landed on the excluded pentium");
        }
    }

    #[test]
    fn schedule_is_feasible_and_saturating() {
        for top in benchmarks::micro() {
            let (s, p) = run(&top);
            let ev = p.evaluator();
            assert!(s.eval.feasible, "{}: infeasible result", top.name);
            assert!(s.rate >= 8.0, "{}: rate {}", top.name, s.rate);
            // every component keeps >= 1 instance
            for c in 0..top.n_components() {
                assert!(s.placement.count(c) >= 1);
            }
            // no machine over budget
            for (m, u) in s.eval.util.iter().enumerate() {
                assert!(*u <= ev.cap[m] + 1e-6, "{}: machine {m} at {u}%", top.name);
            }
            // provenance is stamped
            assert_eq!(s.provenance.policy, "hetero");
            assert_eq!(s.provenance.backend, "native");
            assert!(s.provenance.placements_evaluated > 0);
        }
    }

    #[test]
    fn beats_default_rr_on_micro() {
        use crate::scheduler::default_rr::DefaultScheduler;
        use crate::topology::Etg;
        for top in benchmarks::micro() {
            let p = problem(&top);
            let ours = HeteroScheduler::default()
                .schedule(&p, &ScheduleRequest::max_throughput())
                .unwrap();
            let etg = Etg { counts: ours.placement.counts() };
            let rr = DefaultScheduler::with_etg(etg)
                .schedule(&p, &ScheduleRequest::max_throughput())
                .unwrap();
            assert!(
                ours.eval.throughput >= rr.eval.throughput * 0.999,
                "{}: ours {} < rr {}",
                top.name,
                ours.eval.throughput,
                rr.eval.throughput
            );
        }
    }

    #[test]
    fn respects_task_bound() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let hs = HeteroScheduler { max_tasks_per_machine: 2, ..Default::default() };
        let s = hs.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        for m in 0..p.cluster().n_machines() {
            assert!(s.placement.tasks_on(m) <= 2);
        }
    }

    #[test]
    fn respects_instance_cap() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let high =
            top.components.iter().position(|c| c.task_type == "highCompute").unwrap();
        let name = top.components[high].name.clone();
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().max_instances(&name, 1));
        let s = HeteroScheduler::default().schedule(&p, &req).unwrap();
        assert_eq!(s.placement.count(high), 1, "instance cap ignored");
        assert!(s.eval.feasible);
    }

    #[test]
    fn infeasible_r0_errors() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let hs = HeteroScheduler { r0: 1e9, max_tasks_per_machine: 4, ..Default::default() };
        assert!(hs.schedule(&p, &ScheduleRequest::max_throughput()).is_err());
    }

    #[test]
    fn deterministic() {
        let top = benchmarks::diamond();
        let p = problem(&top);
        let a =
            HeteroScheduler::default().schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        let b =
            HeteroScheduler::default().schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        assert_eq!(a.placement, b.placement);
        assert!((a.rate - b.rate).abs() < 1e-9);
    }
}
