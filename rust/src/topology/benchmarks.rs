//! The paper's evaluation topologies.
//!
//! * Micro-Benchmark (R-Storm [6], paper Fig. 5): **Linear**, **Diamond**,
//!   **Star**, assembled from `lowCompute` / `midCompute` / `highCompute`
//!   CPU-intensive components.  The gray bolt in Fig. 5 (the profiled one
//!   in Fig. 6) is `highCompute`.
//! * Storm-Benchmark [15]: **RollingCount** and **UniqueVisitor** — a
//!   spout plus two bolts each; used for the instance-count study
//!   (Fig. 7).

use super::builder::TopologyBuilder;
use super::Topology;

/// Profile key of the spout (negligible per-tuple cost, it only emits).
pub const SPOUT_TYPE: &str = "spout";

/// Linear micro-benchmark: spout → low → mid → high (Fig. 5 left).
pub fn linear() -> Topology {
    TopologyBuilder::new("linear")
        .spout("spout", SPOUT_TYPE, 1.0)
        .bolt("low", "lowCompute", 1.0, &["spout"])
        .bolt("mid", "midCompute", 1.0, &["low"])
        .bolt("high", "highCompute", 1.0, &["mid"])
        .build()
        .expect("linear benchmark is valid")
}

/// Diamond micro-benchmark: spout fans out to three parallel bolts which
/// all feed the `highCompute` sink (Fig. 5 middle).
pub fn diamond() -> Topology {
    TopologyBuilder::new("diamond")
        .spout("spout", SPOUT_TYPE, 1.0)
        .bolt("branch-a", "lowCompute", 1.0, &["spout"])
        .bolt("branch-b", "midCompute", 1.0, &["spout"])
        .bolt("branch-c", "lowCompute", 1.0, &["spout"])
        .bolt("sink", "highCompute", 1.0, &["branch-a", "branch-b", "branch-c"])
        .build()
        .expect("diamond benchmark is valid")
}

/// Star micro-benchmark: multiple spouts feed a central `highCompute`
/// bolt which fans out to multiple sinks (Fig. 5 right).
pub fn star() -> Topology {
    TopologyBuilder::new("star")
        .spout("spout-a", SPOUT_TYPE, 1.0)
        .spout("spout-b", SPOUT_TYPE, 1.0)
        .bolt("center", "highCompute", 1.0, &["spout-a", "spout-b"])
        .bolt("sink-a", "lowCompute", 1.0, &["center"])
        .bolt("sink-b", "midCompute", 1.0, &["center"])
        .build()
        .expect("star benchmark is valid")
}

/// Storm-Benchmark RollingCount: spout → split → rolling-count.
/// `split` emits one word per sentence fragment (α > 1 in the real
/// benchmark; we profile it as mid-cost with α = 1.5), the counter is
/// cheap per tuple.
pub fn rolling_count() -> Topology {
    TopologyBuilder::new("rolling-count")
        .spout("sentence-spout", SPOUT_TYPE, 1.0)
        .bolt("split", "midCompute", 1.5, &["sentence-spout"])
        .bolt("rolling-count", "lowCompute", 1.0, &["split"])
        .build()
        .expect("rolling-count benchmark is valid")
}

/// Storm-Benchmark UniqueVisitor: spout → extract → unique-count.
/// Extraction is cheap, the distinct-count bolt is the heavy stage.
pub fn unique_visitor() -> Topology {
    TopologyBuilder::new("unique-visitor")
        .spout("view-spout", SPOUT_TYPE, 1.0)
        .bolt("extract", "lowCompute", 1.0, &["view-spout"])
        .bolt("unique-count", "midCompute", 1.0, &["extract"])
        .build()
        .expect("unique-visitor benchmark is valid")
}

/// All five evaluation topologies.
pub fn all() -> Vec<Topology> {
    vec![linear(), diamond(), star(), rolling_count(), unique_visitor()]
}

/// The three Micro-Benchmark topologies used in Figs. 3/6/8/9/10.
pub fn micro() -> Vec<Topology> {
    vec![linear(), diamond(), star()]
}

/// Canonical names accepted by [`by_name`] (CLI error surfaces list
/// these so typos fail with the valid options).
pub const NAMES: [&str; 5] = ["linear", "diamond", "star", "rolling-count", "unique-visitor"];

/// Look a benchmark up by name (CLI/config surface).
pub fn by_name(name: &str) -> Option<Topology> {
    match name {
        "linear" => Some(linear()),
        "diamond" => Some(diamond()),
        "star" => Some(star()),
        "rolling-count" | "rollingcount" => Some(rolling_count()),
        "unique-visitor" | "uniquevisitor" => Some(unique_visitor()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_roundtrip() {
        for t in all() {
            let got = by_name(&t.name).unwrap();
            assert_eq!(got.n_components(), t.n_components());
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn names_const_matches_by_name() {
        for name in NAMES {
            assert!(by_name(name).is_some(), "NAMES lists unknown topology '{name}'");
        }
        assert_eq!(NAMES.len(), all().len());
    }

    #[test]
    fn star_has_two_spouts() {
        assert_eq!(star().spouts().len(), 2);
    }

    #[test]
    fn micro_is_three() {
        assert_eq!(micro().len(), 3);
    }

    #[test]
    fn rolling_count_alpha_amplifies() {
        let g = rolling_count().rate_gains().unwrap();
        // split has α=1.5 so the counter sees 1.5× the spout rate
        assert!((g[2] - 1.5).abs() < 1e-12);
    }
}
