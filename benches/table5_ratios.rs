//! Bench: regenerate Table 5 (throughput-gain / utilization-gain ratios)
//! from the Fig. 10 simulation cells.
//! Run: cargo bench --bench table5_ratios  [HSTORM_FAST=1 for quick mode]

use hstorm::experiments::fig10;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig10::table5(fast).expect("table5 runs"));
    println!("{}", result.render());
    println!("[table5_ratios] regenerated in {dt:?} (fast={fast})");
}
