//! End-to-end driver — the full system on a real small workload, proving
//! every layer composes:
//!
//!   1. **Profile** the task types on the engine (the paper's §5.2
//!      pre-process), recovering `e_ij`/`MET_ij` from measurements.
//!   2. **Schedule** each Micro-Benchmark topology with the proposed
//!      algorithm, with placement evaluations flowing through the
//!      **PJRT-compiled AOT model** (L2 JAX + L1 Pallas — Python not in
//!      the process).
//!   3. **Run** the schedule on the stream engine (the "real cluster"),
//!      measuring throughput and per-node utilization.
//!   4. **Compare** against Storm's default Round-Robin scheduler on the
//!      same ETG — the paper's headline metric — and against the
//!      prediction model (the paper's 92% accuracy claim).
//!
//! Requires artifacts: `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example end_to_end
//! ```

use std::time::Duration;

use hstorm::cluster::presets;
use hstorm::engine::{self, EngineConfig};
use hstorm::profiling;
use hstorm::runtime::scorer::PjRtScorer;
use hstorm::runtime::PjRtRuntime;
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::topology::benchmarks;

fn main() -> hstorm::Result<()> {
    println!("== hstorm end-to-end driver ==\n");
    let (cluster, truth) = presets::paper_cluster();

    // ---- 1. profile ------------------------------------------------------
    println!("[1/4] profiling task types on the engine (paper §5.2)...");
    let prof_cfg = EngineConfig {
        duration: Duration::from_millis(1200),
        warmup: Duration::from_millis(400),
        time_scale: 0.5,
        ..Default::default()
    };
    let profiles = profiling::profile_all(&benchmarks::linear(), &cluster, &truth, &prof_cfg)?;
    for tt in ["lowCompute", "midCompute", "highCompute"] {
        for mt in ["pentium", "core-i3", "core-i5"] {
            let m = profiles.get(tt, mt)?;
            let t = truth.get(tt, mt)?;
            println!("  {tt:<12} on {mt:<8}: e = {:.4} (truth {:.4})", m.e, t.e);
        }
    }

    // ---- 2. schedule through PJRT ------------------------------------------
    println!("\n[2/4] scheduling via the AOT-compiled evaluation model (PJRT)...");
    let rt = PjRtRuntime::cpu_default()?;
    println!("  PJRT platform: {}", rt.platform());

    let engine_cfg = EngineConfig {
        duration: Duration::from_secs(3),
        warmup: Duration::from_millis(700),
        time_scale: 0.5,
        ..Default::default()
    };

    let mut gains = Vec::new();
    let mut pred_errs = Vec::new();
    for top in benchmarks::micro() {
        // one Problem per topology, with the PJRT scorer attached: every
        // placement evaluation of the search runs through the AOT model
        let problem = Problem::new(&top, &cluster, &profiles)?
            .with_scorer(Box::new(PjRtScorer::new(&rt, &top, &cluster, &profiles)?));
        let req = ScheduleRequest::max_throughput();
        let ours = registry::create("hetero", &PolicyParams::default())?.schedule(&problem, &req)?;
        // "default" re-derives the same ETG internally (§6.3 protocol)
        let default =
            registry::create("default", &PolicyParams::default())?.schedule(&problem, &req)?;

        // ---- 3. run on the engine ---------------------------------------------
        println!(
            "\n[3/4] running '{}' on the engine (proposed @ {:.0} t/s, default @ {:.0} t/s)...",
            top.name, ours.rate, default.rate
        );
        let ours_rep =
            engine::run(&top, &cluster, &profiles, &ours.placement, ours.rate, &engine_cfg)?;
        let def_rep =
            engine::run(&top, &cluster, &profiles, &default.placement, default.rate, &engine_cfg)?;

        // ---- 4. compare -------------------------------------------------------------
        let gain = (ours_rep.throughput - def_rep.throughput) / def_rep.throughput * 100.0;
        gains.push((top.name.clone(), gain));
        println!("  throughput measured: proposed {:.1} t/s vs default {:.1} t/s  ({gain:+.1}%)",
            ours_rep.throughput, def_rep.throughput);
        for (m, (meas, pred)) in ours_rep.util.iter().zip(&ours.eval.util).enumerate() {
            let err = (meas - pred).abs();
            pred_errs.push(err);
            println!(
                "  {:<10} util measured {:>5.1}%  predicted {:>5.1}%  |err| {:>4.1} pp",
                cluster.machines[m].name, meas, pred, err
            );
        }
    }

    println!("\n[4/4] headline results:");
    for (name, gain) in &gains {
        println!("  {name:<10} throughput gain over default: {gain:+.1}%  (paper: +7%..+44%)");
    }
    let mean_err = pred_errs.iter().sum::<f64>() / pred_errs.len() as f64;
    println!(
        "  CPU prediction mean |err| = {mean_err:.2} pp -> accuracy {:.1}% (paper: >92%)",
        100.0 - mean_err
    );
    Ok(())
}
