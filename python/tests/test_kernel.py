"""Kernel-vs-oracle correctness: the CORE build-time signal.

The Pallas kernels (interpret=True) must match the pure-jnp reference
(fp32 allclose) on fixed cases and under hypothesis sweeps of
shapes/values.
"""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from numpy.testing import assert_allclose

from compile import dims
from compile.kernels.propagate import propagate_step
from compile.kernels.ref import (propagate_ref, propagate_step_ref,
                                 score_utilization_ref)
from compile.kernels.score import score_utilization

jax.config.update("jax_platform_name", "cpu")


def rand_case(b, c, m, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 4, size=(b, c, m)).astype(np.float32)
    ir = (rng.random((b, c)) * 100).astype(np.float32)
    e_m = (rng.random((c, m)) * 0.3).astype(np.float32)
    met = (rng.random((c, m)) * 5).astype(np.float32)
    return x, ir, e_m, met


class TestScoreKernel:
    def test_matches_ref_fixed(self):
        x, ir, e_m, met = rand_case(dims.B_BATCH, dims.C, dims.M)
        got = score_utilization(jnp.array(x), jnp.array(ir), jnp.array(e_m),
                                jnp.array(met))
        want = score_utilization_ref(x, ir, e_m, met)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_batch_one(self):
        x, ir, e_m, met = rand_case(1, dims.C, dims.M, seed=1)
        got = score_utilization(jnp.array(x), jnp.array(ir), jnp.array(e_m),
                                jnp.array(met), block_b=1)
        want = score_utilization_ref(x, ir, e_m, met)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_zero_placement_zero_util(self):
        x = np.zeros((32, dims.C, dims.M), np.float32)
        _, ir, e_m, met = rand_case(32, dims.C, dims.M, seed=2)
        got = score_utilization(jnp.array(x), jnp.array(ir), jnp.array(e_m),
                                jnp.array(met))
        assert np.all(np.asarray(got) == 0.0)

    def test_single_instance_equals_tcu(self):
        """One instance of c0 on m0 -> util[m0] == e*ir + met exactly."""
        c, m = dims.C, dims.M
        x = np.zeros((32, c, m), np.float32)
        x[:, 0, 0] = 1.0
        ir = np.full((32, c), 10.0, np.float32)
        e_m = np.full((c, m), 0.2, np.float32)
        met = np.full((c, m), 3.0, np.float32)
        got = np.asarray(score_utilization(jnp.array(x), jnp.array(ir),
                                           jnp.array(e_m), jnp.array(met)))
        assert_allclose(got[:, 0], 0.2 * 10.0 + 3.0, rtol=1e-6)
        assert np.all(got[:, 1:] == 0.0)

    def test_additive_in_instances(self):
        """util is linear in instance count (eq. 5 per-instance sum)."""
        x, ir, e_m, met = rand_case(32, dims.C, dims.M, seed=3)
        one = np.asarray(score_utilization(jnp.array(x), jnp.array(ir),
                                           jnp.array(e_m), jnp.array(met)))
        two = np.asarray(score_utilization(jnp.array(2 * x), jnp.array(ir),
                                           jnp.array(e_m), jnp.array(met)))
        assert_allclose(two, 2 * one, rtol=1e-5)

    @settings(deadline=None, max_examples=25)
    @given(b=st.sampled_from([1, 2, 4, 8, 32, 64]),
           c=st.integers(1, 16), m=st.integers(1, 32),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shapes(self, b, c, m, seed):
        x, ir, e_m, met = rand_case(b, c, m, seed=seed)
        bb = min(b, 8) if b % min(b, 8) == 0 else 1
        got = score_utilization(jnp.array(x), jnp.array(ir), jnp.array(e_m),
                                jnp.array(met), block_b=bb)
        want = score_utilization_ref(x, ir, e_m, met)
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=1e-4, atol=1e-4)


def linear_adj(c_active, c_total):
    """c0 -> c1 -> ... -> c_{k-1} chain, padded to c_total."""
    adj = np.zeros((c_total, c_total), np.float32)
    for i in range(c_active - 1):
        adj[i, i + 1] = 1.0
    return adj


class TestPropagateKernel:
    def test_matches_ref_fixed(self):
        rng = np.random.default_rng(4)
        b, c = 64, dims.C
        ir = (rng.random((b, c)) * 50).astype(np.float32)
        adj = (rng.random((c, c)) < 0.2).astype(np.float32)
        np.fill_diagonal(adj, 0)
        alpha = rng.random(c).astype(np.float32)
        src = (rng.random((b, c)) * 10).astype(np.float32)
        got = propagate_step(jnp.array(ir), jnp.array(adj), jnp.array(alpha),
                             jnp.array(src))
        want = propagate_step_ref(ir, adj, alpha, src)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_linear_chain_fixed_point(self):
        """Chain with alpha=1: every component sees rate R0 at fixed point."""
        c = dims.C
        adj = linear_adj(5, c)
        alpha = np.ones(c, np.float32)
        src = np.zeros((4, c), np.float32)
        src[:, 0] = 100.0
        ir = propagate_ref(adj, alpha, src, depth=dims.DEPTH)
        assert_allclose(np.asarray(ir[:, :5]), 100.0, rtol=1e-6)
        assert np.all(np.asarray(ir[:, 5:]) == 0.0)

    def test_alpha_scales_downstream(self):
        """alpha=0.5 on each hop halves the rate per stage."""
        c = dims.C
        adj = linear_adj(4, c)
        alpha = np.full(c, 0.5, np.float32)
        src = np.zeros((2, c), np.float32)
        src[:, 0] = 80.0
        ir = np.asarray(propagate_ref(adj, alpha, src, depth=dims.DEPTH))
        assert_allclose(ir[:, 0], 80.0)
        assert_allclose(ir[:, 1], 40.0)
        assert_allclose(ir[:, 2], 20.0)
        assert_allclose(ir[:, 3], 10.0)

    def test_diamond_fanin_sums(self):
        """src -> {a, b} -> sink: sink rate = OR_a + OR_b (full copies)."""
        c = dims.C
        adj = np.zeros((c, c), np.float32)
        adj[0, 1] = adj[0, 2] = 1.0   # spout feeds both branches
        adj[1, 3] = adj[2, 3] = 1.0   # both feed the sink
        alpha = np.ones(c, np.float32)
        src = np.zeros((1, c), np.float32)
        src[:, 0] = 30.0
        ir = np.asarray(propagate_ref(adj, alpha, src, depth=dims.DEPTH))
        assert_allclose(ir[0, 1], 30.0)
        assert_allclose(ir[0, 2], 30.0)
        assert_allclose(ir[0, 3], 60.0)

    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([1, 8, 32]))
    def test_hypothesis_step(self, seed, b):
        rng = np.random.default_rng(seed)
        c = dims.C
        ir = (rng.random((b, c)) * 100).astype(np.float32)
        adj = (rng.random((c, c)) < 0.3).astype(np.float32)
        alpha = (rng.random(c) * 2).astype(np.float32)
        src = (rng.random((b, c)) * 20).astype(np.float32)
        got = propagate_step(jnp.array(ir), jnp.array(adj), jnp.array(alpha),
                             jnp.array(src), block_b=1 if b == 1 else 8)
        want = propagate_step_ref(ir, adj, alpha, src)
        assert_allclose(np.asarray(got), np.asarray(want),
                        rtol=1e-4, atol=1e-4)
