//! Small statistics helpers shared by the simulators.  Percentiles
//! moved to the observability layer's log-bucketed
//! [`crate::obs::Histogram`] (exact mean/max, mergeable, lock-free);
//! only the plain mean remains here.  Kept tiny and dependency-free
//! (the usual stats crates are not in the vendor set — see
//! [`crate::util`]).

/// Arithmetic mean; 0.0 on the empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
