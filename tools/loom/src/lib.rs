//! Loom-backed build of the `hstorm` atomic cores.
//!
//! The main crate splits its concurrency-bearing primitives into
//! standalone "core" source files (`rust/src/obs/histogram_core.rs`,
//! `rust/src/metrics/meanstat_core.rs`) that import every sync
//! primitive from a sibling `sync_shim` module.  In the main crate the
//! shim re-exports `std::sync`; here the same files are re-included by
//! `#[path]` under a shim that re-exports `loom::sync`, so the loom
//! model checker exhaustively permutes every interleaving of the exact
//! production source — no copies, no `cfg(loom)` in the main manifest.
//!
//! The models live in `tests/loom_models.rs`.

/// Loom-backed stand-in for the cores' `super::sync_shim` imports.
pub mod sync_shim {
    pub use loom::sync::atomic::{AtomicU64, Ordering};
    pub use loom::sync::RwLock;
}

#[path = "../../../rust/src/obs/histogram_core.rs"]
pub mod histogram_core;

#[path = "../../../rust/src/metrics/meanstat_core.rs"]
pub mod meanstat_core;
