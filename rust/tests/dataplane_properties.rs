//! Dataplane properties over the full benchmark suite: the batched
//! ring engine must (a) deliver what it is offered at sub-saturation —
//! losslessly, at the predicted utilization — and (b) throttle the
//! spout without shedding or unbounded queues at over-saturation.
//!
//! Every benchmark topology × {hetero, default, optimal} schedule on
//! the paper cluster is executed for real (one thread per machine),
//! with virtual time compressed so each cell runs at high wall rates.

use std::time::Duration;

use hstorm::cluster::presets;
use hstorm::engine::{self, EngineConfig};
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::topology::benchmarks;

const POLICIES: &[&str] = &["hetero", "default", "optimal"];

fn cfg(time_scale: f64) -> EngineConfig {
    EngineConfig {
        duration: Duration::from_millis(600),
        warmup: Duration::from_millis(200),
        time_scale,
        ..Default::default()
    }
}

/// At 0.5x the certified rate the engine must deliver the offered load
/// (throughput within 5%) at the eq.-5 utilization (within 8 pp), with
/// zero loss — over every topology and every scheduling policy.
#[test]
fn half_rate_is_lossless_and_tracks_prediction() {
    let (cluster, db) = presets::paper_cluster();
    for top in benchmarks::all() {
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        for pol in POLICIES {
            let sched = registry::create(pol, &PolicyParams::default()).unwrap();
            let s = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
            let rate = s.rate * 0.5;
            assert!(rate > 0.0, "{}/{pol}: no certified rate", top.name);
            let pred = problem.evaluator().evaluate(&s.placement, rate).unwrap();
            // compress virtual time onto ~1M wall tuples/s so the cell
            // is fast and transport-dominated, like production rates
            let ts = (pred.throughput / 1.0e6).clamp(1e-4, 1.0);
            let rep =
                engine::run(&top, &cluster, &db, &s.placement, rate, &cfg(ts)).unwrap();

            assert_eq!(rep.shed, 0, "{}/{pol}: lossless dataplane shed tuples", top.name);
            assert!(
                !rep.throttled,
                "{}/{pol}: throttled at half the certified rate",
                top.name
            );
            let rel = (rep.throughput - pred.throughput).abs() / pred.throughput;
            assert!(
                rel < 0.05,
                "{}/{pol}: throughput {:.1} vs offered {:.1} (rel {rel:.3})",
                top.name,
                rep.throughput,
                pred.throughput
            );
            for (m, (p, g)) in pred.util.iter().zip(&rep.util).enumerate() {
                let err = (p - g).abs();
                assert!(
                    err < 8.0,
                    "{}/{pol} machine {m}: executed util {g:.1}% vs predicted {p:.1}% \
                     ({err:.1} pp, paper bound 8 pp)",
                    top.name
                );
            }
        }
    }
}

/// At 1.5x the certified rate credits must run out: the spout is
/// throttled (not shedding), queues stay bounded by construction, and
/// the engine still delivers ~capacity.
#[test]
fn saturation_throttles_spout_without_loss() {
    let (cluster, db) = presets::paper_cluster();
    for top in benchmarks::all() {
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        let sched = registry::create("hetero", &PolicyParams::default()).unwrap();
        let s = sched.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        let offered = s.rate * 1.5;
        let cap = problem.evaluator().evaluate(&s.placement, s.rate).unwrap();
        let ts = (cap.throughput / 1.0e6).clamp(1e-4, 1.0);
        // small batches/rings bound the warmup-epoch backlog that
        // drains (uncounted) into the measurement window at saturation
        let run_cfg = EngineConfig { batch: 32, ring_capacity: 8, ..cfg(ts) };
        let rep = engine::run(&top, &cluster, &db, &s.placement, offered, &run_cfg).unwrap();

        assert_eq!(rep.shed, 0, "{}: lossless dataplane shed tuples", top.name);
        assert!(rep.throttled, "{}: credits never ran out at 1.5x", top.name);
        assert!(rep.credit_stalls > 0, "{}: no credit stalls at 1.5x", top.name);
        // the spout was actually held back, not just flagged
        assert!(
            rep.emitted_rate < offered * 0.95,
            "{}: emitted {:.1} of offered {offered:.1} — not throttled",
            top.name,
            rep.emitted_rate
        );
        // delivered throughput stays near certified capacity: bounded
        // queues mean overload cannot inflate it, stalls must not
        // collapse it
        assert!(
            rep.throughput < cap.throughput * 1.25,
            "{}: throughput {:.1} above capacity {:.1}",
            top.name,
            rep.throughput,
            cap.throughput
        );
        assert!(
            rep.throughput > cap.throughput * 0.60,
            "{}: throughput {:.1} collapsed below capacity {:.1}",
            top.name,
            rep.throughput,
            cap.throughput
        );
    }
}
