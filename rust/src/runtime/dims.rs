//! AOT dims: the fixed shapes the HLO artifacts were lowered with.
//!
//! Mirror of `python/compile/dims.py`.  `load_manifest` reads
//! `artifacts/dims.json` and [`check`] asserts the two sides agree before
//! any PJRT execution — a dim drift fails fast instead of producing
//! garbage numerics.

use crate::util::json;
use crate::{Error, Result};

/// Max components the AOT scorer supports (padding masks the rest).
pub const MAX_COMPONENTS: usize = 16;
/// Max machines per scorer call.
pub const MAX_MACHINES: usize = 32;
/// Rate-propagation iterations lowered into the model.
pub const DEPTH: usize = 16;
/// Candidate batch of the exhaustive-search artifact.
pub const B_BATCH: usize = 256;
/// Single-candidate artifact (heuristic scheduler inner loop).
pub const B_ONE: usize = 1;
/// MAC budget (percent) baked into feasibility checks.
pub const CAP: f64 = 100.0;
/// Vector length of the bolt-work kernel.
pub const WORK_N: usize = 64;

/// Parsed `artifacts/dims.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub c: usize,
    pub m: usize,
    pub depth: usize,
    pub b_batch: usize,
    pub b_one: usize,
    pub cap: f64,
    pub work_n: usize,
}

impl Manifest {
    /// Parse from the JSON text `aot.py` emits.
    pub fn parse(text: &str) -> Result<Self> {
        let v = json::parse(text).map_err(|e| Error::Runtime(format!("bad dims.json: {e}")))?;
        let field = |k: &str| -> Result<usize> {
            v.get(k)?
                .as_usize()
                .ok_or_else(|| Error::Runtime(format!("dims.json: '{k}' is not an integer")))
        };
        Ok(Manifest {
            c: field("C")?,
            m: field("M")?,
            depth: field("DEPTH")?,
            b_batch: field("B_BATCH")?,
            b_one: field("B_ONE")?,
            cap: v.num_field("CAP").map_err(|e| Error::Runtime(e.to_string()))?,
            work_n: field("WORK_N")?,
        })
    }
}

/// Load `dims.json` from an artifacts directory.
pub fn load_manifest(artifacts_dir: &std::path::Path) -> Result<Manifest> {
    let path = artifacts_dir.join("dims.json");
    let text = std::fs::read_to_string(&path).map_err(|e| {
        Error::Runtime(format!(
            "cannot read {} (run `make artifacts` first): {e}",
            path.display()
        ))
    })?;
    Manifest::parse(&text)
}

/// Assert the artifact dims match this build's constants.
pub fn check(m: &Manifest) -> Result<()> {
    let pairs = [
        ("C", m.c, MAX_COMPONENTS),
        ("M", m.m, MAX_MACHINES),
        ("DEPTH", m.depth, DEPTH),
        ("B_BATCH", m.b_batch, B_BATCH),
        ("B_ONE", m.b_one, B_ONE),
        ("WORK_N", m.work_n, WORK_N),
    ];
    for (name, got, want) in pairs {
        if got != want {
            return Err(Error::Runtime(format!(
                "artifact dim {name}={got} but crate expects {want}; re-run `make artifacts`"
            )));
        }
    }
    if (m.cap - CAP).abs() > 1e-9 {
        return Err(Error::Runtime(format!("artifact CAP={} != {CAP}", m.cap)));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_checks() {
        let text = r#"{"C":16,"M":32,"DEPTH":16,"B_BATCH":256,"B_ONE":1,
                       "CAP":100.0,"WORK_N":64,"artifacts":{}}"#;
        let m = Manifest::parse(text).unwrap();
        check(&m).unwrap();
    }

    #[test]
    fn dim_mismatch_detected() {
        let text = r#"{"C":8,"M":32,"DEPTH":16,"B_BATCH":256,"B_ONE":1,
                       "CAP":100.0,"WORK_N":64}"#;
        let m = Manifest::parse(text).unwrap();
        assert!(check(&m).is_err());
    }

    #[test]
    fn missing_field_rejected() {
        assert!(Manifest::parse(r#"{"C":16}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
