//! Micro-benchmarks of the scheduling hot paths (the §Perf targets in
//! EXPERIMENTS.md): evaluator, closed-form max-rate, FirstAssignment,
//! full hetero schedule, and the refinement pass, across cluster sizes.
//! Run: cargo bench --bench scheduler_micro  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::{presets, scenarios};
use hstorm::predict::{Evaluator, Placement};
use hstorm::scheduler::default_rr::DefaultScheduler;
use hstorm::scheduler::hetero::HeteroScheduler;
use hstorm::scheduler::Scheduler;
use hstorm::topology::{benchmarks, Etg};
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let iters = if fast { 50 } else { 500 };

    // paper cluster (3 machines)
    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::diamond();
    let ev = Evaluator::new(&top, &cluster, &db).expect("evaluator");
    let mut p = Placement::empty(top.n_components(), cluster.n_machines());
    for c in 0..top.n_components() {
        p.x[c][c % 3] = 1;
    }

    bench::run("evaluate placement (5 comp x 3 machines)", 10, iters * 10, || {
        ev.evaluate(&p, 100.0).expect("evaluates");
    });
    bench::run("max_stable_rate closed form", 10, iters * 10, || {
        ev.max_stable_rate(&p).expect("rate");
    });
    bench::run("hetero schedule (paper cluster)", 2, iters / 5, || {
        HeteroScheduler::default().schedule(&top, &cluster, &db).expect("schedules");
    });
    bench::run("default RR schedule (paper cluster)", 2, iters, || {
        DefaultScheduler::with_etg(Etg { counts: vec![1, 2, 2, 2, 2] })
            .schedule(&top, &cluster, &db)
            .expect("schedules");
    });

    // medium scenario (30 machines)
    let (c30, db30) = scenarios::by_id(2).unwrap().build();
    bench::run("hetero schedule (30 machines)", 1, (iters / 25).max(3), || {
        HeteroScheduler::default().schedule(&top, &c30, &db30).expect("schedules");
    });

    if !fast {
        // large scenario (180 machines)
        let (c180, db180) = scenarios::by_id(3).unwrap().build();
        bench::run("hetero schedule (180 machines)", 1, 3, || {
            HeteroScheduler::default().schedule(&top, &c180, &db180).expect("schedules");
        });
    }
}
