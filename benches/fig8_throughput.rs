//! Bench: regenerate the paper's Fig.8-throughput-comparison table (fig8) and time it.
//! Run: cargo bench --bench fig8_throughput  [HSTORM_FAST=1 for quick mode]

use hstorm::experiments::fig8;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig8::run(fast).expect("fig8 runs"));
    println!("{}", result.render());
    println!("[fig8_throughput] regenerated in {dt:?} (fast={fast})");
}
