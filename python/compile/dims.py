"""Fixed AOT shapes shared by the JAX model, the Pallas kernels and the
Rust runtime.

HLO modules have static shapes, so the scheduler's evaluation model is
lowered once for a padded problem size and the Rust side masks the padding:

* ``C``      — max components in a user topology graph (paper topologies
               have <= 9; RollingCount/UniqueVisitor have 3).
* ``M``      — max worker machines visible to one scorer call.  The
               exhaustive (optimal) scheduler only ever runs on small
               clusters (the paper's point is that it is intractable), so
               32 machines is generous; the heuristic path batches B=1.
* ``DEPTH``  — fixed-point iterations for rate propagation (eq. 6).  A DAG
               with C components converges in <= C iterations.
* ``B_*``    — candidate-batch sizes we emit artifacts for.

Changing any of these requires `make artifacts` and a rebuild; the Rust
runtime asserts the artifact dims match `rust/src/runtime/dims.rs`.
"""

C = 16        # max components
M = 32        # max machines
DEPTH = 16    # rate-propagation iterations (>= longest DAG path)
B_BATCH = 256 # exhaustive-search scoring batch
B_ONE = 1     # single-candidate variant (heuristic scheduler inner loop)
BLOCK_B = 256 # Pallas batch tile (one grid step per batch; a 512 KiB
              # candidate block still fits a TPU core's VMEM)

CAP = 100.0   # MAC budget per machine (percent), paper §4.2

WORK_N = 64   # synthetic bolt-work kernel vector length
