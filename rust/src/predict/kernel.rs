//! Incremental candidate-scoring engine: the hot-loop counterpart of the
//! naive [`Evaluator`] paths.
//!
//! The schedulers explore placements that differ from their neighbours in
//! exactly one component row (the exhaustive search) or one instance (the
//! refinement passes and the control plane), yet the naive paths re-derive
//! every machine's utilization slope/intercept from scratch in `O(C·M)`
//! per candidate, with nested-`Vec` placements and a `counts()` allocation
//! per call.  This module keeps that linear structure *incremental*:
//!
//! * [`PlacementBuf`] — a flat, row-major instance-count arena (`x[c*M+m]`)
//!   used inside the hot loops; the public [`Placement`] stays the API
//!   type, with cheap conversion at the boundary.
//! * [`Row`] / [`RowTable`] — for each enumerated distribution of `k`
//!   instances of component `c`, its per-machine `(a_m, b_m)`
//!   slope/intercept contribution, computed **once**.  A candidate is
//!   then a choice of one row per component, and its closed-form
//!   `R0* = min_m (cap_m - b_m)/a_m` is read off running accumulators.
//! * [`AccumState`] — per-machine `(a, b, tasks)` accumulators with an
//!   undo log, so a depth-first enumeration composes candidates by
//!   pushing/popping rows in `O(nnz)` per step.  Pops restore the saved
//!   words bit-for-bit (no floating-point subtraction), so deep searches
//!   accumulate zero drift.
//! * [`DeltaEval`] — single-placement incremental state for the hetero
//!   scheduler's refinement and the controller's breach path: probing a
//!   one-instance move/add/remove is `O(M)`, applying one recomputes only
//!   the affected machine columns.
//!
//! Eq. 5 linearity is the whole trick (see [`Evaluator::max_stable_rate`]):
//! `util_m(R0) = a_m·R0 + b_m` with
//! `a_m = Σ_c x[c][m]·e[c][m]·gain_c/n_c` and `b_m = Σ_c x[c][m]·met[c][m]`,
//! and a component row with `k` total instances contributes
//! `a_m += x·e·gain/k`, `b_m += x·met` — independent of every other
//! component, which is what makes row tables composable.

use super::{Evaluation, Evaluator, Placement};
use crate::{Error, Result};

/// Flat, row-major placement arena: `x[c * n_machines + m]` = instances
/// of component `c` on machine `m`.  The hot-loop twin of [`Placement`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlacementBuf {
    n_comp: usize,
    n_machines: usize,
    x: Vec<u32>,
}

impl PlacementBuf {
    /// All-zero buffer.
    pub fn empty(n_comp: usize, n_machines: usize) -> Self {
        PlacementBuf { n_comp, n_machines, x: vec![0; n_comp * n_machines] }
    }

    /// Copy a nested-`Vec` placement into flat form.
    pub fn from_placement(p: &Placement) -> Self {
        let n_comp = p.n_components();
        let n_machines = p.n_machines();
        let mut x = Vec::with_capacity(n_comp * n_machines);
        for row in &p.x {
            x.extend(row.iter().map(|&k| k as u32));
        }
        PlacementBuf { n_comp, n_machines, x }
    }

    /// Materialize back into the public API type.
    pub fn to_placement(&self) -> Placement {
        Placement {
            x: (0..self.n_comp)
                .map(|c| self.row(c).iter().map(|&k| k as usize).collect())
                .collect(),
        }
    }

    pub fn n_components(&self) -> usize {
        self.n_comp
    }

    pub fn n_machines(&self) -> usize {
        self.n_machines
    }

    #[inline]
    pub fn get(&self, c: usize, m: usize) -> u32 {
        self.x[c * self.n_machines + m]
    }

    #[inline]
    pub fn set(&mut self, c: usize, m: usize, k: u32) {
        self.x[c * self.n_machines + m] = k;
    }

    /// Component `c`'s machine row as a contiguous slice.
    #[inline]
    pub fn row(&self, c: usize) -> &[u32] {
        &self.x[c * self.n_machines..(c + 1) * self.n_machines]
    }

    /// Total instances of component `c`.
    pub fn count(&self, c: usize) -> u32 {
        self.row(c).iter().sum()
    }

    /// Tasks hosted on machine `m`.
    pub fn tasks_on(&self, m: usize) -> u32 {
        (0..self.n_comp).map(|c| self.get(c, m)).sum()
    }
}

/// One machine's contribution from one component row.
#[derive(Debug, Clone, Copy)]
pub struct RowTerm {
    /// Machine index.
    pub m: u32,
    /// Instances of the component on that machine.
    pub count: u32,
    /// Slope contribution `count · e[c][m] · gain_c / k`.
    pub a: f64,
    /// Intercept contribution `count · met[c][m]`.
    pub b: f64,
}

/// One enumerated distribution of `k` instances of a component, as its
/// sparse per-machine `(a, b)` contributions.
#[derive(Debug, Clone)]
pub struct Row {
    /// Total instances in this row.
    pub k: u32,
    /// Per-machine terms (machines with zero instances are absent).
    pub terms: Vec<RowTerm>,
}

impl Row {
    /// A rate-independent load row: pure per-machine intercepts
    /// (`a = 0`, `b = load[m]`), no tasks.  This is how resident
    /// tenants enter a candidate search in incremental admission — their
    /// utilization at their certified rates does not scale with the
    /// candidate's rate, so it offsets the intercepts and the closed
    /// form becomes `R0* = min_m (cap_m − load_m − b_m)/a_m`, exactly
    /// the residual-capacity view
    /// [`Problem::constrained_evaluator`](crate::scheduler::Problem::constrained_evaluator)
    /// expresses by shrinking `cap`.
    pub fn fixed_load(load: &[f64]) -> Row {
        let terms = load
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(m, &b)| RowTerm { m: m as u32, count: 0, a: 0.0, b })
            .collect();
        Row { k: 0, terms }
    }

    /// Build the term list for component `c` from a full-width count row.
    pub fn build(ev: &Evaluator, c: usize, counts: &[usize]) -> Row {
        let k: usize = counts.iter().sum();
        let kf = k.max(1) as f64;
        let terms = counts
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(m, &n)| RowTerm {
                m: m as u32,
                count: n as u32,
                a: n as f64 * ev.e_m[c][m] * ev.gains[c] / kf,
                b: n as f64 * ev.met_m[c][m],
            })
            .collect();
        Row { k: k as u32, terms }
    }
}

/// Precomputed rows for one component: every distribution the search may
/// pick for it, with slope/intercept terms ready to push.
#[derive(Debug, Clone)]
pub struct RowTable {
    pub rows: Vec<Row>,
}

impl RowTable {
    /// Build from the enumerated full-width count rows of one component.
    /// Table construction is the search's fixed setup cost; its wall
    /// time lands in the `kernel.row_build_s` histogram when telemetry
    /// is enabled.
    pub fn build(ev: &Evaluator, c: usize, rows: &[Vec<usize>]) -> RowTable {
        let started = std::time::Instant::now();
        let table = RowTable { rows: rows.iter().map(|r| Row::build(ev, c, r)).collect() };
        if crate::obs::enabled() {
            crate::obs::global()
                .histogram("kernel.row_build_s")
                .observe(started.elapsed().as_secs_f64());
        }
        table
    }
}

/// Undo-log entry: one machine's state before a push touched it.
#[derive(Debug, Clone, Copy)]
struct Saved {
    m: u32,
    a: f64,
    b: f64,
    tasks: u32,
}

/// One push's undo frame.
#[derive(Debug, Clone, Copy)]
struct Frame {
    saved_start: usize,
    used: usize,
}

/// Per-machine slope/intercept/task accumulators with exact push/pop.
///
/// `pop` restores the exact words saved by the matching `push` (no
/// arithmetic), so an enumeration of any depth is drift-free: the state
/// after `push(r); pop()` is bit-identical to the state before.
#[derive(Debug, Clone)]
pub struct AccumState {
    a: Vec<f64>,
    b: Vec<f64>,
    tasks: Vec<u32>,
    /// Machines currently hosting at least one task.
    used: usize,
    saved: Vec<Saved>,
    frames: Vec<Frame>,
    /// Pre-push digests, popped and re-checked by `pop` (debug builds).
    #[cfg(debug_assertions)]
    fp_stack: Vec<u64>,
}

impl AccumState {
    pub fn new(n_machines: usize) -> Self {
        AccumState {
            a: vec![0.0; n_machines],
            b: vec![0.0; n_machines],
            tasks: vec![0; n_machines],
            used: 0,
            saved: Vec::with_capacity(64),
            frames: Vec::with_capacity(16),
            #[cfg(debug_assertions)]
            fp_stack: Vec::with_capacity(16),
        }
    }

    /// FNV-1a digest over every accumulator word — `a`/`b` bit patterns,
    /// task counts and `used` — so debug builds can prove `pop` restored
    /// the exact pre-push state, not merely an arithmetically close one.
    #[cfg(debug_assertions)]
    fn fingerprint(&self) -> u64 {
        fn mix(h: u64, w: u64) -> u64 {
            (h ^ w).wrapping_mul(0x0000_0100_0000_01b3)
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for m in 0..self.a.len() {
            h = mix(h, self.a[m].to_bits());
            h = mix(h, self.b[m].to_bits());
            h = mix(h, u64::from(self.tasks[m]));
        }
        mix(h, self.used as u64)
    }

    /// Machines hosting at least one task under the pushed rows.
    pub fn machines_used(&self) -> usize {
        self.used
    }

    pub fn depth(&self) -> usize {
        self.frames.len()
    }

    /// Add one component row: `O(nnz)` — only the row's machines move.
    pub fn push(&mut self, row: &Row) {
        #[cfg(debug_assertions)]
        self.fp_stack.push(self.fingerprint());
        self.frames.push(Frame { saved_start: self.saved.len(), used: self.used });
        for t in &row.terms {
            let m = t.m as usize;
            self.saved.push(Saved { m: t.m, a: self.a[m], b: self.b[m], tasks: self.tasks[m] });
            self.a[m] += t.a;
            self.b[m] += t.b;
            // zero-count terms (fixed resident load) reserve budget
            // without occupying the machine
            if t.count > 0 && self.tasks[m] == 0 {
                self.used += 1;
            }
            self.tasks[m] += t.count;
        }
    }

    /// Undo the most recent [`push`](Self::push), restoring saved words
    /// bit-for-bit.
    pub fn pop(&mut self) {
        let f = self.frames.pop().expect("pop without matching push");
        for s in self.saved.drain(f.saved_start..).rev() {
            let m = s.m as usize;
            self.a[m] = s.a;
            self.b[m] = s.b;
            self.tasks[m] = s.tasks;
        }
        self.used = f.used;
        #[cfg(debug_assertions)]
        {
            let want = self.fp_stack.pop();
            debug_assert_eq!(
                Some(self.fingerprint()),
                want,
                "pop did not restore the accumulator state bit-for-bit"
            );
        }
    }

    /// Closed-form max stable rate of the composed candidate:
    /// `min_m (cap_m - b_m)/a_m`, `0` when MET alone breaks a budget or
    /// no machine has a positive slope (nothing real can be certified).
    pub fn rate(&self, cap: &[f64]) -> f64 {
        let mut best = f64::INFINITY;
        for m in 0..self.a.len() {
            if self.b[m] > cap[m] + 1e-9 {
                return 0.0;
            }
            if self.a[m] > 0.0 {
                best = best.min((cap[m] - self.b[m]) / self.a[m]);
            }
        }
        if best.is_finite() {
            best
        } else {
            0.0
        }
    }

    /// Admissible optimistic bound on [`rate`](Self::rate) over **every
    /// completion** of the currently-pushed partial candidate.
    ///
    /// Identical arithmetic to `rate`, with one deliberate difference:
    /// a partial state where no machine has a positive slope yet is
    /// *unbounded-feasible* (any rate could still be certified by rows
    /// not pushed yet), so the bound is `+∞` there — where `rate`
    /// certifies `0` because a complete candidate without positive
    /// slope sustains nothing.  An intercept already over budget still
    /// bounds to `0`: pushes only add nonnegative `b`, so no completion
    /// can become feasible again.
    ///
    /// Admissibility (bound ≥ true best over all completions) follows
    /// from monotonicity: every push adds `a ≥ 0` and `b ≥ 0` per
    /// machine, so `(cap_m − b_m)/a_m` can only shrink as rows land —
    /// branch-and-bound may prune any subtree whose bound cannot beat
    /// the incumbent without losing the optimum.
    pub fn bound(&self, cap: &[f64]) -> f64 {
        let mut best = f64::INFINITY;
        for m in 0..self.a.len() {
            if self.b[m] > cap[m] + 1e-9 {
                return 0.0;
            }
            if self.a[m] > 0.0 {
                best = best.min((cap[m] - self.b[m]) / self.a[m]);
            }
        }
        best
    }

    /// Utilization spread (max − min over non-excluded machines) at rate
    /// `r`, from the linear form `util_m = a_m·r + b_m`.
    pub fn spread(&self, excluded: &[bool], r: f64) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for m in 0..self.a.len() {
            if excluded[m] {
                continue;
            }
            let u = self.a[m] * r + self.b[m];
            lo = lo.min(u);
            hi = hi.max(u);
        }
        if hi >= lo {
            hi - lo
        } else {
            0.0
        }
    }
}

/// Synthesize the per-component rows of an arbitrary placement (one row
/// per component, same term arithmetic as [`RowTable::build`]), so seeded
/// candidates score bit-identically to enumerated ones that happen to
/// contain the same distribution.
pub fn rows_of_placement(ev: &Evaluator, p: &Placement) -> Vec<Row> {
    (0..p.n_components()).map(|c| Row::build(ev, c, &p.x[c])).collect()
}

/// [`Evaluator::evaluate`] with the per-call `counts` allocation hoisted
/// into a caller-provided scratch buffer — the batch-scoring entry point
/// ([`crate::runtime::scorer::NativeScorer`] loops this over candidates
/// with one scratch for the whole batch).  Arithmetic is identical to the
/// naive path, operation for operation.
pub fn evaluate_with_scratch(
    ev: &Evaluator,
    p: &Placement,
    r0: f64,
    counts: &mut Vec<usize>,
) -> Result<Evaluation> {
    if p.n_components() != ev.n_components() || p.n_machines() != ev.n_machines() {
        return Err(Error::Schedule(format!(
            "placement shape {}x{} != problem {}x{}",
            p.n_components(),
            p.n_machines(),
            ev.n_components(),
            ev.n_machines()
        )));
    }
    counts.clear();
    counts.extend((0..p.n_components()).map(|c| p.count(c)));
    let ir_comp = ev.rates(r0);
    let mut util = vec![0.0f64; ev.n_machines()];
    for c in 0..ev.n_components() {
        let n_c = counts[c].max(1) as f64;
        let ir_task = ir_comp[c] / n_c;
        for m in 0..ev.n_machines() {
            let k = p.x[c][m] as f64;
            if k > 0.0 {
                util[m] += k * (ev.e_m[c][m] * ir_task + ev.met_m[c][m]);
            }
        }
    }
    let over = util.iter().zip(&ev.cap).any(|(u, c)| *u > *c + 1e-6);
    let missing = counts.iter().any(|&n| n == 0);
    let throughput = ir_comp.iter().sum();
    Ok(Evaluation { util, throughput, feasible: !over && !missing, ir_comp })
}

/// Incremental single-placement evaluation state: per-machine `(a, b)`
/// kept in sync with a [`PlacementBuf`], so probing a one-instance
/// move/add/remove is `O(M)` (one pass over the adjusted closed form) and
/// applying one recomputes only the affected machine columns — no
/// placement clones, no `counts()` allocations.
///
/// Used by the hetero scheduler's refinement sweeps and by the control
/// plane's per-step capacity (breach) check.
#[derive(Debug, Clone)]
pub struct DeltaEval<'e> {
    ev: &'e Evaluator,
    x: PlacementBuf,
    counts: Vec<u32>,
    a: Vec<f64>,
    b: Vec<f64>,
}

impl<'e> DeltaEval<'e> {
    /// Build the incremental state for `p` (shape-checked).
    pub fn new(ev: &'e Evaluator, p: &Placement) -> Result<Self> {
        if p.n_components() != ev.n_components() || p.n_machines() != ev.n_machines() {
            return Err(Error::Schedule(format!(
                "placement shape {}x{} != problem {}x{}",
                p.n_components(),
                p.n_machines(),
                ev.n_components(),
                ev.n_machines()
            )));
        }
        let x = PlacementBuf::from_placement(p);
        let counts: Vec<u32> = (0..x.n_components()).map(|c| x.count(c)).collect();
        let mut de = DeltaEval {
            ev,
            a: vec![0.0; x.n_machines()],
            b: vec![0.0; x.n_machines()],
            x,
            counts,
        };
        for m in 0..de.x.n_machines() {
            de.recompute_machine(m);
        }
        Ok(de)
    }

    #[inline]
    pub fn get(&self, c: usize, m: usize) -> u32 {
        self.x.get(c, m)
    }

    #[inline]
    pub fn count(&self, c: usize) -> u32 {
        self.counts[c]
    }

    pub fn tasks_on(&self, m: usize) -> u32 {
        self.x.tasks_on(m)
    }

    /// The tracked placement, materialized.
    pub fn placement(&self) -> Placement {
        self.x.to_placement()
    }

    /// Rebuild machine `m`'s `(a, b)` column from the placement — exact
    /// recomputation, so applied deltas never accumulate drift.
    fn recompute_machine(&mut self, m: usize) {
        let mut a = 0.0f64;
        let mut b = 0.0f64;
        for c in 0..self.x.n_components() {
            let k = self.x.get(c, m) as f64;
            if k > 0.0 {
                a += k * self.ev.e_m[c][m] * self.ev.gains[c] / self.counts[c].max(1) as f64;
                b += k * self.ev.met_m[c][m];
            }
        }
        self.a[m] = a;
        self.b[m] = b;
    }

    /// Closed-form max stable rate of the current placement.  `∞` when no
    /// machine has positive slope (symbolically unbounded), `0` when MET
    /// alone breaks a budget.
    pub fn rate(&self) -> f64 {
        self.rate_adjusted(|_| (0.0, 0.0))
    }

    /// [`rate`](Self::rate) clamped to an operating point (`∞` → `0`) and
    /// `0` when a component has no instance — the control plane's
    /// capacity semantics ([`Evaluator::max_stable_rate_or_zero`]).
    pub fn rate_or_zero(&self) -> f64 {
        if self.counts.iter().any(|&n| n == 0) {
            return 0.0;
        }
        let r = self.rate();
        if r.is_finite() {
            r
        } else {
            0.0
        }
    }

    /// Closed form with a per-machine `(Δa, Δb)` adjustment applied on
    /// the fly — the shared probe kernel.
    fn rate_adjusted(&self, adj: impl Fn(usize) -> (f64, f64)) -> f64 {
        let mut best = f64::INFINITY;
        for m in 0..self.a.len() {
            let (da, db) = adj(m);
            let bm = self.b[m] + db;
            if bm > self.ev.cap[m] + 1e-9 {
                return 0.0;
            }
            let am = self.a[m] + da;
            if am > 0.0 {
                best = best.min((self.ev.cap[m] - bm) / am);
            }
        }
        best
    }

    /// Rate if one instance of `c` moved `from → to` (share unchanged:
    /// only the two endpoints' columns adjust).
    pub fn rate_with_move(&self, c: usize, from: usize, to: usize) -> f64 {
        let share = self.ev.gains[c] / self.counts[c].max(1) as f64;
        self.rate_adjusted(|m| {
            if m == from {
                (-self.ev.e_m[c][m] * share, -self.ev.met_m[c][m])
            } else if m == to {
                (self.ev.e_m[c][m] * share, self.ev.met_m[c][m])
            } else {
                (0.0, 0.0)
            }
        })
    }

    /// Apply the move probed by [`rate_with_move`](Self::rate_with_move).
    ///
    /// Debug builds re-probe before mutating and assert the post-apply
    /// recomputed rate matches the probe — the probe/apply pair must
    /// never drift, or refinement would chase phantom improvements.
    pub fn apply_move(&mut self, c: usize, from: usize, to: usize) {
        debug_assert!(self.x.get(c, from) > 0);
        #[cfg(debug_assertions)]
        let probe = if from != to { self.rate_with_move(c, from, to) } else { self.rate() };
        self.x.set(c, from, self.x.get(c, from) - 1);
        self.x.set(c, to, self.x.get(c, to) + 1);
        self.recompute_machine(from);
        self.recompute_machine(to);
        #[cfg(debug_assertions)]
        debug_assert!(
            probe_matches(probe, self.rate()),
            "apply_move({c}, {from}->{to}): probed rate {probe} vs recomputed {}",
            self.rate()
        );
    }

    /// Rate if one instance of `c` were removed from machine `drop_m`
    /// (the stream re-shares over `n-1` instances: every machine hosting
    /// `c` adjusts its slope).
    pub fn rate_removing(&self, c: usize, drop_m: usize) -> f64 {
        let n = self.counts[c];
        debug_assert!(n > 1, "removing the last instance of a component");
        let share_old = self.ev.gains[c] / n as f64;
        let share_new = self.ev.gains[c] / (n - 1) as f64;
        self.rate_adjusted(|m| {
            let k_old = self.x.get(c, m) as f64;
            if k_old == 0.0 {
                return (0.0, 0.0);
            }
            let k_new = k_old - if m == drop_m { 1.0 } else { 0.0 };
            (
                self.ev.e_m[c][m] * (k_new * share_new - k_old * share_old),
                -if m == drop_m { self.ev.met_m[c][m] } else { 0.0 },
            )
        })
    }

    /// Apply the removal probed by [`rate_removing`](Self::rate_removing).
    pub fn apply_remove(&mut self, c: usize, drop_m: usize) {
        debug_assert!(self.x.get(c, drop_m) > 0);
        #[cfg(debug_assertions)]
        let probe = self.rate_removing(c, drop_m);
        self.x.set(c, drop_m, self.x.get(c, drop_m) - 1);
        self.counts[c] -= 1;
        for m in 0..self.x.n_machines() {
            if self.x.get(c, m) > 0 || m == drop_m {
                self.recompute_machine(m);
            }
        }
        #[cfg(debug_assertions)]
        debug_assert!(
            probe_matches(probe, self.rate()),
            "apply_remove({c}, {drop_m}): probed rate {probe} vs recomputed {}",
            self.rate()
        );
    }

    /// Rate if one instance of `c` were added on machine `add_m` (the
    /// stream re-shares over `n+1` instances).
    pub fn rate_adding(&self, c: usize, add_m: usize) -> f64 {
        let n = self.counts[c];
        let share_old = self.ev.gains[c] / n.max(1) as f64;
        let share_new = self.ev.gains[c] / (n + 1) as f64;
        self.rate_adjusted(|m| {
            let k_old = self.x.get(c, m) as f64;
            let k_new = k_old + if m == add_m { 1.0 } else { 0.0 };
            if k_new == 0.0 {
                return (0.0, 0.0);
            }
            // components with n = 0 contribute no slope yet: k_old·share_old
            // is 0 either way
            (
                self.ev.e_m[c][m] * (k_new * share_new - k_old * share_old),
                if m == add_m { self.ev.met_m[c][m] } else { 0.0 },
            )
        })
    }

    /// Apply the addition probed by [`rate_adding`](Self::rate_adding).
    pub fn apply_add(&mut self, c: usize, add_m: usize) {
        #[cfg(debug_assertions)]
        let probe = self.rate_adding(c, add_m);
        self.x.set(c, add_m, self.x.get(c, add_m) + 1);
        self.counts[c] += 1;
        for m in 0..self.x.n_machines() {
            if self.x.get(c, m) > 0 {
                self.recompute_machine(m);
            }
        }
        #[cfg(debug_assertions)]
        debug_assert!(
            probe_matches(probe, self.rate()),
            "apply_add({c}, {add_m}): probed rate {probe} vs recomputed {}",
            self.rate()
        );
    }
}

/// Probe/apply agreement predicate for the debug asserts above: both
/// symbolically unbounded, or within `1e-9` relative of each other.
#[cfg(debug_assertions)]
fn probe_matches(probe: f64, post: f64) -> bool {
    if probe.is_infinite() && post.is_infinite() {
        return true;
    }
    (probe - post).abs() <= 1e-9 * post.abs().max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;
    use crate::util::rng::Rng;

    fn setup() -> Evaluator {
        let (c, db) = presets::paper_cluster();
        Evaluator::new(&benchmarks::linear(), &c, &db).unwrap()
    }

    fn random_placement(rng: &mut Rng, n_comp: usize, n_m: usize) -> Placement {
        let mut p = Placement::empty(n_comp, n_m);
        for c in 0..n_comp {
            for _ in 0..rng.range(1, 3) {
                p.x[c][rng.range(0, n_m - 1)] += 1;
            }
        }
        p
    }

    #[test]
    fn buf_roundtrips() {
        let mut rng = Rng::new(7);
        for _ in 0..32 {
            let p = random_placement(&mut rng, 4, 3);
            let buf = PlacementBuf::from_placement(&p);
            assert_eq!(buf.to_placement(), p);
            for c in 0..4 {
                assert_eq!(buf.count(c) as usize, p.count(c));
            }
            for m in 0..3 {
                assert_eq!(buf.tasks_on(m) as usize, p.tasks_on(m));
            }
        }
    }

    #[test]
    fn pushed_rows_match_closed_form() {
        let ev = setup();
        let mut rng = Rng::new(11);
        for _ in 0..64 {
            let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            let rows = rows_of_placement(&ev, &p);
            let mut acc = AccumState::new(ev.n_machines());
            // push in the search's order (outermost component last index)
            for row in rows.iter().rev() {
                acc.push(row);
            }
            let want = ev.max_stable_rate_or_zero(&p).unwrap();
            assert!(
                (acc.rate(&ev.cap) - want).abs() < 1e-9,
                "{} vs {want}",
                acc.rate(&ev.cap)
            );
            assert_eq!(
                acc.machines_used(),
                (0..ev.n_machines()).filter(|&m| p.tasks_on(m) > 0).count()
            );
        }
    }

    #[test]
    fn bound_is_admissible_and_monotone_along_pushes() {
        let ev = setup();
        let mut rng = Rng::new(31);
        for _ in 0..64 {
            let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            let rows = rows_of_placement(&ev, &p);
            let full = ev.max_stable_rate_or_zero(&p).unwrap();
            let mut acc = AccumState::new(ev.n_machines());
            // the empty prefix bounds everything (no slope yet → +∞)
            let mut prev = acc.bound(&ev.cap);
            assert!(prev >= full);
            for row in rows.iter().rev() {
                acc.push(row);
                let b = acc.bound(&ev.cap);
                // admissible at every prefix: never below the true
                // rate of this completion ...
                assert!(b + 1e-9 >= full, "bound {b} underestimates completion rate {full}");
                // ... and monotone nonincreasing as rows land
                assert!(b <= prev + 1e-9, "bound rose from {prev} to {b}");
                prev = b;
            }
            // complete candidate: bound degenerates to the exact rate
            // (when a positive slope exists; rate() maps ∞ to 0)
            let (b, r) = (acc.bound(&ev.cap), acc.rate(&ev.cap));
            assert!(b == f64::INFINITY || (b - r).abs() < 1e-9, "{b} vs {r}");
        }
    }

    #[test]
    fn pop_restores_state_bit_for_bit() {
        let ev = setup();
        let mut rng = Rng::new(23);
        let base = random_placement(&mut rng, ev.n_components(), ev.n_machines());
        let rows = rows_of_placement(&ev, &base);
        let mut acc = AccumState::new(ev.n_machines());
        acc.push(&rows[3]);
        acc.push(&rows[2]);
        let snapshot = acc.clone();
        // a deep excursion, then unwind
        for _ in 0..50 {
            let extra = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            for row in rows_of_placement(&ev, &extra) {
                acc.push(&row);
            }
            for _ in 0..ev.n_components() {
                acc.pop();
            }
        }
        assert_eq!(acc.a, snapshot.a, "slope accumulators drifted");
        assert_eq!(acc.b, snapshot.b, "intercept accumulators drifted");
        assert_eq!(acc.tasks, snapshot.tasks);
        assert_eq!(acc.machines_used(), snapshot.machines_used());
    }

    #[test]
    fn fixed_load_offsets_match_cap_reduction() {
        // Pushing a resident-load row offsets the intercepts; reducing
        // the capacities instead must certify the same rate — the two
        // spellings of the residual-capacity view.
        let ev = setup();
        let mut rng = Rng::new(101);
        for _ in 0..32 {
            let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            let load: Vec<f64> =
                (0..ev.n_machines()).map(|_| rng.range_f64(0.0, 60.0)).collect();
            // (a) intercept offsets through the accumulator
            let mut acc = AccumState::new(ev.n_machines());
            acc.push(&Row::fixed_load(&load));
            assert_eq!(acc.machines_used(), 0, "fixed load must not occupy machines");
            for row in rows_of_placement(&ev, &p).iter().rev() {
                acc.push(row);
            }
            let offset_rate = acc.rate(&ev.cap);
            // (b) the same residual as reduced capacities
            let mut reduced = ev.clone();
            for (m, cap) in reduced.cap.iter_mut().enumerate() {
                *cap = (*cap - load[m]).max(0.0);
            }
            let want = reduced.max_stable_rate_or_zero(&p).unwrap();
            assert!(
                (offset_rate - want).abs() < 1e-9,
                "offset {offset_rate} vs reduced-cap {want}"
            );
        }
    }

    #[test]
    fn evaluate_with_scratch_matches_naive() {
        let ev = setup();
        let mut rng = Rng::new(31);
        let mut counts = Vec::new();
        for _ in 0..32 {
            let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            let r0 = rng.range_f64(1.0, 300.0);
            let a = ev.evaluate(&p, r0).unwrap();
            let b = evaluate_with_scratch(&ev, &p, r0, &mut counts).unwrap();
            assert_eq!(a.feasible, b.feasible);
            assert_eq!(a.util, b.util, "scratch path must be arithmetic-identical");
            assert_eq!(a.ir_comp, b.ir_comp);
        }
        // shape mismatch still rejected
        let bad = Placement::empty(2, 3);
        assert!(evaluate_with_scratch(&ev, &bad, 1.0, &mut counts).is_err());
    }

    #[test]
    fn delta_probes_match_full_recompute() {
        let ev = setup();
        let mut rng = Rng::new(47);
        for _ in 0..24 {
            let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
            let de = DeltaEval::new(&ev, &p).unwrap();
            assert!((de.rate_or_zero() - ev.max_stable_rate_or_zero(&p).unwrap()).abs() < 1e-9);

            // probe a move and cross-check against a cloned placement
            let c = rng.range(0, ev.n_components() - 1);
            let from = (0..ev.n_machines()).find(|&m| p.x[c][m] > 0).unwrap();
            let to = (from + 1) % ev.n_machines();
            let mut q = p.clone();
            q.x[c][from] -= 1;
            q.x[c][to] += 1;
            let want = ev.max_stable_rate_or_zero(&q).unwrap();
            let probe = de.rate_with_move(c, from, to);
            let probe = if probe.is_finite() { probe } else { 0.0 };
            assert!((probe - want).abs() < 1e-9, "move probe {probe} vs {want}");
        }
    }

    #[test]
    fn delta_apply_chain_stays_exact() {
        let ev = setup();
        let mut rng = Rng::new(59);
        let p = random_placement(&mut rng, ev.n_components(), ev.n_machines());
        let mut de = DeltaEval::new(&ev, &p).unwrap();
        for step in 0..64 {
            let c = rng.range(0, ev.n_components() - 1);
            match rng.range(0, 2) {
                0 => {
                    let from = (0..ev.n_machines()).find(|&m| de.get(c, m) > 0).unwrap();
                    let to = rng.range(0, ev.n_machines() - 1);
                    if to != from {
                        de.apply_move(c, from, to);
                    }
                }
                1 => de.apply_add(c, rng.range(0, ev.n_machines() - 1)),
                _ => {
                    if de.count(c) > 1 {
                        let m = (0..ev.n_machines()).find(|&m| de.get(c, m) > 0).unwrap();
                        de.apply_remove(c, m);
                    }
                }
            }
            let q = de.placement();
            let want = ev.max_stable_rate_or_zero(&q).unwrap();
            assert!(
                (de.rate_or_zero() - want).abs() < 1e-9,
                "drift after {step} applies: {} vs {want}",
                de.rate_or_zero()
            );
        }
    }

    #[test]
    fn delta_add_and_remove_probe_resharing() {
        let ev = setup();
        let mut p = Placement::empty(4, 3);
        for c in 0..4 {
            p.x[c][c % 3] = 1;
        }
        p.x[3][0] = 1; // highCompute on 2 machines
        let de = DeltaEval::new(&ev, &p).unwrap();
        let mut q = p.clone();
        q.x[3][1] += 1;
        let want_add = ev.max_stable_rate_or_zero(&q).unwrap();
        assert!((de.rate_adding(3, 1) - want_add).abs() < 1e-9);
        let mut q = p.clone();
        q.x[3][0] -= 1;
        let want_rm = ev.max_stable_rate_or_zero(&q).unwrap();
        assert!((de.rate_removing(3, 0) - want_rm).abs() < 1e-9);
    }
}
