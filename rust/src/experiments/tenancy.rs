//! Multi-tenancy experiment: joint vs incremental-admission vs isolated
//! scheduling of 2–4-tenant mixes of the five benchmark topologies on
//! the paper cluster and the Table-4 scenario clusters.
//!
//! For each mix the three modes of
//! [`WorkloadProblem`](crate::scheduler::WorkloadProblem) run under the
//! hetero policy and report the workload **scale** (the largest `R`
//! with every tenant certified at `w_t · R`), the weighted throughput
//! at proportional rates (`scale · Σ w_t · gain_t`), the total
//! predicted throughput at the certified (possibly uneven) rates, and
//! machines used.  The headline the CI pipeline greps: joint
//! scheduling — statistical multiplexing over all shared machines —
//! must dominate the isolated machine-partition baseline on weighted
//! throughput for every mix.

use std::sync::Arc;

use crate::cluster::profile::ProfileDb;
use crate::cluster::{presets, scenarios, Cluster};
use crate::scheduler::{
    registry, PolicyParams, ScheduleRequest, TenancyMode, Workload, WorkloadProblem,
    WorkloadSchedule,
};
use crate::topology::benchmarks;
use crate::util::json::{self, Value};
use crate::Result;

use super::{f1, ExperimentResult};

/// One tenant mix: cluster label, (topology, weight) pairs.
struct Mix {
    cluster: &'static str,
    tenants: &'static [(&'static str, f64)],
}

const MIXES: &[Mix] = &[
    Mix { cluster: "paper", tenants: &[("linear", 1.0), ("rolling-count", 1.0)] },
    Mix { cluster: "paper", tenants: &[("star", 1.0), ("unique-visitor", 2.0)] },
    Mix {
        cluster: "paper",
        tenants: &[("linear", 1.0), ("rolling-count", 1.0), ("unique-visitor", 1.0)],
    },
    Mix {
        cluster: "scenario1",
        tenants: &[("linear", 1.0), ("star", 1.0), ("unique-visitor", 2.0)],
    },
    Mix {
        cluster: "scenario1",
        tenants: &[
            ("linear", 1.0),
            ("star", 1.0),
            ("rolling-count", 1.0),
            ("unique-visitor", 1.0),
        ],
    },
];

/// The medium scenario joins in full mode only.
const FULL_MIXES: &[Mix] = &[Mix {
    cluster: "scenario2",
    tenants: &[
        ("linear", 1.0),
        ("star", 1.0),
        ("rolling-count", 1.0),
        ("unique-visitor", 1.0),
    ],
}];

fn cluster_by_label(label: &str) -> (Cluster, ProfileDb) {
    match label {
        "paper" => presets::paper_cluster(),
        "scenario1" => scenarios::by_id(1).expect("scenario 1 exists").build(),
        "scenario2" => scenarios::by_id(2).expect("scenario 2 exists").build(),
        other => unreachable!("unknown cluster label {other}"),
    }
}

fn mix_label(mix: &Mix) -> String {
    mix.tenants
        .iter()
        .map(|(t, w)| if *w == 1.0 { t.to_string() } else { format!("{t}x{w}") })
        .collect::<Vec<_>>()
        .join("+")
}

fn build_problem(mix: &Mix) -> Result<WorkloadProblem> {
    let (cluster, db) = cluster_by_label(mix.cluster);
    let db = Arc::new(db);
    let mut w = Workload::new(mix_label(mix));
    for (i, (top, weight)) in mix.tenants.iter().enumerate() {
        let topology = benchmarks::by_name(top).expect("benchmark topology exists");
        w = w.tenant(format!("t{i}-{top}"), topology, db.clone(), *weight);
    }
    WorkloadProblem::new(w, cluster)
}

fn mode_json(ws: &WorkloadSchedule) -> Value {
    json::obj(vec![
        ("scale", json::num(ws.scale)),
        ("weighted_throughput", json::num(ws.weighted_throughput)),
        ("total_throughput", json::num(ws.total_throughput())),
        ("machines_used", json::num(ws.machines_used() as f64)),
        ("denied", json::num(ws.denied.len() as f64)),
        ("feasible", Value::Bool(ws.feasible)),
    ])
}

pub fn run(fast: bool) -> Result<ExperimentResult> {
    run_with_json(fast).map(|(r, _)| r)
}

/// Run the experiment and also return the machine-readable JSON the CLI
/// writes to `BENCH_tenancy.json` (uploaded by the CI experiments job).
pub fn run_with_json(fast: bool) -> Result<(ExperimentResult, Value)> {
    let mut out = ExperimentResult::new(
        "tenancy",
        "multi-tenant scheduling: joint vs incremental admission vs isolated partitions \
         (hetero policy)",
        &[
            "cluster", "tenants", "mode", "scale", "weighted thpt", "total thpt", "machines",
            "denied",
        ],
    );
    let sched = registry::create("hetero", &PolicyParams::default())?;
    let req = ScheduleRequest::max_throughput();

    let mixes: Vec<&Mix> = if fast {
        MIXES.iter().collect()
    } else {
        MIXES.iter().chain(FULL_MIXES.iter()).collect()
    };

    let mut joint_ge_isolated = true;
    let mut joint_ge_incremental = true;
    let mut mix_rows = Vec::new();
    for mix in &mixes {
        let wp = build_problem(mix)?;
        let joint = wp.schedule_joint(sched.as_ref(), &req)?;
        let incremental = wp.schedule_incremental(sched.as_ref(), &req)?;
        let isolated = wp.schedule_isolated(sched.as_ref(), &req)?;
        joint_ge_isolated &=
            joint.weighted_throughput >= isolated.weighted_throughput * (1.0 - 1e-9);
        joint_ge_incremental &=
            joint.weighted_throughput >= incremental.weighted_throughput * (1.0 - 1e-9);
        for ws in [&joint, &incremental, &isolated] {
            out.row(vec![
                mix.cluster.to_string(),
                mix_label(mix),
                ws.mode.name().to_string(),
                f1(ws.scale),
                f1(ws.weighted_throughput),
                f1(ws.total_throughput()),
                ws.machines_used().to_string(),
                ws.denied.len().to_string(),
            ]);
        }
        mix_rows.push(json::obj(vec![
            ("cluster", json::s(mix.cluster)),
            ("tenants", json::s(&mix_label(mix))),
            (TenancyMode::Joint.name(), mode_json(&joint)),
            (TenancyMode::Incremental.name(), mode_json(&incremental)),
            (TenancyMode::Isolated.name(), mode_json(&isolated)),
        ]));
    }

    out.note(format!(
        "joint >= isolated weighted throughput : {}",
        if joint_ge_isolated { "PASS" } else { "FAIL" }
    ));
    out.note(format!(
        "joint >= incremental weighted throughput : {}",
        if joint_ge_incremental { "PASS" } else { "FAIL" }
    ));
    out.note(
        "scale: largest R with every tenant certified at weight*R; weighted thpt = \
         scale * sum(weight * gain); incremental admits in workload order against \
         residual capacity (denied = tenants it could not host)",
    );
    let v = json::obj(vec![
        ("id", json::s("tenancy")),
        ("fast", Value::Bool(fast)),
        ("policy", json::s("hetero")),
        ("joint_ge_isolated", Value::Bool(joint_ge_isolated)),
        ("joint_ge_incremental", Value::Bool(joint_ge_incremental)),
        ("mixes", json::arr(mix_rows)),
    ]);
    Ok((out, v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_cover_every_mix_and_mode() {
        let (r, v) = run_with_json(true).unwrap();
        assert_eq!(r.rows.len(), MIXES.len() * 3);
        for row in &r.rows {
            assert_eq!(row.len(), 8);
        }
        assert_eq!(
            v.get("mixes").unwrap().as_arr().unwrap().len(),
            MIXES.len()
        );
    }

    #[test]
    fn joint_dominates_isolated_partitions() {
        let (r, v) = run_with_json(true).unwrap();
        assert_eq!(v.get("joint_ge_isolated").unwrap().as_bool(), Some(true));
        assert!(
            r.notes.iter().any(|n| n == "joint >= isolated weighted throughput : PASS"),
            "{:?}",
            r.notes
        );
    }

    #[test]
    fn every_joint_mode_is_feasible() {
        let (_, v) = run_with_json(true).unwrap();
        for mix in v.get("mixes").unwrap().as_arr().unwrap() {
            let joint = mix.get("joint").unwrap();
            assert_eq!(joint.get("feasible").unwrap().as_bool(), Some(true));
            assert!(joint.get("scale").unwrap().as_f64().unwrap() > 0.0);
        }
    }
}
