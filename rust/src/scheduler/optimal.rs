//! The optimal scheduler (paper §3 & §6): an exhaustive search over the
//! task-assignment design space.
//!
//! For every candidate placement (instance counts per component ×
//! distribution over machines) the search computes the largest feasible
//! topology input rate and keeps the best candidate **under the
//! request's objective** — highest rate for `MaxThroughput`, fewest used
//! machines (then highest rate) among candidates sustaining the target
//! for `MinMachinesAtRate`, and smallest utilization spread among
//! rate-ties for `BalancedUtilization`.  Constraints shrink the space
//! itself: per-component rows only distribute instances over allowed
//! machines, and counts stop at the component's cap.
//!
//! The paper uses this brute-force comparator to bound how far the
//! heuristic is from optimal (within 4% worst case), and to motivate the
//! heuristic in the first place: the search that took the paper's Xeon
//! server ~18 h for 27,405 possibilities is exactly the loop below.  Two
//! engines make it tractable:
//!
//! * **Incremental kernel** (the default, [`crate::predict::kernel`]):
//!   every distribution a component may take is precomputed once as its
//!   per-machine `(a_m, b_m)` slope/intercept contribution
//!   ([`RowTable`]); the exhaustive DFS then composes candidates by
//!   pushing/popping rows into per-machine accumulators in `O(nnz)` and
//!   reads the closed form `R0* = min_m (cap_m - b_m)/a_m` straight off
//!   the running state — no per-candidate allocation, no `O(C·M)`
//!   re-derivation.  The outermost component-row loop is sharded across
//!   threads (`threads`, [`std::thread::scope`]); shard results merge in
//!   enumeration order under the request's objective, so the parallel
//!   search returns the *identical* schedule as the single-threaded one.
//! * **Batched scorer** (the PJRT path, and the naive comparator): one
//!   batched evaluation at `R0 = 1` yields each machine's utilization
//!   slope `a_m` (after subtracting the placement's rate-independent MET
//!   load `b_m`, computed natively) — one PJRT execution scores 256
//!   placements exactly.  [`OptimalScheduler::schedule_naive`] pins this
//!   engine on the native mirror so benches and the equivalence suite
//!   can race the two.

use std::time::Instant;

use super::problem::ResolvedConstraints;
use super::{
    finish, util_spread, Objective, Problem, Provenance, Schedule, ScheduleRequest, Scheduler,
};
use crate::predict::kernel::{self, AccumState, RowTable};
use crate::predict::{Evaluator, Placement};
use crate::runtime::scorer::{NativeScorer, PlacementScorer};
use crate::{Error, Result};

/// How to traverse the design space.
#[derive(Debug, Clone)]
pub enum SearchSpace {
    /// Enumerate every placement (errors above `enumeration_limit`).
    Exhaustive,
    /// Uniformly sample `candidates` placements (for spaces the paper
    /// calls "increased exponentially").
    Sampled { candidates: usize, seed: u64 },
}

/// Exhaustive/sampled optimal search.
#[derive(Debug, Clone)]
pub struct OptimalScheduler {
    /// Max instances per component (`k_j`-style bound on the space).
    pub max_instances_per_component: usize,
    pub space: SearchSpace,
    /// Hard cap on exhaustive enumeration size.
    pub enumeration_limit: u64,
    /// Also score the heuristic schedulers' solutions as candidates, so
    /// the reported optimum upper-bounds them even when they use more
    /// instances than `max_instances_per_component` (the paper's optimal
    /// is by construction >= its heuristic; this keeps that property
    /// while the enumeration stays bounded).
    pub seed_heuristics: bool,
    /// Worker threads for the exhaustive kernel search: `0` = one per
    /// available core, `1` = sequential.  Shards split the outermost
    /// component-row loop and merge deterministically, so the result is
    /// identical at every thread count.  Design spaces of <= 4096
    /// placements always run sequentially (spawns would dominate), and
    /// so does `BalancedUtilization` (its epsilon-banded tie predicate
    /// is not associative; the sequential fold is the spec).
    pub threads: usize,
}

impl Default for OptimalScheduler {
    fn default() -> Self {
        OptimalScheduler {
            max_instances_per_component: 3,
            space: SearchSpace::Exhaustive,
            enumeration_limit: 3_000_000,
            seed_heuristics: true,
            threads: 0,
        }
    }
}

/// Binomial coefficient (u128 to survive Table-4-scale sanity checks).
fn binom(n: u64, k: u64) -> u128 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut r: u128 = 1;
    for i in 0..k {
        r = r * (n - i) as u128 / (i + 1) as u128;
    }
    r
}

/// Number of ways to place `k` identical instances on `m` machines.
fn placements_of(k: u64, m: u64) -> u128 {
    binom(k + m - 1, m - 1)
}

/// The best candidate seen so far, under one objective.
pub(crate) struct Best {
    pub(crate) placement: Placement,
    pub(crate) rate: f64,
    /// Machines hosting tasks (MinMachinesAtRate key).
    pub(crate) used: usize,
    /// Utilization spread at `rate` (BalancedUtilization tie-breaker).
    pub(crate) spread: f64,
}

/// Shared read-only state of one kernel search (borrowed by every shard;
/// also the substrate of the [`super::search`] portfolio strategies).
pub(crate) struct KernelCtx<'a> {
    pub(crate) ev: &'a Evaluator,
    pub(crate) rc: &'a ResolvedConstraints,
    pub(crate) objective: &'a Objective,
    /// Full-width count rows per component (placement materialization).
    pub(crate) rows: &'a [Vec<Vec<usize>>],
    /// The same rows as precomputed slope/intercept terms.
    pub(crate) tables: &'a [RowTable],
}

impl KernelCtx<'_> {
    /// Build the placement selected by one row index per component —
    /// only paid when a candidate actually becomes the running best.
    pub(crate) fn materialize(&self, sel: &[usize]) -> Placement {
        Placement {
            x: sel.iter().enumerate().map(|(c, &i)| self.rows[c][i].clone()).collect(),
        }
    }

    /// Fold the candidate currently composed in `acc` into `best` under
    /// the objective.  `make` materializes the placement lazily.
    /// Returns the candidate's `R0*` so leaves can count infeasible
    /// (pruned) candidates without re-reading the accumulator.
    pub(crate) fn consider_scored(
        &self,
        acc: &AccumState,
        make: impl FnOnce() -> Placement,
        best: &mut Option<Best>,
    ) -> f64 {
        let r = acc.rate(&self.ev.cap);
        match self.objective {
            Objective::MaxThroughput => {
                if best.as_ref().map_or(true, |b| r > b.rate) {
                    *best = Some(Best { placement: make(), rate: r, used: 0, spread: 0.0 });
                }
            }
            Objective::MinMachinesAtRate(target) => {
                if r + 1e-9 < *target {
                    return r;
                }
                let used = acc.machines_used();
                let take = best
                    .as_ref()
                    .map_or(true, |b| used < b.used || (used == b.used && r > b.rate));
                if take {
                    *best = Some(Best { placement: make(), rate: r, used, spread: 0.0 });
                }
            }
            Objective::BalancedUtilization => {
                let decisively_better =
                    best.as_ref().map_or(true, |b| r > b.rate * (1.0 + 1e-9));
                let rate_tie = best
                    .as_ref()
                    .map_or(false, |b| !decisively_better && r >= b.rate * (1.0 - 1e-9));
                if decisively_better || rate_tie {
                    let spread = acc.spread(&self.rc.excluded, r);
                    let take = decisively_better
                        || best.as_ref().map_or(true, |b| spread + 1e-9 < b.spread);
                    if take {
                        *best = Some(Best { placement: make(), rate: r, used: 0, spread });
                    }
                }
            }
        }
        r
    }

    /// Score a seeded (non-enumerated) placement through the same row
    /// arithmetic and push order as the enumeration, so a seed that ties
    /// an enumerated twin compares bit-identically.  Returns the seed's
    /// `R0*` (journaled as a runner-up candidate).
    pub(crate) fn consider_seed(
        &self,
        p: Placement,
        best: &mut Option<Best>,
        evaluated: &mut u64,
    ) -> f64 {
        let rows = kernel::rows_of_placement(self.ev, &p);
        let mut acc = AccumState::new(self.ev.n_machines());
        for row in rows.iter().rev() {
            acc.push(row);
        }
        *evaluated += 1;
        self.consider_scored(&acc, || p, best)
    }

    /// Enumerate one contiguous slice of the outermost component's rows
    /// (component `C-1`; component 0 varies fastest, matching the
    /// batched engine's odometer order).  `pruned` counts infeasible
    /// leaves (`R0* = 0`) — a plain local counter, flushed to the
    /// telemetry registry once per search, never perturbing `evaluated`.
    fn enum_shard(
        &self,
        outer: std::ops::Range<usize>,
        best: &mut Option<Best>,
        evaluated: &mut u64,
        pruned: &mut u64,
    ) {
        let n_comp = self.tables.len();
        let mut acc = AccumState::new(self.ev.n_machines());
        let mut sel = vec![0usize; n_comp];
        for i in outer {
            sel[n_comp - 1] = i;
            acc.push(&self.tables[n_comp - 1].rows[i]);
            if n_comp == 1 {
                *evaluated += 1;
                if self.consider_scored(&acc, || self.materialize(&sel), best) <= 0.0 {
                    *pruned += 1;
                }
            } else {
                self.enum_level(n_comp - 2, &mut acc, &mut sel, best, evaluated, pruned);
            }
            acc.pop();
        }
    }

    /// DFS over components `c..=0`, innermost component 0 at the leaves.
    fn enum_level(
        &self,
        c: usize,
        acc: &mut AccumState,
        sel: &mut [usize],
        best: &mut Option<Best>,
        evaluated: &mut u64,
        pruned: &mut u64,
    ) {
        for (i, row) in self.tables[c].rows.iter().enumerate() {
            sel[c] = i;
            acc.push(row);
            if c == 0 {
                *evaluated += 1;
                if self.consider_scored(acc, || self.materialize(sel), best) <= 0.0 {
                    *pruned += 1;
                }
            } else {
                self.enum_level(c - 1, acc, sel, best, evaluated, pruned);
            }
            acc.pop();
        }
    }
}

/// Contiguous, balanced partition of `0..n` into `t` shards.
fn shard_ranges(n: usize, t: usize) -> Vec<std::ops::Range<usize>> {
    let base = n / t;
    let rem = n % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0;
    for i in 0..t {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Fold a shard's winner into the running best under the objective —
/// the same strictly-better predicate as the in-shard fold, applied in
/// shard (= enumeration) order.
pub(crate) fn merge_best(objective: &Objective, cur: &mut Option<Best>, cand: Option<Best>) {
    let Some(cand) = cand else { return };
    let take = match cur.as_ref() {
        None => true,
        Some(b) => match objective {
            Objective::MaxThroughput => cand.rate > b.rate,
            Objective::MinMachinesAtRate(_) => {
                cand.used < b.used || (cand.used == b.used && cand.rate > b.rate)
            }
            Objective::BalancedUtilization => {
                cand.rate > b.rate * (1.0 + 1e-9)
                    || (cand.rate >= b.rate * (1.0 - 1e-9) && cand.spread + 1e-9 < b.spread)
            }
        },
    };
    if take {
        *cur = Some(cand);
    }
}

/// Score the heuristics' solutions as seed candidates through the
/// kernel's row arithmetic (RR first, then hetero — the batched
/// engine's order), journaling each as a runner-up under `policy`.
/// Shared by the exhaustive kernel search and the [`super::search`]
/// portfolio strategies so every engine starts from the same incumbent.
pub(crate) fn seed_candidates(
    ctx: &KernelCtx,
    problem: &Problem,
    req: &ScheduleRequest,
    policy: &str,
    best: &mut Option<Best>,
    evaluated: &mut u64,
) {
    use crate::scheduler::default_rr::DefaultScheduler;
    use crate::scheduler::hetero::HeteroScheduler;
    let seed_req = ScheduleRequest::max_throughput().with_constraints(req.constraints.clone());
    if let Ok(h) = HeteroScheduler::default().schedule(problem, &seed_req) {
        let etg = crate::topology::Etg { counts: h.placement.counts() };
        let mut seeds: Vec<(&str, f64)> = Vec::new();
        if let Ok(rr) =
            DefaultScheduler::assign_constrained(problem.topology(), problem.cluster(), &etg, ctx.rc)
        {
            seeds.push(("seed-rr", ctx.consider_seed(rr, best, evaluated)));
        }
        seeds.push(("seed-hetero", ctx.consider_seed(h.placement, best, evaluated)));
        if crate::obs::enabled() {
            let journal = crate::obs::global().journal();
            for (label, rate) in seeds {
                journal.record(crate::obs::Event::RunnerUp {
                    policy: policy.into(),
                    label: label.into(),
                    rate,
                });
            }
        }
    }
}

/// The "no candidate survived" error, per objective.
pub(crate) fn no_best_error(objective: &Objective) -> Error {
    match objective {
        Objective::MinMachinesAtRate(t) => {
            Error::Schedule(format!("no placement in the design space sustains rate {t:.3}"))
        }
        _ => Error::Schedule("empty design space".into()),
    }
}

impl OptimalScheduler {
    pub fn sampled(candidates: usize, seed: u64) -> Self {
        OptimalScheduler { space: SearchSpace::Sampled { candidates, seed }, ..Default::default() }
    }

    /// Size of the *unconstrained* exhaustive design space for `n_comp`
    /// components on `m` machines with 1..=max instances each — the
    /// paper's eq. 1 combinatorics, used by the §3 motivation bench.
    pub fn design_space_size(&self, n_comp: usize, m: usize) -> u128 {
        let per_comp: u128 = (1..=self.max_instances_per_component as u64)
            .map(|k| placements_of(k, m as u64))
            .sum();
        per_comp.pow(n_comp as u32)
    }

    /// Enumerate all distributions of `k` instances over `m` machines.
    fn compositions(k: usize, m: usize, out: &mut Vec<Vec<usize>>) {
        fn rec(
            rest: usize,
            slot: usize,
            m: usize,
            cur: &mut Vec<usize>,
            out: &mut Vec<Vec<usize>>,
        ) {
            if slot == m - 1 {
                cur.push(rest);
                out.push(cur.clone());
                cur.pop();
                return;
            }
            for take in 0..=rest {
                cur.push(take);
                rec(rest - take, slot + 1, m, cur, out);
                cur.pop();
            }
        }
        rec(k, 0, m, &mut Vec::with_capacity(m), out);
    }

    /// Placement rows for component `c`: counts `1..=min(bound, cap_c)`
    /// distributed over the machines the constraints allow it, scattered
    /// back to full cluster width.
    pub(crate) fn component_rows(
        &self,
        c: usize,
        n_m: usize,
        rc: &ResolvedConstraints,
    ) -> Vec<Vec<usize>> {
        let allowed: Vec<usize> = (0..n_m).filter(|&m| rc.allows(c, m)).collect();
        let k_max = self.max_instances_per_component.min(rc.max_instances[c]);
        let mut packed = Vec::new();
        for k in 1..=k_max {
            Self::compositions(k, allowed.len(), &mut packed);
        }
        packed
            .into_iter()
            .map(|row| {
                let mut full = vec![0usize; n_m];
                for (slot, &count) in row.iter().enumerate() {
                    full[allowed[slot]] = count;
                }
                full
            })
            .collect()
    }

    /// Visit every placement in the cartesian product of the
    /// per-component rows, streaming into `sink`.
    fn enumerate(
        rows: &[Vec<Vec<usize>>],
        sink: &mut dyn FnMut(Placement) -> Result<()>,
    ) -> Result<()> {
        let n_comp = rows.len();
        let mut idx = vec![0usize; n_comp];
        loop {
            let p = Placement {
                x: idx.iter().enumerate().map(|(c, &i)| rows[c][i].clone()).collect(),
            };
            sink(p)?;
            // odometer increment
            let mut d = 0;
            loop {
                idx[d] += 1;
                if idx[d] < rows[d].len() {
                    break;
                }
                idx[d] = 0;
                d += 1;
                if d == n_comp {
                    return Ok(());
                }
            }
        }
    }

    /// Score a batch of candidates via one evaluation at `R0 = 1` plus
    /// the native MET load, returning each candidate's `R0*`.
    fn rate_stars(
        &self,
        ev: &Evaluator,
        scorer: &dyn PlacementScorer,
        batch: &[Placement],
    ) -> Result<Vec<f64>> {
        let rows = scorer.score_batch(batch, &vec![1.0; batch.len()])?;
        let mut out = Vec::with_capacity(batch.len());
        for (p, row) in batch.iter().zip(&rows) {
            let mut r_star = f64::INFINITY;
            let mut met_over = false;
            for m in 0..ev.n_machines() {
                let mut b = 0.0;
                for c in 0..ev.n_components() {
                    b += p.x[c][m] as f64 * ev.met_m[c][m];
                }
                if b > ev.cap[m] + 1e-9 {
                    met_over = true;
                    break;
                }
                let a = (row.util[m] - b).max(0.0);
                if a > 1e-12 {
                    r_star = r_star.min((ev.cap[m] - b) / a);
                }
            }
            out.push(if met_over || !r_star.is_finite() { 0.0 } else { r_star });
        }
        Ok(out)
    }

    /// Objective-aware candidate comparison: fold `(p, r)` into `best`.
    fn consider(
        ev: &Evaluator,
        rc: &ResolvedConstraints,
        objective: &Objective,
        best: &mut Option<Best>,
        p: Placement,
        r: f64,
    ) -> Result<()> {
        match objective {
            Objective::MaxThroughput => {
                if best.as_ref().map_or(true, |b| r > b.rate) {
                    *best = Some(Best { placement: p, rate: r, used: 0, spread: 0.0 });
                }
            }
            Objective::MinMachinesAtRate(target) => {
                if r + 1e-9 < *target {
                    return Ok(());
                }
                let used = (0..p.n_machines()).filter(|&m| p.tasks_on(m) > 0).count();
                let take = best
                    .as_ref()
                    .map_or(true, |b| used < b.used || (used == b.used && r > b.rate));
                if take {
                    *best = Some(Best { placement: p, rate: r, used, spread: 0.0 });
                }
            }
            Objective::BalancedUtilization => {
                let decisively_better = best.as_ref().map_or(true, |b| r > b.rate * (1.0 + 1e-9));
                let rate_tie = best
                    .as_ref()
                    .map_or(false, |b| !decisively_better && r >= b.rate * (1.0 - 1e-9));
                if decisively_better || rate_tie {
                    let spread = util_spread(ev, rc, &p, r)?;
                    let take = decisively_better
                        || best.as_ref().map_or(true, |b| spread + 1e-9 < b.spread);
                    if take {
                        *best = Some(Best { placement: p, rate: r, used: 0, spread });
                    }
                }
            }
        }
        Ok(())
    }

    /// The incremental kernel search: row tables + accumulator DFS,
    /// optionally sharded across threads.  Enumeration visits candidates
    /// in exactly the batched engine's odometer order (component 0's row
    /// varies fastest), so the two engines select the same schedule.
    fn search_kernel(
        &self,
        problem: &Problem,
        req: &ScheduleRequest,
        rc: &ResolvedConstraints,
        ev: &Evaluator,
    ) -> Result<Schedule> {
        let started = Instant::now();
        let top = problem.topology();
        let n_comp = top.n_components();
        let n_m = problem.cluster().n_machines();
        let mut evaluated: u64 = 0;
        let mut pruned: u64 = 0;
        let mut best: Option<Best> = None;
        if crate::obs::enabled() {
            crate::obs::global().journal().record(crate::obs::Event::SearchStarted {
                policy: self.name().into(),
                components: n_comp,
                machines: n_m,
            });
        }

        let rows: Vec<Vec<Vec<usize>>> =
            (0..n_comp).map(|c| self.component_rows(c, n_m, rc)).collect();
        let size = rows.iter().fold(1u128, |acc, r| acc.saturating_mul(r.len() as u128));
        if size > self.enumeration_limit as u128 {
            return Err(Error::Schedule(format!(
                "design space has {size} placements (> limit {}); use SearchSpace::Sampled",
                self.enumeration_limit
            )));
        }
        let tables: Vec<RowTable> =
            (0..n_comp).map(|c| RowTable::build(ev, c, &rows[c])).collect();
        let ctx = KernelCtx { ev, rc, objective: &req.objective, rows: &rows, tables: &tables };

        if self.seed_heuristics {
            // include the heuristics' solutions in the candidate set, in
            // the same order the batched engine scores them (RR first)
            seed_candidates(&ctx, problem, req, self.name(), &mut best, &mut evaluated);
        }

        if !req.budget.is_unlimited() {
            // anytime mode: a sequential budgeted walk over the same
            // enumeration order (no bound pruning — this policy's
            // contract is the plain exhaustive fold), reporting partial
            // coverage through the provenance certainty fields
            let mut meter = super::search::BudgetMeter::new(&req.budget, n_m as u64);
            meter.charge_n(evaluated); // the seeds count against the budget
            let glob = super::search::global_bound(&ctx);
            let out = super::search::walk(&ctx, best, glob, &mut meter, false);
            evaluated += out.evaluated;
            pruned += out.pruned;
            let best = out.best.ok_or_else(|| no_best_error(&req.objective))?;
            if best.rate <= 0.0 {
                return Err(Error::Schedule("no feasible placement in the design space".into()));
            }
            let mut s = finish(ev, best.placement)?;
            let (bound, gap) = super::search::certify(out.terminated, s.rate, out.frontier, glob);
            s.provenance = Provenance {
                policy: self.name().into(),
                objective: req.objective.describe(),
                placements_evaluated: evaluated,
                backend: "kernel".into(),
                wall: started.elapsed(),
                bound,
                optimality_gap: gap,
                terminated: out.terminated,
            };
            super::record_schedule_telemetry(&s, pruned);
            super::debug_validate(problem, req, &s);
            return Ok(s);
        }

        let outer_rows = tables[n_comp - 1].rows.len();
        // tiny spaces stay sequential: thread spawns would dominate the
        // search itself (the controller re-plans micro spaces every step)
        let threads = if size <= 4096 {
            1
        } else {
            match req.objective {
                Objective::BalancedUtilization => 1,
                _ => {
                    let want = if self.threads == 0 {
                        std::thread::available_parallelism().map_or(1, |n| n.get())
                    } else {
                        self.threads
                    };
                    want.clamp(1, outer_rows.max(1))
                }
            }
        };

        if threads <= 1 {
            ctx.enum_shard(0..outer_rows, &mut best, &mut evaluated, &mut pruned);
        } else {
            let shards: Vec<(Option<Best>, u64, u64)> = std::thread::scope(|s| {
                let handles: Vec<_> = shard_ranges(outer_rows, threads)
                    .into_iter()
                    .map(|range| {
                        let ctx = &ctx;
                        s.spawn(move || {
                            let mut b = None;
                            let mut n = 0u64;
                            let mut pr = 0u64;
                            ctx.enum_shard(range, &mut b, &mut n, &mut pr);
                            (b, n, pr)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("optimal search shard panicked"))
                    .collect()
            });
            // fold shard winners in enumeration order: a later shard only
            // replaces the running best when strictly better, which is
            // exactly the sequential first-wins fold
            for (shard_best, n, pr) in shards {
                evaluated += n;
                pruned += pr;
                merge_best(&req.objective, &mut best, shard_best);
            }
        }

        let best = best.ok_or_else(|| no_best_error(&req.objective))?;
        if best.rate <= 0.0 {
            return Err(Error::Schedule("no feasible placement in the design space".into()));
        }
        let mut s = finish(ev, best.placement)?;
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: "kernel".into(),
            wall: started.elapsed(),
            // exhaustion proves the incumbent is the space's optimum
            bound: Some(s.rate),
            optimality_gap: Some(0.0),
            terminated: super::Termination::Exhausted,
        };
        super::record_schedule_telemetry(&s, pruned);
        super::debug_validate(problem, req, &s);
        Ok(s)
    }

    /// The search proper, over an already-resolved request.
    fn search(
        &self,
        problem: &Problem,
        req: &ScheduleRequest,
        rc: &ResolvedConstraints,
        ev: &Evaluator,
        scorer: &dyn PlacementScorer,
    ) -> Result<Schedule> {
        let started = Instant::now();
        let top = problem.topology();
        let n_comp = top.n_components();
        let n_m = problem.cluster().n_machines();
        let mut evaluated: u64 = 0;
        let mut pruned: u64 = 0;
        if crate::obs::enabled() {
            crate::obs::global().journal().record(crate::obs::Event::SearchStarted {
                policy: self.name().into(),
                components: n_comp,
                machines: n_m,
            });
        }

        let mut best: Option<Best> = None;
        let mut buf: Vec<Placement> = Vec::with_capacity(256);
        let flush = |buf: &mut Vec<Placement>,
                     best: &mut Option<Best>,
                     evaluated: &mut u64,
                     pruned: &mut u64|
         -> Result<()> {
            if buf.is_empty() {
                return Ok(());
            }
            let stars = self.rate_stars(ev, scorer, buf)?;
            *evaluated += buf.len() as u64;
            *pruned += stars.iter().filter(|r| **r <= 0.0).count() as u64;
            for (p, r) in buf.drain(..).zip(stars) {
                Self::consider(ev, rc, &req.objective, best, p, r)?;
            }
            Ok(())
        };

        if self.seed_heuristics {
            // include the heuristics' solutions in the candidate set
            // (scheduled under the same constraints, max-throughput)
            use crate::scheduler::default_rr::DefaultScheduler;
            use crate::scheduler::hetero::HeteroScheduler;
            let seed_req =
                ScheduleRequest::max_throughput().with_constraints(req.constraints.clone());
            if let Ok(h) = HeteroScheduler::default().schedule(problem, &seed_req) {
                let etg = crate::topology::Etg { counts: h.placement.counts() };
                if let Ok(rr) =
                    DefaultScheduler::assign_constrained(top, problem.cluster(), &etg, rc)
                {
                    buf.push(rr);
                }
                buf.push(h.placement);
                flush(&mut buf, &mut best, &mut evaluated, &mut pruned)?;
            }
        }

        // deterministic anytime cap: candidates directly, and virtual
        // ops as candidates × machines (each batched score is O(M))
        let cand_cap: u64 = req
            .budget
            .max_candidates
            .unwrap_or(u64::MAX)
            .min(req.budget.max_virtual_ops.map_or(u64::MAX, |v| v / (n_m as u64).max(1)));
        const BUDGET_STOP: &str = "__search budget exhausted__";
        let mut terminated = super::Termination::Exhausted;
        match &self.space {
            SearchSpace::Exhaustive => {
                let rows: Vec<Vec<Vec<usize>>> =
                    (0..n_comp).map(|c| self.component_rows(c, n_m, rc)).collect();
                let size = rows
                    .iter()
                    .fold(1u128, |acc, r| acc.saturating_mul(r.len() as u128));
                if size > self.enumeration_limit as u128 {
                    return Err(Error::Schedule(format!(
                        "design space has {size} placements (> limit {}); use SearchSpace::Sampled",
                        self.enumeration_limit
                    )));
                }
                let walked = Self::enumerate(&rows, &mut |p| {
                    if evaluated + buf.len() as u64 >= cand_cap {
                        return Err(Error::Schedule(BUDGET_STOP.into()));
                    }
                    buf.push(p);
                    if buf.len() == 256 {
                        flush(&mut buf, &mut best, &mut evaluated, &mut pruned)?;
                    }
                    Ok(())
                });
                match walked {
                    Err(Error::Schedule(msg)) if msg == BUDGET_STOP => {
                        terminated = super::Termination::Budget;
                    }
                    other => other?,
                }
                flush(&mut buf, &mut best, &mut evaluated, &mut pruned)?;
            }
            SearchSpace::Sampled { candidates, seed } => {
                let mut rng = crate::util::rng::Rng::new(*seed);
                let allowed: Vec<Vec<usize>> = (0..n_comp)
                    .map(|c| (0..n_m).filter(|&m| rc.allows(c, m)).collect())
                    .collect();
                for _ in 0..*candidates {
                    if evaluated + buf.len() as u64 >= cand_cap {
                        terminated = super::Termination::Budget;
                        break;
                    }
                    let mut p = Placement::empty(n_comp, n_m);
                    for (c, hosts) in allowed.iter().enumerate() {
                        let k_max = self.max_instances_per_component.min(rc.max_instances[c]);
                        let k = rng.range(1, k_max.max(1));
                        for _ in 0..k {
                            p.x[c][hosts[rng.range(0, hosts.len() - 1)]] += 1;
                        }
                    }
                    buf.push(p);
                    if buf.len() == 256 {
                        flush(&mut buf, &mut best, &mut evaluated, &mut pruned)?;
                    }
                }
                flush(&mut buf, &mut best, &mut evaluated, &mut pruned)?;
            }
        }

        let best = best.ok_or_else(|| no_best_error(&req.objective))?;
        if best.rate <= 0.0 {
            return Err(Error::Schedule("no feasible placement in the design space".into()));
        }
        let mut s = finish(ev, best.placement)?;
        // a complete exhaustive sweep certifies optimality; sampling and
        // budget-truncated walks prove no bound through this engine
        let (bound, gap) = match (&self.space, terminated) {
            (SearchSpace::Exhaustive, super::Termination::Exhausted) => {
                (Some(s.rate), Some(0.0))
            }
            _ => (None, None),
        };
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: scorer.backend().into(),
            wall: started.elapsed(),
            bound,
            optimality_gap: gap,
            terminated,
        };
        super::record_schedule_telemetry(&s, pruned);
        super::debug_validate(problem, req, &s);
        Ok(s)
    }

    /// Search with a pluggable scorer (the PJRT path in production).
    pub fn schedule_with_scorer(
        &self,
        problem: &Problem,
        req: &ScheduleRequest,
        scorer: &dyn PlacementScorer,
    ) -> Result<Schedule> {
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        self.search(problem, req, &rc, &ev, scorer)
    }

    /// Force the naive batched engine on the native mirror — the
    /// comparator the equivalence suite and `bench sched-perf` race the
    /// incremental kernel against.
    pub fn schedule_naive(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        let scorer = NativeScorer::from_evaluator(ev.into_owned());
        self.search(problem, req, &rc, scorer.evaluator(), &scorer)
    }
}

impl Scheduler for OptimalScheduler {
    fn name(&self) -> &'static str {
        "optimal"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        match problem.scorer() {
            // an attached scorer (PJRT) owns candidate evaluation
            Some(scorer) => self.search(problem, req, &rc, &ev, scorer),
            None => match &self.space {
                // the incremental kernel is the native exhaustive engine
                SearchSpace::Exhaustive => self.search_kernel(problem, req, &rc, &ev),
                SearchSpace::Sampled { .. } => {
                    let scorer = NativeScorer::from_evaluator(ev.into_owned());
                    self.search(problem, req, &rc, scorer.evaluator(), &scorer)
                }
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::hetero::HeteroScheduler;
    use crate::scheduler::Constraints;
    use crate::topology::{benchmarks, Topology};

    fn problem(top: &Topology) -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(top, &cluster, &db).unwrap()
    }

    #[test]
    fn binom_basics() {
        assert_eq!(binom(5, 2), 10);
        assert_eq!(binom(3, 0), 1);
        assert_eq!(binom(2, 5), 0);
        // the paper's §3 example: C(30, 4) = 27,405
        assert_eq!(binom(30, 4), 27_405);
    }

    #[test]
    fn compositions_count() {
        let mut out = Vec::new();
        OptimalScheduler::compositions(3, 3, &mut out);
        // C(3+2, 2) = 10 ways
        assert_eq!(out.len(), 10);
        for row in &out {
            assert_eq!(row.iter().sum::<usize>(), 3);
        }
    }

    #[test]
    fn design_space_size_matches_rows() {
        let o = OptimalScheduler::default();
        let rc = ResolvedConstraints::unconstrained(4, 3);
        let rows = o.component_rows(0, 3, &rc);
        let per_comp = rows.len() as u128;
        assert_eq!(o.design_space_size(4, 3), per_comp.pow(4));
    }

    #[test]
    fn constrained_rows_exclude_machines() {
        let o = OptimalScheduler { max_instances_per_component: 2, ..Default::default() };
        let top = benchmarks::linear();
        let p = problem(&top);
        let rc = p.resolve(&Constraints::new().exclude_machine("i3-0")).unwrap();
        for c in 0..top.n_components() {
            for row in o.component_rows(c, 3, &rc) {
                assert_eq!(row[1], 0, "row {row:?} uses the excluded machine");
                assert!(row.iter().sum::<usize>() >= 1);
            }
        }
    }

    #[test]
    fn optimal_at_least_as_good_as_hetero() {
        for top in benchmarks::micro() {
            let p = problem(&top);
            // max 2 instances keeps the debug-mode enumeration small; the
            // >= property is guaranteed by heuristic seeding regardless.
            let opt = OptimalScheduler { max_instances_per_component: 2, ..Default::default() }
                .schedule(&p, &ScheduleRequest::max_throughput())
                .unwrap();
            let het = HeteroScheduler::default()
                .schedule(&p, &ScheduleRequest::max_throughput())
                .unwrap();
            assert!(
                opt.eval.throughput >= het.eval.throughput * 0.999,
                "{}: optimal {} < hetero {}",
                top.name,
                opt.eval.throughput,
                het.eval.throughput
            );
            assert!(opt.eval.feasible);
            assert!(opt.provenance.placements_evaluated > 0);
        }
    }

    #[test]
    fn min_machines_objective_prefers_fewer_hosts() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let o = OptimalScheduler { max_instances_per_component: 2, ..Default::default() };
        let max = o.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        let target = max.rate * 0.25;
        let s = o
            .schedule(&p, &ScheduleRequest::new(Objective::MinMachinesAtRate(target)))
            .unwrap();
        assert!(s.rate + 1e-9 >= target);
        assert!(s.machines_used() <= max.machines_used());
        // unattainable target errors
        assert!(o
            .schedule(&p, &ScheduleRequest::new(Objective::MinMachinesAtRate(max.rate * 50.0)))
            .is_err());
    }

    #[test]
    fn oversize_space_rejected() {
        let (cluster, db) = presets::homogeneous_cluster(8);
        let top = benchmarks::diamond();
        let p = Problem::new(&top, &cluster, &db).unwrap();
        let o = OptimalScheduler {
            max_instances_per_component: 6,
            enumeration_limit: 1000,
            seed_heuristics: false,
            ..Default::default()
        };
        assert!(o.schedule(&p, &ScheduleRequest::max_throughput()).is_err());
    }

    #[test]
    fn kernel_matches_naive_engine() {
        for top in benchmarks::micro() {
            let p = problem(&top);
            let o = OptimalScheduler {
                max_instances_per_component: 2,
                threads: 1,
                ..Default::default()
            };
            let k = o.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
            let n = o.schedule_naive(&p, &ScheduleRequest::max_throughput()).unwrap();
            assert_eq!(k.placement, n.placement, "{}: engines disagree", top.name);
            assert_eq!(k.rate, n.rate, "{}: finish() certifies both", top.name);
            assert_eq!(k.provenance.placements_evaluated, n.provenance.placements_evaluated);
            assert_eq!(k.provenance.backend, "kernel");
            assert_eq!(n.provenance.backend, "native");
        }
    }

    #[test]
    fn parallel_search_identical_to_sequential() {
        let top = benchmarks::diamond();
        let p = problem(&top);
        let single = OptimalScheduler {
            max_instances_per_component: 2,
            threads: 1,
            ..Default::default()
        };
        let want = single.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        for threads in [2, 3, 8] {
            let par = OptimalScheduler { threads, ..single.clone() };
            let got = par.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
            assert_eq!(got.placement, want.placement, "{threads} threads diverged");
            assert_eq!(got.rate, want.rate);
            assert_eq!(
                got.provenance.placements_evaluated,
                want.provenance.placements_evaluated
            );
        }
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for (n, t) in [(10, 3), (7, 7), (5, 2), (12, 5)] {
            let ranges = shard_ranges(n, t);
            assert_eq!(ranges.len(), t);
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn sampled_mode_returns_feasible() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let o = OptimalScheduler::sampled(500, 42);
        let s = o.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        assert!(s.eval.feasible);
        assert!(s.rate > 0.0);
    }

    #[test]
    fn sampled_deterministic_by_seed() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let a = OptimalScheduler::sampled(200, 7)
            .schedule(&p, &ScheduleRequest::max_throughput())
            .unwrap();
        let b = OptimalScheduler::sampled(200, 7)
            .schedule(&p, &ScheduleRequest::max_throughput())
            .unwrap();
        assert_eq!(a.placement, b.placement);
    }

    #[test]
    fn sampled_respects_exclusion() {
        let top = benchmarks::linear();
        let p = problem(&top);
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().exclude_machine("pentium-0"));
        let s = OptimalScheduler::sampled(300, 9).schedule(&p, &req).unwrap();
        assert_eq!(s.placement.tasks_on(0), 0);
    }
}
