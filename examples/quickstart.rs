//! Quickstart: schedule the Linear micro-benchmark on the paper's
//! Table-2 heterogeneous cluster with the proposed algorithm and print
//! the resulting execution topology graph.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hstorm::cluster::presets;
use hstorm::scheduler::default_rr::DefaultScheduler;
use hstorm::scheduler::hetero::HeteroScheduler;
use hstorm::scheduler::Scheduler;
use hstorm::topology::{benchmarks, Etg};

fn main() -> hstorm::Result<()> {
    let top = benchmarks::linear();
    let (cluster, profiles) = presets::paper_cluster();

    println!("== hstorm quickstart ==");
    println!("topology '{}' ({} components), cluster '{}' ({} machines)\n",
        top.name, top.n_components(), cluster.name, cluster.n_machines());

    // The paper's scheduler: builds the ETG *and* the assignment.
    let ours = HeteroScheduler::default().schedule(&top, &cluster, &profiles)?;
    println!("proposed scheduler:");
    println!("  certified input rate  {:.1} tuple/s", ours.rate);
    println!("  predicted throughput  {:.1} tuple/s", ours.eval.throughput);
    print!("{}", ours.describe(&top, &cluster));

    // Storm's default: same instance counts, Round-Robin placement.
    let etg = Etg { counts: ours.placement.counts() };
    let default = DefaultScheduler::with_etg(etg).schedule(&top, &cluster, &profiles)?;
    println!("\nStorm default scheduler (same ETG, Round-Robin):");
    println!("  max stable rate       {:.1} tuple/s", default.rate);
    println!("  predicted throughput  {:.1} tuple/s", default.eval.throughput);

    let gain = (ours.eval.throughput - default.eval.throughput) / default.eval.throughput * 100.0;
    println!("\n=> heterogeneity-aware scheduling gains {gain:+.1}% throughput (paper: +7%..+44%)");
    Ok(())
}
