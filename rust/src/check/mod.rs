//! Deep invariant verifier over emitted schedules — `hstorm check`.
//!
//! Every correctness claim the schedulers make is re-derived here **from
//! scratch**: utilization and the eq.-5 line `util_m(R0) = a_m·R0 + b_m`
//! are rebuilt from the raw [`ProfileDb`](crate::cluster::profile::ProfileDb)
//! entries and the topology's rate gains — not from the cached
//! [`Evaluator`](crate::predict::Evaluator) tables and not from the
//! search kernel's accumulators — so a bug in either of those layers
//! cannot certify its own output.  The recomputation must agree with the
//! schedule's reported evaluation within [`UTIL_TOL`] (relative).
//!
//! Checked invariants (see the crate docs for the full table):
//!
//! * every component has at least one instance;
//! * instance counts respect the request's `max_instances` caps;
//! * excluded machines host zero instances; pinned components stay on
//!   their allowed machines;
//! * per-machine load `a_m·rate + b_m ≤ cap_m − headroom − reserved_m`
//!   (within [`CAP_TOL`], the evaluator's own feasibility slack);
//! * the certified rate does not exceed the recomputed closed-form
//!   maximum `min_m (cap_m − b_m)/a_m`;
//! * the reported per-machine utilization and `feasible` flag match the
//!   from-scratch recomputation;
//! * optimality certificates are self-consistent: a reported gap is
//!   ≥ 0, an exhausted search certifies gap 0, and a claimed bound is
//!   never below the certified rate;
//! * workload schedules: per-tenant invariants, combined utilization
//!   within the *unreduced* machine budgets, machine-disjoint placements
//!   in isolated mode, and the workload scale equal to
//!   `min_t rate_t / weight_t`;
//! * determinism: re-running the provenance-named policy reproduces the
//!   placement and certified rate bit-for-bit ([`validate_replay`]);
//! * provenance: a matching `schedule_chosen` journal event exists
//!   ([`validate_journal`]).
//!
//! Debug builds run the structural checks after every `schedule()` call
//! (see `scheduler::debug_validate`); the CLI surface additionally runs
//! the replay and journal checks.  Negative mutation tests in
//! `rust/tests/check_invariants.rs` prove each corruption class maps to
//! its own [`Violation`] variant.

use crate::scheduler::{
    registry, PolicyParams, Problem, Schedule, ScheduleRequest, TenancyMode, WorkloadProblem,
    WorkloadSchedule,
};
use crate::Result;

/// Relative tolerance for the from-scratch utilization recomputation
/// agreeing with the schedule's reported evaluation.
pub const UTIL_TOL: f64 = 1e-9;

/// Absolute slack (percentage points / tuples-per-second) for capacity
/// and rate-boundary checks — the evaluator's own feasibility slack.
pub const CAP_TOL: f64 = 1e-6;

/// One invariant violation, with a stable machine-readable code and
/// enough payload to act on.  Every seeded corruption class in the
/// mutation tests maps to a distinct variant.
#[derive(Debug, Clone, PartialEq)]
pub enum Violation {
    /// The placement's shape disagrees with the problem's.
    ShapeMismatch { got: (usize, usize), want: (usize, usize) },
    /// A component has zero instances.
    MissingComponent { component: String },
    /// A component exceeds its `max_instances` cap.
    InstanceCapExceeded { component: String, count: usize, max: usize },
    /// An excluded machine hosts instances.
    ExcludedMachine { machine: String, tasks: usize },
    /// A pinned component has instances outside its allowed machines.
    PinViolated { component: String, machine: String, instances: usize },
    /// Recomputed load exceeds the constrained machine budget.
    Overutilized { machine: String, util: f64, cap: f64 },
    /// Reported utilization disagrees with the from-scratch value.
    UtilMismatch { machine: String, reported: f64, recomputed: f64 },
    /// The certified rate exceeds the recomputed eq.-5 maximum (or is
    /// not a finite non-negative number).
    RateInfeasible { certified: f64, max: f64 },
    /// The reported `feasible` flag disagrees with the recomputation.
    FeasibleFlagWrong { reported: bool, recomputed: bool },
    /// Re-running the provenance-named policy produced a different
    /// schedule.
    ReplayDiverged { policy: String, detail: String },
    /// The provenance does not match the telemetry journal (or names an
    /// unknown tenant/policy).
    ProvenanceInconsistent { detail: String },
    /// Isolated-mode tenants share a machine.
    TenantOverlap { machine: String, tenants: Vec<String> },
    /// Combined tenant load exceeds a machine's unreduced budget.
    CombinedOverutilized { machine: String, util: f64, cap: f64 },
    /// The workload scale disagrees with `min_t rate_t / weight_t`.
    ScaleMismatch { reported: f64, recomputed: f64 },
    /// The provenance's optimality-gap certificate is self-contradictory
    /// (negative gap, or a nonzero gap after an exhausted search).
    GapInconsistent { gap: f64, detail: String },
    /// A fleet re-plan step changed the placement of a tenant that was
    /// not in the step's dirty set (residual re-plans must never move
    /// clean residents).
    ResidentMoved { tenant: String },
    /// A fleet re-plan step started more instances than the per-step
    /// migration budget allows.
    MigrationBudgetExceeded { moved: usize, budget: usize },
}

impl Violation {
    /// Stable diagnostic code, one per corruption class.
    pub fn code(&self) -> &'static str {
        match self {
            Violation::ShapeMismatch { .. } => "shape-mismatch",
            Violation::MissingComponent { .. } => "missing-component",
            Violation::InstanceCapExceeded { .. } => "instance-cap-exceeded",
            Violation::ExcludedMachine { .. } => "excluded-machine",
            Violation::PinViolated { .. } => "pin-violated",
            Violation::Overutilized { .. } => "overutilized",
            Violation::UtilMismatch { .. } => "util-mismatch",
            Violation::RateInfeasible { .. } => "rate-infeasible",
            Violation::FeasibleFlagWrong { .. } => "feasible-flag-wrong",
            Violation::ReplayDiverged { .. } => "replay-diverged",
            Violation::ProvenanceInconsistent { .. } => "provenance-inconsistent",
            Violation::TenantOverlap { .. } => "tenant-overlap",
            Violation::CombinedOverutilized { .. } => "combined-overutilized",
            Violation::ScaleMismatch { .. } => "scale-mismatch",
            Violation::GapInconsistent { .. } => "gap-inconsistent",
            Violation::ResidentMoved { .. } => "resident-moved",
            Violation::MigrationBudgetExceeded { .. } => "migration-budget-exceeded",
        }
    }

    /// One-line human rendering: `code: detail`.
    pub fn render(&self) -> String {
        match self {
            Violation::ShapeMismatch { got, want } => format!(
                "{}: placement is {}x{}, problem is {}x{}",
                self.code(),
                got.0,
                got.1,
                want.0,
                want.1
            ),
            Violation::MissingComponent { component } => {
                format!("{}: component '{component}' has zero instances", self.code())
            }
            Violation::InstanceCapExceeded { component, count, max } => format!(
                "{}: component '{component}' has {count} instances (cap {max})",
                self.code()
            ),
            Violation::ExcludedMachine { machine, tasks } => format!(
                "{}: excluded machine '{machine}' hosts {tasks} instance(s)",
                self.code()
            ),
            Violation::PinViolated { component, machine, instances } => format!(
                "{}: component '{component}' has {instances} instance(s) on \
                 disallowed machine '{machine}'",
                self.code()
            ),
            Violation::Overutilized { machine, util, cap } => format!(
                "{}: machine '{machine}' at {util:.6}% exceeds budget {cap:.6}%",
                self.code()
            ),
            Violation::UtilMismatch { machine, reported, recomputed } => format!(
                "{}: machine '{machine}' reports {reported:.12}% but recomputes \
                 to {recomputed:.12}%",
                self.code()
            ),
            Violation::RateInfeasible { certified, max } => format!(
                "{}: certified rate {certified:.6} exceeds recomputed maximum {max:.6}",
                self.code()
            ),
            Violation::FeasibleFlagWrong { reported, recomputed } => format!(
                "{}: schedule reports feasible={reported} but recomputes to {recomputed}",
                self.code()
            ),
            Violation::ReplayDiverged { policy, detail } => {
                format!("{}: policy '{policy}' replay diverged ({detail})", self.code())
            }
            Violation::ProvenanceInconsistent { detail } => {
                format!("{}: {detail}", self.code())
            }
            Violation::TenantOverlap { machine, tenants } => format!(
                "{}: isolated-mode machine '{machine}' shared by tenants [{}]",
                self.code(),
                tenants.join(", ")
            ),
            Violation::CombinedOverutilized { machine, util, cap } => format!(
                "{}: combined tenant load on '{machine}' at {util:.6}% exceeds \
                 cap {cap:.6}%",
                self.code()
            ),
            Violation::ScaleMismatch { reported, recomputed } => format!(
                "{}: workload scale {reported:.9} != min_t rate_t/weight_t = {recomputed:.9}",
                self.code()
            ),
            Violation::GapInconsistent { gap, detail } => {
                format!("{}: optimality gap {gap:.9} is inconsistent ({detail})", self.code())
            }
            Violation::ResidentMoved { tenant } => format!(
                "{}: clean tenant '{tenant}' was moved by a dirty-tenant re-plan",
                self.code()
            ),
            Violation::MigrationBudgetExceeded { moved, budget } => format!(
                "{}: step started {moved} instance(s), budget is {budget}",
                self.code()
            ),
        }
    }
}

/// The outcome of a validation pass: empty means every invariant held.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub violations: Vec<Violation>,
}

impl Report {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }

    /// Merge another report's findings into this one.
    pub fn absorb(&mut self, other: Report) {
        self.violations.extend(other.violations);
    }

    /// Multi-line rendering, one violation per line; "ok" when clean.
    pub fn render(&self) -> String {
        if self.violations.is_empty() {
            "ok".to_string()
        } else {
            self.violations.iter().map(|v| v.render()).collect::<Vec<_>>().join("\n")
        }
    }
}

/// Per-machine `(a_m, b_m)` of the eq.-5 line `util_m(R0) = a_m·R0 +
/// b_m`, rebuilt from the raw profile database — deliberately not from
/// the problem's cached evaluator tables, so the check is independent
/// of the code path being checked.
fn eq5_lines(problem: &Problem, placement: &crate::predict::Placement) -> Result<Vec<(f64, f64)>> {
    let top = problem.topology();
    let cluster = problem.cluster();
    let profiles = problem.profiles();
    let gains = top.rate_gains()?;
    let counts = placement.counts();
    let n_m = cluster.n_machines();
    let mut lines = vec![(0.0f64, 0.0f64); n_m];
    for (c, comp) in top.components.iter().enumerate() {
        let n_c = counts[c].max(1) as f64;
        for (m, mach) in cluster.machines.iter().enumerate() {
            let k = placement.x[c][m] as f64;
            if k > 0.0 {
                let p = profiles.get(&comp.task_type, &cluster.types[mach.type_id].name)?;
                lines[m].0 += k * p.e * gains[c] / n_c;
                lines[m].1 += k * p.met;
            }
        }
    }
    Ok(lines)
}

/// Validate one fleet control step: given every tenant's placement
/// before and after the step's dirty-tenant re-plans (both already on
/// the step's machine list), the dirty set the controller claimed, and
/// the per-step migration budget, check that
///
/// * no clean (non-dirty) tenant's placement changed at all
///   ([`Violation::ResidentMoved`]) — residual re-plans only ever
///   touch dirty tenants, and
/// * the step started at most `max_moves` instances in total
///   ([`Violation::MigrationBudgetExceeded`]).
pub fn validate_fleet(
    tenants: &[String],
    before: &[crate::predict::Placement],
    after: &[crate::predict::Placement],
    dirty: &[bool],
    max_moves: usize,
) -> Report {
    let n = before.len().min(after.len()).min(dirty.len());
    let mut v = Vec::new();
    let mut moved_total = 0usize;
    for i in 0..n {
        if !dirty[i] && before[i] != after[i] {
            let tenant = tenants
                .get(i)
                .cloned()
                .unwrap_or_else(|| format!("tenant-{i}"));
            v.push(Violation::ResidentMoved { tenant });
        }
        moved_total += crate::controller::workload::started_tasks(&before[i], &after[i]);
    }
    if moved_total > max_moves {
        v.push(Violation::MigrationBudgetExceeded { moved: moved_total, budget: max_moves });
    }
    Report { violations: v }
}

/// Validate a single-problem [`Schedule`] against every structural
/// invariant.  Errors only on malformed inputs (unknown constraint
/// names, missing profiles); invariant failures land in the report.
pub fn validate(problem: &Problem, req: &ScheduleRequest, s: &Schedule) -> Result<Report> {
    let top = problem.topology();
    let cluster = problem.cluster();
    let rc = problem.resolve(&req.constraints)?;
    let n_comp = top.n_components();
    let n_m = cluster.n_machines();
    let mut v = Vec::new();

    if s.placement.n_components() != n_comp
        || s.placement.n_machines() != n_m
        || s.eval.util.len() != n_m
    {
        v.push(Violation::ShapeMismatch {
            got: (s.placement.n_components(), s.placement.n_machines()),
            want: (n_comp, n_m),
        });
        return Ok(Report { violations: v });
    }

    // Constrained machine budgets, recomputed the same way
    // `Problem::constrained_evaluator` derives them.
    let cap: Vec<f64> = cluster
        .machines
        .iter()
        .enumerate()
        .map(|(m, mach)| (mach.cap - rc.headroom_pct - rc.reserved[m]).max(0.0))
        .collect();

    let counts = s.placement.counts();
    for (c, comp) in top.components.iter().enumerate() {
        if counts[c] == 0 {
            v.push(Violation::MissingComponent { component: comp.name.clone() });
        }
        if counts[c] > rc.max_instances[c] {
            v.push(Violation::InstanceCapExceeded {
                component: comp.name.clone(),
                count: counts[c],
                max: rc.max_instances[c],
            });
        }
        for m in 0..n_m {
            if s.placement.x[c][m] > 0 && !rc.excluded[m] && !rc.allows(c, m) {
                v.push(Violation::PinViolated {
                    component: comp.name.clone(),
                    machine: cluster.machines[m].name.clone(),
                    instances: s.placement.x[c][m],
                });
            }
        }
    }
    for m in 0..n_m {
        if rc.excluded[m] && s.placement.tasks_on(m) > 0 {
            v.push(Violation::ExcludedMachine {
                machine: cluster.machines[m].name.clone(),
                tasks: s.placement.tasks_on(m),
            });
        }
    }

    if !s.rate.is_finite() || s.rate < 0.0 {
        v.push(Violation::RateInfeasible { certified: s.rate, max: f64::NAN });
        return Ok(Report { violations: v });
    }

    // From-scratch eq.-5 recomputation at the certified rate.
    let lines = eq5_lines(problem, &s.placement)?;
    let mut over = false;
    let mut max_rate = f64::INFINITY;
    for (m, &(a, b)) in lines.iter().enumerate() {
        let util = a * s.rate + b;
        let reported = s.eval.util[m];
        if (util - reported).abs() > UTIL_TOL * reported.abs().max(1.0) {
            v.push(Violation::UtilMismatch {
                machine: cluster.machines[m].name.clone(),
                reported,
                recomputed: util,
            });
        }
        if util > cap[m] + CAP_TOL {
            over = true;
            v.push(Violation::Overutilized {
                machine: cluster.machines[m].name.clone(),
                util,
                cap: cap[m],
            });
        }
        if b > cap[m] + 1e-9 {
            max_rate = 0.0;
        } else if a > 0.0 {
            max_rate = max_rate.min((cap[m] - b) / a);
        }
    }
    let missing = counts.iter().any(|&n| n == 0);
    if !missing && max_rate.is_finite() && s.rate > max_rate + CAP_TOL * max_rate.abs().max(1.0) {
        v.push(Violation::RateInfeasible { certified: s.rate, max: max_rate });
    }
    let recomputed_feasible = !over && !missing;
    if s.eval.feasible != recomputed_feasible {
        v.push(Violation::FeasibleFlagWrong {
            reported: s.eval.feasible,
            recomputed: recomputed_feasible,
        });
    }

    // Optimality-certificate consistency: a gap is relative and can
    // never be negative, an exhausted search must certify gap 0, and a
    // claimed bound can never sit below the certified rate.
    if let Some(gap) = s.provenance.optimality_gap {
        if gap < -CAP_TOL {
            v.push(Violation::GapInconsistent {
                gap,
                detail: "gap is negative (bound below the returned rate)".into(),
            });
        } else if matches!(s.provenance.terminated, crate::scheduler::Termination::Exhausted)
            && gap > CAP_TOL
        {
            v.push(Violation::GapInconsistent {
                gap,
                detail: "search reports exhausted but certifies a nonzero gap".into(),
            });
        }
    }
    if let Some(bound) = s.provenance.bound {
        if bound + CAP_TOL * bound.abs().max(1.0) < s.rate {
            v.push(Violation::GapInconsistent {
                gap: s.provenance.optimality_gap.unwrap_or(f64::NAN),
                detail: format!("claimed bound {bound:.6} below certified rate {:.6}", s.rate),
            });
        }
    }
    Ok(Report { violations: v })
}

/// Determinism replay: rebuild the provenance-named policy with the
/// given params, re-run it on the same problem/request, and require the
/// identical placement and certified rate (bit-for-bit — the policies
/// are deterministic by construction).
pub fn validate_replay(
    problem: &Problem,
    req: &ScheduleRequest,
    s: &Schedule,
    params: &PolicyParams,
) -> Result<Report> {
    let sched = registry::create(&s.provenance.policy, params)?;
    let replay = sched.schedule(problem, req)?;
    let mut v = Vec::new();
    if replay.placement != s.placement {
        v.push(Violation::ReplayDiverged {
            policy: s.provenance.policy.clone(),
            detail: format!(
                "placements differ ({} vs {} total tasks)",
                replay.placement.total_tasks(),
                s.placement.total_tasks()
            ),
        });
    } else if replay.rate.to_bits() != s.rate.to_bits() {
        v.push(Violation::ReplayDiverged {
            policy: s.provenance.policy.clone(),
            detail: format!("rate {:.9} vs {:.9}", replay.rate, s.rate),
        });
    }
    Ok(Report { violations: v })
}

/// Provenance-vs-journal consistency: the global journal must retain a
/// `schedule_chosen` event matching this schedule's policy, evaluated
/// count and certified rate.  A no-op report when telemetry is disabled
/// (nothing was recorded to cross-check).
pub fn validate_journal(s: &Schedule) -> Report {
    if !crate::obs::enabled() {
        return Report::default();
    }
    let entries = crate::obs::global().journal().entries();
    let matched = entries.iter().rev().any(|e| match &e.event {
        crate::obs::Event::ScheduleChosen { policy, evaluated, rate, .. } => {
            *policy == s.provenance.policy
                && *evaluated == s.provenance.placements_evaluated
                && (*rate - s.rate).abs() <= UTIL_TOL * s.rate.abs().max(1.0)
        }
        _ => false,
    });
    let mut v = Vec::new();
    if !matched {
        v.push(Violation::ProvenanceInconsistent {
            detail: format!(
                "no schedule_chosen journal event matches policy '{}' \
                 (evaluated {}, rate {:.3})",
                s.provenance.policy, s.provenance.placements_evaluated, s.rate
            ),
        });
    }
    Report { violations: v }
}

/// Validate a [`WorkloadSchedule`]: per-tenant structural invariants,
/// combined utilization within the shared cluster's unreduced budgets,
/// machine-disjoint tenants in isolated mode, and the workload scale
/// identity `scale = min_t rate_t / weight_t`.
pub fn validate_workload(wp: &WorkloadProblem, ws: &WorkloadSchedule) -> Result<Report> {
    let cluster = wp.cluster();
    let n_m = cluster.n_machines();
    let mut v = Vec::new();
    let mut combined = vec![0.0f64; n_m];
    let mut owners: Vec<Vec<String>> = vec![Vec::new(); n_m];
    let mut scale = f64::INFINITY;
    let mut all_feasible = true;

    for ts in &ws.tenants {
        let Some(tp) = wp.tenant(&ts.tenant) else {
            v.push(Violation::ProvenanceInconsistent {
                detail: format!("schedule names unknown tenant '{}'", ts.tenant),
            });
            continue;
        };
        all_feasible &= ts.schedule.eval.feasible;
        if ts.weight > 0.0 {
            scale = scale.min(ts.schedule.rate / ts.weight);
        }
        let denied = ws.denied.iter().any(|d| d == &ts.tenant);
        if denied && ts.schedule.placement.total_tasks() == 0 {
            continue; // a denied tenant's empty placement carries no load
        }
        // Per-tenant structural check against an unconstrained request:
        // tenant loads must fit even the unreduced budgets, and the
        // reported per-tenant evaluation must recompute exactly.  The
        // feasible flag is mode-dependent (incremental tenants evaluate
        // under reduced caps), so it is aggregated above instead.
        let mut sub = validate(&tp.problem, &ScheduleRequest::max_throughput(), &ts.schedule)?;
        sub.violations.retain(|x| !matches!(x, Violation::FeasibleFlagWrong { .. }));
        v.extend(sub.violations);
        let lines = eq5_lines(&tp.problem, &ts.schedule.placement)?;
        for (m, &(a, b)) in lines.iter().enumerate() {
            combined[m] += a * ts.schedule.rate + b;
            if ts.schedule.placement.tasks_on(m) > 0 {
                owners[m].push(ts.tenant.clone());
            }
        }
    }

    for m in 0..n_m {
        let reported = ws.util[m];
        if (combined[m] - reported).abs() > UTIL_TOL * reported.abs().max(1.0) {
            v.push(Violation::UtilMismatch {
                machine: cluster.machines[m].name.clone(),
                reported,
                recomputed: combined[m],
            });
        }
        if combined[m] > cluster.machines[m].cap + CAP_TOL {
            v.push(Violation::CombinedOverutilized {
                machine: cluster.machines[m].name.clone(),
                util: combined[m],
                cap: cluster.machines[m].cap,
            });
        }
        if matches!(ws.mode, TenancyMode::Isolated) && owners[m].len() > 1 {
            v.push(Violation::TenantOverlap {
                machine: cluster.machines[m].name.clone(),
                tenants: owners[m].clone(),
            });
        }
    }

    let recomputed_scale = if scale.is_finite() { scale.max(0.0) } else { 0.0 };
    if (ws.scale - recomputed_scale).abs() > UTIL_TOL * recomputed_scale.abs().max(1.0) {
        v.push(Violation::ScaleMismatch { reported: ws.scale, recomputed: recomputed_scale });
    }
    let over = (0..n_m).any(|m| combined[m] > cluster.machines[m].cap + CAP_TOL);
    let recomputed_feasible = !over && all_feasible && !ws.tenants.is_empty();
    if ws.feasible != recomputed_feasible {
        v.push(Violation::FeasibleFlagWrong {
            reported: ws.feasible,
            recomputed: recomputed_feasible,
        });
    }
    Ok(Report { violations: v })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::Constraints;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    fn scheduled(req: &ScheduleRequest) -> (Problem, Schedule) {
        let p = problem();
        let s = registry::create("hetero", &PolicyParams::default())
            .unwrap()
            .schedule(&p, req)
            .unwrap();
        (p, s)
    }

    #[test]
    fn clean_schedule_passes() {
        let req = ScheduleRequest::max_throughput();
        let (p, s) = scheduled(&req);
        let report = validate(&p, &req, &s).unwrap();
        assert!(report.passed(), "{}", report.render());
        assert_eq!(report.render(), "ok");
    }

    #[test]
    fn constrained_schedule_passes() {
        let req = ScheduleRequest::max_throughput().with_constraints(
            Constraints::new()
                .exclude_machine("i3-0")
                .pin_component("spout", ["i5-0"])
                .reserve_headroom(10.0)
                .reserve_machine_load("pentium-0", 5.0),
        );
        let (p, s) = scheduled(&req);
        let report = validate(&p, &req, &s).unwrap();
        assert!(report.passed(), "{}", report.render());
    }

    #[test]
    fn overfilled_machine_is_flagged() {
        let req = ScheduleRequest::max_throughput();
        let (p, mut s) = scheduled(&req);
        // inflate the certified rate far past the eq.-5 boundary but keep
        // the reported eval consistent, isolating the capacity violation
        s.rate *= 10.0;
        s.eval = p.evaluator().evaluate(&s.placement, s.rate).unwrap();
        let report = validate(&p, &req, &s).unwrap();
        let codes: Vec<&str> = report.violations.iter().map(|x| x.code()).collect();
        assert!(codes.contains(&"overutilized"), "{codes:?}");
        assert!(codes.contains(&"rate-infeasible"), "{codes:?}");
    }

    #[test]
    fn dropped_component_is_flagged() {
        let req = ScheduleRequest::max_throughput();
        let (p, mut s) = scheduled(&req);
        for m in 0..s.placement.n_machines() {
            s.placement.x[0][m] = 0;
        }
        let report = validate(&p, &req, &s).unwrap();
        assert!(
            report.violations.iter().any(|x| x.code() == "missing-component"),
            "{}",
            report.render()
        );
    }

    #[test]
    fn tampered_util_is_flagged() {
        let req = ScheduleRequest::max_throughput();
        let (p, mut s) = scheduled(&req);
        s.eval.util[0] += 1.0;
        let report = validate(&p, &req, &s).unwrap();
        assert!(
            report.violations.iter().any(|x| x.code() == "util-mismatch"),
            "{}",
            report.render()
        );
    }

    #[test]
    fn inconsistent_gap_certificates_are_flagged() {
        use crate::scheduler::Termination;
        let req = ScheduleRequest::max_throughput();
        let (p, s) = scheduled(&req);

        // negative gap (bound below the returned rate)
        let mut neg = s.clone();
        neg.provenance.optimality_gap = Some(-0.02);
        neg.provenance.terminated = Termination::Budget;
        let report = validate(&p, &req, &neg).unwrap();
        assert!(
            report.violations.iter().any(|x| x.code() == "gap-inconsistent"),
            "{}",
            report.render()
        );

        // exhausted search claiming a nonzero gap
        let mut exh = s.clone();
        exh.provenance.optimality_gap = Some(0.07);
        exh.provenance.terminated = Termination::Exhausted;
        let report = validate(&p, &req, &exh).unwrap();
        assert!(
            report.violations.iter().any(|x| x.code() == "gap-inconsistent"),
            "{}",
            report.render()
        );

        // bound below the certified rate
        let mut low = s.clone();
        low.provenance.bound = Some(s.rate * 0.5);
        let report = validate(&p, &req, &low).unwrap();
        assert!(
            report.violations.iter().any(|x| x.code() == "gap-inconsistent"),
            "{}",
            report.render()
        );

        // a legitimate budgeted certificate passes
        let mut ok = s;
        ok.provenance.bound = Some(ok.rate * 1.08);
        ok.provenance.optimality_gap = Some(0.08);
        ok.provenance.terminated = Termination::Budget;
        let report = validate(&p, &req, &ok).unwrap();
        assert!(report.passed(), "{}", report.render());
    }

    #[test]
    fn replay_reproduces_and_detects_divergence() {
        let req = ScheduleRequest::max_throughput();
        let (p, s) = scheduled(&req);
        let params = PolicyParams::default();
        assert!(validate_replay(&p, &req, &s, &params).unwrap().passed());
        let mut tampered = s.clone();
        tampered.rate += 1.0;
        let report = validate_replay(&p, &req, &tampered, &params).unwrap();
        assert!(report.violations.iter().any(|x| x.code() == "replay-diverged"));
    }

    #[test]
    fn journal_check_matches_recorded_schedule() {
        let req = ScheduleRequest::max_throughput();
        let (_, s) = scheduled(&req);
        if crate::obs::enabled() {
            assert!(validate_journal(&s).passed());
        }
        let mut ghost = s;
        ghost.provenance.policy = "never-ran".into();
        assert!(!validate_journal(&ghost).passed());
    }
}
