//! Discrete-event, tuple-level simulator — the queueing companion to the
//! analytic model in [`super`].
//!
//! Where [`super::simulate`] answers "what utilization does eq. 5 predict
//! at rate R0", this module *runs* the placement: every task instance is
//! a FIFO queue, every machine a single server that round-robins over its
//! hosted tasks, service times come from the same `ProfileDb` means the
//! predictor reads (optionally exponentially distributed around them,
//! deterministic by seed via [`crate::util::rng`]), and tuples fan out
//! along the topology DAG under shuffle grouping with the eq.-6
//! fractional-α accumulator.  That buys the axes the closed form cannot
//! express: end-to-end latency percentiles, queue occupancy over time,
//! and an explicit backpressure verdict at rates the analytic model calls
//! unstable.
//!
//! ## Unit conventions
//!
//! A machine's CPU budget is `cap[m]` %·s per second and per-instance MET
//! overhead drains it constantly, so the budget left for tuple work is
//! `cap[m] − ΣMET`.  One tuple of component `c` costs `e[c][m]` %·s,
//! hence a wall-clock service time of `e / (cap − ΣMET)` seconds — the
//! machine's busy fraction reaches 1 exactly when eq. 5 utilization
//! reaches `cap`.  Measured utilization is reported back in eq.-5 units
//! (`busy_fraction · (cap − ΣMET) + ΣMET`), directly comparable to
//! [`crate::predict::Evaluator::evaluate`] predictions — the basis of
//! the `accuracy` experiment ([`crate::experiments::accuracy`]).
//!
//! Arrivals are deterministic (one external tuple per spout every
//! `1/(R0 · weight)` seconds — see
//! [`crate::topology::Component::weight`]; classic topologies have
//! weight 1); [`ServiceModel`] chooses whether service draws equal
//! their mean or are exponential around it.  Both modes are exactly
//! reproducible from [`EventSimConfig::seed`].
//!
//! ## Multi-tenant runs
//!
//! Co-located tenants share machine servers natively: a machine is one
//! round-robin server over **all** hosted tasks regardless of which
//! tenant owns them, so simulating a merged multi-tenant placement
//! ([`crate::scheduler::WorkloadProblem`]) needs no special casing.
//! [`simulate_grouped`] slices the run per component group (one group
//! per tenant): per-tenant throughput, sink-latency percentiles, queue
//! growth and backpressure verdicts on top of the cluster-wide report.

use std::collections::{BinaryHeap, VecDeque};

use crate::predict::Placement;
use crate::scheduler::Problem;
use crate::topology::fanout::{AlphaAcc, ShuffleCursor};
use crate::util::rng::Rng;
use crate::{Error, Result};

use super::stats;
use super::weighted_utilization;

/// How service times relate to their profiled means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceModel {
    /// Every draw equals the mean (zero queueing noise; tightest match
    /// to the analytic model, used by the `accuracy` experiment).
    Deterministic,
    /// Exponentially distributed around the mean (realistic queueing
    /// variance; latency tails grow as load approaches saturation).
    Exponential,
}

/// Event-simulator tunables.
#[derive(Debug, Clone)]
pub struct EventSimConfig {
    /// Virtual horizon, seconds.
    pub horizon: f64,
    /// Warmup cut before measurement starts, seconds (`< horizon`).
    pub warmup: f64,
    pub seed: u64,
    pub service: ServiceModel,
    /// Spouts shed external tuples once this many are in flight — a
    /// memory guard for far-over-saturation runs; any shedding is
    /// itself reported as backpressure.
    pub max_in_flight: usize,
}

impl Default for EventSimConfig {
    fn default() -> Self {
        EventSimConfig {
            horizon: 30.0,
            warmup: 5.0,
            seed: 0xE5EED,
            service: ServiceModel::Exponential,
            max_in_flight: 200_000,
        }
    }
}

impl EventSimConfig {
    /// Short-horizon configuration for per-step control-plane probes
    /// (see [`crate::controller::ControllerConfig::event_probe`]).
    /// Exponential service on purpose: a deterministic run at an
    /// analytically feasible rate is stable by construction, so only
    /// service variance lets the probe flag queueing the closed form
    /// cannot see.
    pub fn probe() -> Self {
        EventSimConfig {
            horizon: 6.0,
            warmup: 1.0,
            service: ServiceModel::Exponential,
            ..Default::default()
        }
    }
}

/// End-to-end latency of tuples that completed at a sink component
/// inside the measurement window, seconds.
#[derive(Debug, Clone)]
pub struct LatencySummary {
    pub samples: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Measured results of one event-simulation run.
#[derive(Debug, Clone)]
pub struct EventReport {
    /// Topology input rate per spout, tuples/s.
    pub rate: f64,
    pub horizon: f64,
    pub warmup: f64,
    pub seed: u64,
    /// Tuples processed per second summed over all tasks inside the
    /// window (the paper's eq.-2 throughput objective).
    pub throughput: f64,
    /// Per-component processing rates, tuples/s.
    pub comp_rate: Vec<f64>,
    /// Eq.-5-comparable utilization per machine, percent.
    pub util: Vec<f64>,
    pub mean_util: f64,
    /// Eq.-7 weighted overall utilization, percent.
    pub weighted_util: f64,
    /// Sink latency percentiles; `None` when nothing reached a sink
    /// inside the window.
    pub latency: Option<LatencySummary>,
    /// `(virtual time, total queued tuples)` samples across the horizon.
    pub queue_samples: Vec<(f64, usize)>,
    /// Peak total queue depth observed.
    pub max_queue: usize,
    /// External tuples shed by the in-flight guard.
    pub shed: u64,
    /// Queue-depth growth between the first and last post-warmup third,
    /// tuples/s (≈0 when stable, positive under backpressure).
    pub queue_growth: f64,
    /// True when queues grow without bound at this rate.
    pub backpressure: bool,
}

impl EventReport {
    /// One-line stability verdict for CLI output and reports.
    pub fn verdict(&self) -> &'static str {
        if self.backpressure {
            "DIVERGING (backpressure: queues grow without bound)"
        } else {
            "stable"
        }
    }
}

/// Tuple currently in service on a machine.
struct Current {
    task: usize,
    birth: f64,
}

/// One task instance: its home, its FIFO queue of tuple birth times,
/// and its deterministic routing state.
struct TaskState {
    comp: usize,
    machine: usize,
    queue: VecDeque<f64>,
    /// Mean wall-clock service time on the hosting machine, seconds
    /// (`∞` when MET alone exceeds the machine budget).
    svc_mean: f64,
    /// Fractional-α accumulator (eq. 6 semantics, per producer task).
    acc: AlphaAcc,
    /// Shuffle cursors, index-aligned with `downstream[comp]`.
    cursors: Vec<ShuffleCursor>,
    /// Tuples processed inside the measurement window.
    done: u64,
}

/// One machine: a single server draining its hosted tasks round-robin.
struct MachineState {
    tasks: Vec<usize>,
    rr: usize,
    current: Option<Current>,
    /// Busy seconds inside the measurement window.
    busy: f64,
    /// `cap − ΣMET`, %·s per second of budget left for tuple work.
    budget: f64,
    met_total: f64,
}

#[derive(Debug, Clone, Copy)]
enum EventKind {
    /// External arrival for spout stream `spout` (index into the
    /// topology's spout list).
    Arrival { spout: usize },
    /// The machine's in-service tuple completes.
    Finish { machine: usize },
}

#[derive(Debug, Clone, Copy)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    // BinaryHeap is a max-heap; invert so the earliest (time, seq) pops
    // first — seq makes simultaneous events deterministic.
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Sim<'a> {
    cfg: &'a EventSimConfig,
    tasks: Vec<TaskState>,
    machines: Vec<MachineState>,
    /// Task ids per component.
    tasks_of: Vec<Vec<usize>>,
    downstream: Vec<Vec<usize>>,
    is_sink: Vec<bool>,
    alpha: Vec<f64>,
    /// External-arrival shuffle cursor per spout component.
    route: Vec<ShuffleCursor>,
    heap: BinaryHeap<Event>,
    seq: u64,
    rng: Rng,
    in_flight: usize,
    queued: usize,
    /// Queued tuples per component (per-tenant breakdowns slice this).
    queued_comp: Vec<usize>,
    max_queue: usize,
    shed: u64,
    /// Shed external tuples per spout component.
    shed_comp: Vec<u64>,
    /// Sink latencies per component, seconds.
    lat_comp: Vec<Vec<f64>>,
}

impl Sim<'_> {
    fn push(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        self.heap.push(Event { t, seq: self.seq, kind });
    }

    fn draw_service(&mut self, mean: f64) -> f64 {
        match self.cfg.service {
            ServiceModel::Deterministic => mean,
            ServiceModel::Exponential => {
                let u = self.rng.f64();
                -(1.0 - u).ln() * mean
            }
        }
    }

    /// Queue a tuple on `task` and wake its machine if idle.
    fn enqueue(&mut self, task: usize, birth: f64, now: f64) {
        self.tasks[task].queue.push_back(birth);
        self.queued += 1;
        self.queued_comp[self.tasks[task].comp] += 1;
        self.in_flight += 1;
        if self.queued > self.max_queue {
            self.max_queue = self.queued;
        }
        let m = self.tasks[task].machine;
        if self.machines[m].current.is_none() {
            self.start_service(m, now);
        }
    }

    /// Pop the next tuple (round-robin over hosted tasks) into service.
    /// No-op while a tuple is already being served: `finish` calls this
    /// unconditionally after fan-out, and a same-machine fan-out enqueue
    /// may already have restarted the server — starting again would
    /// overwrite `current` and drop the in-service tuple.
    fn start_service(&mut self, m: usize, now: f64) {
        if self.machines[m].current.is_some() {
            return;
        }
        if self.machines[m].budget <= 0.0 {
            return; // MET alone exceeds the CPU budget: nothing ever serves
        }
        let n = self.machines[m].tasks.len();
        for i in 0..n {
            let idx = (self.machines[m].rr + i) % n;
            let t = self.machines[m].tasks[idx];
            let Some(birth) = self.tasks[t].queue.pop_front() else { continue };
            self.queued -= 1;
            self.queued_comp[self.tasks[t].comp] -= 1;
            self.machines[m].rr = (idx + 1) % n;
            let svc = self.draw_service(self.tasks[t].svc_mean);
            let end = now + svc;
            // busy-time overlap with the measurement window
            let lo = now.max(self.cfg.warmup);
            let hi = end.min(self.cfg.horizon);
            if hi > lo {
                self.machines[m].busy += hi - lo;
            }
            self.machines[m].current = Some(Current { task: t, birth });
            self.push(end, EventKind::Finish { machine: m });
            return;
        }
    }

    /// Complete the in-service tuple: account, fan out, serve the next.
    fn finish(&mut self, m: usize, now: f64) {
        let Some(cur) = self.machines[m].current.take() else { return };
        let t = cur.task;
        let c = self.tasks[t].comp;
        self.in_flight -= 1;
        if now > self.cfg.warmup && now <= self.cfg.horizon {
            self.tasks[t].done += 1;
            if self.is_sink[c] {
                self.lat_comp[c].push(now - cur.birth);
            }
        }
        // fan out along the DAG (shuffle grouping, fractional α); every
        // subscribed consumer component receives the full stream
        let emit = self.tasks[t].acc.step(self.alpha[c]);
        if emit > 0 {
            for di in 0..self.downstream[c].len() {
                let d = self.downstream[c][di];
                for _ in 0..emit {
                    let n_inst = self.tasks_of[d].len();
                    let slot = self.tasks[t].cursors[di].next_slot(n_inst);
                    let target = self.tasks_of[d][slot];
                    self.enqueue(target, cur.birth, now);
                }
            }
        }
        self.start_service(m, now);
    }

    /// Inject one external tuple into spout component `comp`.
    fn arrival(&mut self, comp: usize, now: f64) {
        if self.in_flight >= self.cfg.max_in_flight {
            self.shed += 1;
            self.shed_comp[comp] += 1;
            return;
        }
        let n_inst = self.tasks_of[comp].len();
        let slot = self.route[comp].next_slot(n_inst);
        let target = self.tasks_of[comp][slot];
        self.enqueue(target, now, now);
    }
}

/// One component group a grouped simulation reports on — for
/// multi-tenant runs, one group per tenant
/// ([`crate::scheduler::WorkloadProblem::event_groups`]).
#[derive(Debug, Clone)]
pub struct CompGroup {
    pub name: String,
    /// Component indices belonging to the group.
    pub comps: Vec<usize>,
}

/// Per-group (per-tenant) slice of an event-simulation run.  Co-located
/// groups share machine servers — one round-robin server per machine
/// across all groups' tasks — so these numbers expose cross-tenant
/// interference the per-tenant analytic models cannot see.
#[derive(Debug, Clone)]
pub struct GroupReport {
    pub name: String,
    /// Tuples processed per second summed over the group's tasks.
    pub throughput: f64,
    /// Sink latencies of the group's own components.
    pub latency: Option<LatencySummary>,
    /// Queue-depth growth of the group's queues, tuples/s.
    pub queue_growth: f64,
    /// Peak group queue depth at the sampling points.
    pub max_queue: usize,
    /// External tuples shed at the group's spouts.
    pub shed: u64,
    /// True when the group's queues grow without bound (or its spouts
    /// shed) at this rate.
    pub backpressure: bool,
}

impl GroupReport {
    /// One-line stability verdict for CLI output and reports.
    pub fn verdict(&self) -> &'static str {
        if self.backpressure {
            "DIVERGING"
        } else {
            "stable"
        }
    }
}

/// Queue-depth growth and divergence verdict from `(t, depth)` samples:
/// compare the first vs last post-warmup third — a stationary queue
/// keeps them comparable, an unstable one grows linearly.
fn growth_verdict(samples: &[(f64, f64)], warmup: f64) -> (f64, bool) {
    let meas: Vec<(f64, f64)> = samples.iter().copied().filter(|&(t, _)| t >= warmup).collect();
    if meas.len() < 6 {
        return (0.0, false);
    }
    let k = meas.len() / 3;
    let head: Vec<f64> = meas[..k].iter().map(|&(_, q)| q).collect();
    let tail: Vec<f64> = meas[meas.len() - k..].iter().map(|&(_, q)| q).collect();
    let head_mean = stats::mean(&head);
    let tail_mean = stats::mean(&tail);
    let span = (meas[meas.len() - 1].0 - meas[0].0) * 2.0 / 3.0;
    let growth = if span > 0.0 { (tail_mean - head_mean) / span } else { 0.0 };
    (growth, tail_mean > 2.0 * head_mean + 10.0)
}

/// Latency summary of a sample vector (`None` when empty), read off the
/// observability layer's log-bucketed histogram
/// ([`crate::obs::Histogram`]): mean and max are exact (tracked outside
/// the buckets), percentiles are bucketed (~1% relative error, clamped
/// to the observed range) — no sort, no O(n) copy per quantile.
fn summarize_latency(samples: &[f64]) -> Option<LatencySummary> {
    if samples.is_empty() {
        return None;
    }
    let h = crate::obs::Histogram::new();
    for &v in samples {
        h.observe(v);
    }
    Some(LatencySummary {
        samples: samples.len(),
        mean: h.mean(),
        p50: h.quantile(0.50),
        p95: h.quantile(0.95),
        p99: h.quantile(0.99),
        max: h.max(),
    })
}

/// Run the discrete-event simulation of `placement` at topology input
/// rate `rate` (tuples/s per spout scaled by each spout's input-rate
/// weight, the analytic model's `R0`).
pub fn simulate(
    problem: &Problem,
    placement: &Placement,
    rate: f64,
    cfg: &EventSimConfig,
) -> Result<EventReport> {
    simulate_grouped(problem, placement, rate, cfg, &[]).map(|(report, _)| report)
}

/// [`simulate`], additionally reporting per-group slices (throughput,
/// latency, queue growth, shed, backpressure per [`CompGroup`]) — the
/// multi-tenant entry point: co-located tenants share every machine's
/// single round-robin server, and this exposes who suffers when they
/// interfere.
pub fn simulate_grouped(
    problem: &Problem,
    placement: &Placement,
    rate: f64,
    cfg: &EventSimConfig,
    groups: &[CompGroup],
) -> Result<(EventReport, Vec<GroupReport>)> {
    let top = problem.topology();
    let ev = problem.evaluator();
    let n_comp = top.n_components();
    let n_machines = problem.cluster().n_machines();
    if placement.n_components() != n_comp || placement.n_machines() != n_machines {
        return Err(Error::Schedule(format!(
            "placement shape {}x{} != problem {}x{}",
            placement.n_components(),
            placement.n_machines(),
            n_comp,
            n_machines
        )));
    }
    if placement.counts().iter().any(|&n| n == 0) {
        return Err(Error::Schedule("placement misses a component".into()));
    }
    if !rate.is_finite() || rate <= 0.0 {
        return Err(Error::Schedule(format!(
            "event simulation needs a positive finite rate; got {rate}"
        )));
    }
    if !(cfg.warmup >= 0.0 && cfg.horizon > cfg.warmup && cfg.horizon.is_finite()) {
        return Err(Error::Schedule(format!(
            "event simulation needs 0 <= warmup < horizon (finite); got warmup {} horizon {}",
            cfg.warmup, cfg.horizon
        )));
    }
    if cfg.max_in_flight == 0 {
        return Err(Error::Schedule("max_in_flight must be >= 1".into()));
    }
    for g in groups {
        if let Some(&c) = g.comps.iter().find(|&&c| c >= n_comp) {
            return Err(Error::Schedule(format!(
                "group '{}' references component {c} (topology has {n_comp})",
                g.name
            )));
        }
    }

    // ---- static tables ---------------------------------------------------
    let mut met_total = vec![0.0f64; n_machines];
    for c in 0..n_comp {
        for m in 0..n_machines {
            met_total[m] += placement.x[c][m] as f64 * ev.met_m[c][m];
        }
    }
    let downstream: Vec<Vec<usize>> = (0..n_comp).map(|c| top.downstream(c)).collect();
    let is_sink: Vec<bool> = downstream.iter().map(|d| d.is_empty()).collect();
    let alpha: Vec<f64> = top.components.iter().map(|c| c.alpha).collect();
    let spouts = top.spouts();

    // ---- flatten the placement into task instances -----------------------
    let mut tasks: Vec<TaskState> = Vec::with_capacity(placement.total_tasks());
    let mut tasks_of: Vec<Vec<usize>> = vec![Vec::new(); n_comp];
    let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); n_machines];
    for c in 0..n_comp {
        for m in 0..n_machines {
            for _ in 0..placement.x[c][m] {
                let budget = ev.cap[m] - met_total[m];
                let id = tasks.len();
                tasks.push(TaskState {
                    comp: c,
                    machine: m,
                    queue: VecDeque::new(),
                    svc_mean: if budget > 0.0 { ev.e_m[c][m] / budget } else { f64::INFINITY },
                    acc: AlphaAcc::new(),
                    cursors: vec![ShuffleCursor::new(); downstream[c].len()],
                    done: 0,
                });
                tasks_of[c].push(id);
                hosted[m].push(id);
            }
        }
    }
    let machines: Vec<MachineState> = (0..n_machines)
        .map(|m| MachineState {
            tasks: hosted[m].clone(),
            rr: 0,
            current: None,
            busy: 0.0,
            budget: ev.cap[m] - met_total[m],
            met_total: met_total[m],
        })
        .collect();

    let mut sim = Sim {
        cfg,
        tasks,
        machines,
        tasks_of,
        downstream,
        is_sink,
        alpha,
        route: vec![ShuffleCursor::new(); n_comp],
        heap: BinaryHeap::new(),
        seq: 0,
        rng: Rng::new(cfg.seed),
        in_flight: 0,
        queued: 0,
        queued_comp: vec![0; n_comp],
        max_queue: 0,
        shed: 0,
        shed_comp: vec![0; n_comp],
        lat_comp: vec![Vec::new(); n_comp],
    };

    // seed the arrival streams, phase-staggered so multi-spout
    // topologies do not inject in lockstep; each spout arrives at
    // `rate · weight` (input-rate weights — multi-tenant merges scale a
    // tenant's spouts by its rate-weight)
    let spout_inter: Vec<f64> =
        spouts.iter().map(|&c| 1.0 / (rate * top.components[c].weight)).collect();
    for i in 0..spouts.len() {
        let t0 = spout_inter[i] * (i as f64 + 1.0) / spouts.len() as f64;
        sim.push(t0, EventKind::Arrival { spout: i });
    }

    // ---- event loop ------------------------------------------------------
    let n_samples = 64usize;
    let sample_dt = cfg.horizon / n_samples as f64;
    let mut sample_k = 1usize;
    let mut queue_samples: Vec<(f64, usize)> = Vec::with_capacity(n_samples);
    let mut comp_samples: Vec<Vec<usize>> = Vec::with_capacity(n_samples);
    while let Some(event) = sim.heap.pop() {
        let now = event.t;
        while sample_k <= n_samples && sample_k as f64 * sample_dt <= now {
            queue_samples.push((sample_k as f64 * sample_dt, sim.queued));
            comp_samples.push(sim.queued_comp.clone());
            sample_k += 1;
        }
        if now > cfg.horizon {
            break;
        }
        match event.kind {
            EventKind::Arrival { spout } => {
                sim.arrival(spouts[spout], now);
                let next = now + spout_inter[spout];
                if next <= cfg.horizon {
                    sim.push(next, EventKind::Arrival { spout });
                }
            }
            EventKind::Finish { machine } => sim.finish(machine, now),
        }
    }
    while sample_k <= n_samples {
        queue_samples.push((sample_k as f64 * sample_dt, sim.queued));
        comp_samples.push(sim.queued_comp.clone());
        sample_k += 1;
    }

    // ---- report ----------------------------------------------------------
    let window = cfg.horizon - cfg.warmup;
    let mut done_comp = vec![0u64; n_comp];
    for t in &sim.tasks {
        done_comp[t.comp] += t.done;
    }
    let comp_rate: Vec<f64> = done_comp.iter().map(|&d| d as f64 / window).collect();
    let throughput: f64 = comp_rate.iter().sum();

    let mut util = Vec::with_capacity(n_machines);
    for ms in &sim.machines {
        let frac = (ms.busy / window).clamp(0.0, 1.0);
        util.push(frac * ms.budget.max(0.0) + ms.met_total);
    }
    let mean_util = util.iter().sum::<f64>() / util.len().max(1) as f64;
    let weighted_util =
        weighted_utilization(top, problem.cluster(), problem.profiles(), &util)?;

    let all_lat: Vec<f64> = sim.lat_comp.iter().flatten().copied().collect();
    let latency = summarize_latency(&all_lat);

    let total_series: Vec<(f64, f64)> =
        queue_samples.iter().map(|&(t, q)| (t, q as f64)).collect();
    let (queue_growth, diverging) = growth_verdict(&total_series, cfg.warmup);
    let backpressure = diverging || sim.shed > 0;

    if crate::obs::enabled() {
        let reg = crate::obs::global();
        reg.gauge("sim.event.max_queue").set(sim.max_queue as f64);
        reg.counter("sim.event.shed").add(sim.shed);
        let h = reg.histogram("sim.event.latency_s");
        for &v in &all_lat {
            h.observe(v);
        }
        reg.journal().record(crate::obs::Event::BackpressureVerdict {
            rate,
            backpressure,
            queue_growth,
            shed: sim.shed,
        });
    }

    // ---- per-group (per-tenant) slices -----------------------------------
    let mut group_reports = Vec::with_capacity(groups.len());
    for g in groups {
        let g_thpt: f64 = g.comps.iter().map(|&c| comp_rate[c]).sum();
        let g_lat: Vec<f64> =
            g.comps.iter().flat_map(|&c| sim.lat_comp[c].iter().copied()).collect();
        let series: Vec<(f64, f64)> = queue_samples
            .iter()
            .zip(&comp_samples)
            .map(|(&(t, _), per_comp)| {
                (t, g.comps.iter().map(|&c| per_comp[c] as f64).sum::<f64>())
            })
            .collect();
        let (g_growth, g_diverging) = growth_verdict(&series, cfg.warmup);
        let g_shed: u64 = g.comps.iter().map(|&c| sim.shed_comp[c]).sum();
        let g_max = series.iter().map(|&(_, q)| q as usize).max().unwrap_or(0);
        group_reports.push(GroupReport {
            name: g.name.clone(),
            throughput: g_thpt,
            latency: summarize_latency(&g_lat),
            queue_growth: g_growth,
            max_queue: g_max,
            shed: g_shed,
            backpressure: g_diverging || g_shed > 0,
        });
    }

    let report = EventReport {
        rate,
        horizon: cfg.horizon,
        warmup: cfg.warmup,
        seed: cfg.seed,
        throughput,
        comp_rate,
        util,
        mean_util,
        weighted_util,
        latency,
        queue_samples,
        max_queue: sim.max_queue,
        shed: sim.shed,
        queue_growth,
        backpressure,
    };
    Ok((report, group_reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::{registry, PolicyParams, Problem, Schedule, ScheduleRequest};
    use crate::topology::benchmarks;
    use crate::topology::builder::TopologyBuilder;

    fn hetero(top: crate::topology::Topology) -> (Problem, Schedule) {
        let (cluster, db) = presets::paper_cluster();
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        let s = registry::create("hetero", &PolicyParams::default())
            .unwrap()
            .schedule(&problem, &ScheduleRequest::max_throughput())
            .unwrap();
        (problem, s)
    }

    fn det(horizon: f64, warmup: f64) -> EventSimConfig {
        EventSimConfig {
            horizon,
            warmup,
            service: ServiceModel::Deterministic,
            ..Default::default()
        }
    }

    #[test]
    fn sub_saturation_matches_prediction() {
        let (problem, s) = hetero(benchmarks::linear());
        let rate = s.rate * 0.5;
        let rep = simulate(&problem, &s.placement, rate, &det(20.0, 4.0)).unwrap();
        let pred = problem.evaluator().evaluate(&s.placement, rate).unwrap();
        // throughput: 4 components with gain 1 -> 4 * rate
        let want = 4.0 * rate;
        let rel = (rep.throughput - want).abs() / want;
        assert!(rel < 0.05, "throughput {} vs {want} (rel {rel:.3})", rep.throughput);
        // per-machine utilization tracks eq. 5 closely in deterministic mode
        for (m, (got, exp)) in rep.util.iter().zip(&pred.util).enumerate() {
            assert!((got - exp).abs() < 3.0, "machine {m}: {got} vs {exp}");
        }
        assert!(!rep.backpressure, "spurious backpressure at 50% load");
        let lat = rep.latency.expect("sink completions recorded");
        assert!(lat.samples > 100, "only {} latency samples", lat.samples);
        assert!(lat.p50 > 0.0 && lat.p50 <= lat.p95 && lat.p95 <= lat.p99);
        assert!(lat.p99 <= lat.max, "p99 {} above max {}", lat.p99, lat.max);
    }

    #[test]
    fn above_max_stable_rate_diverges() {
        let (problem, s) = hetero(benchmarks::linear());
        let rate = s.rate * 1.4;
        let rep = simulate(&problem, &s.placement, rate, &det(16.0, 3.0)).unwrap();
        assert!(rep.backpressure, "no backpressure verdict at 1.4x the max stable rate");
        assert!(rep.verdict().contains("DIVERGING"), "{}", rep.verdict());
        assert!(rep.queue_growth > 0.0 || rep.shed > 0, "growth {}", rep.queue_growth);
        assert!(rep.max_queue > 100, "max queue only {}", rep.max_queue);
        // the simulated cluster cannot keep up with the offered stream
        assert!(rep.throughput < 4.0 * rate * 0.995, "kept up at {}", rep.throughput);
    }

    #[test]
    fn deterministic_by_seed() {
        let (problem, s) = hetero(benchmarks::diamond());
        let cfg = EventSimConfig { horizon: 10.0, warmup: 2.0, seed: 77, ..Default::default() };
        let a = simulate(&problem, &s.placement, s.rate * 0.8, &cfg).unwrap();
        let b = simulate(&problem, &s.placement, s.rate * 0.8, &cfg).unwrap();
        assert_eq!(a.throughput, b.throughput);
        assert_eq!(a.max_queue, b.max_queue);
        assert_eq!(a.latency.as_ref().unwrap().p99, b.latency.as_ref().unwrap().p99);
        assert_eq!(a.queue_samples, b.queue_samples);
    }

    #[test]
    fn latency_grows_with_load_under_exponential_service() {
        let (problem, s) = hetero(benchmarks::linear());
        let cfg = EventSimConfig { horizon: 20.0, warmup: 4.0, ..Default::default() };
        let low = simulate(&problem, &s.placement, s.rate * 0.3, &cfg).unwrap();
        let high = simulate(&problem, &s.placement, s.rate * 0.9, &cfg).unwrap();
        let (l, h) = (low.latency.unwrap(), high.latency.unwrap());
        assert!(
            h.mean > l.mean,
            "queueing should raise mean latency: {} at 90% vs {} at 30%",
            h.mean,
            l.mean
        );
        assert!(h.p99 > l.p99, "p99 {} at 90% vs {} at 30%", h.p99, l.p99);
    }

    #[test]
    fn alpha_scales_downstream_rates() {
        // spout with α = 2 doubles the bolt's stream (eq. 6)
        let top = TopologyBuilder::new("amplify")
            .spout("s", "spout", 2.0)
            .bolt("b", "lowCompute", 1.0, &["s"])
            .build()
            .unwrap();
        let (problem, s) = hetero(top);
        let rate = s.rate * 0.5;
        let rep = simulate(&problem, &s.placement, rate, &det(20.0, 4.0)).unwrap();
        let ratio = rep.comp_rate[1] / rep.comp_rate[0].max(1e-9);
        assert!((ratio - 2.0).abs() < 0.1, "bolt/spout rate ratio {ratio}");
    }

    #[test]
    fn in_flight_guard_sheds_and_reports() {
        let (problem, s) = hetero(benchmarks::linear());
        let cfg = EventSimConfig {
            max_in_flight: 64,
            ..det(10.0, 2.0)
        };
        let rep = simulate(&problem, &s.placement, s.rate * 1.5, &cfg).unwrap();
        assert!(rep.shed > 0, "guard never shed");
        assert!(rep.backpressure, "shedding must count as backpressure");
        assert!(rep.max_queue <= 64 + 1, "guard leaked: {}", rep.max_queue);
    }

    #[test]
    fn invalid_inputs_rejected() {
        let (problem, s) = hetero(benchmarks::linear());
        // empty placement misses components
        let empty = Placement::empty(4, 3);
        assert!(simulate(&problem, &empty, 10.0, &det(10.0, 2.0)).is_err());
        // non-positive rate
        assert!(simulate(&problem, &s.placement, 0.0, &det(10.0, 2.0)).is_err());
        // warmup >= horizon
        assert!(simulate(&problem, &s.placement, 10.0, &det(2.0, 2.0)).is_err());
        // non-finite horizon would spin the event loop forever
        assert!(simulate(&problem, &s.placement, 10.0, &det(f64::INFINITY, 2.0)).is_err());
        // shape mismatch
        let bad = Placement::empty(2, 3);
        assert!(simulate(&problem, &bad, 10.0, &det(10.0, 2.0)).is_err());
    }

    #[test]
    fn weighted_spout_arrives_proportionally_faster() {
        // same topology, spout weight 2: the spout (and its bolt) see
        // twice the stream at the same nominal R0
        let top1 = TopologyBuilder::new("w1")
            .spout("s", "spout", 1.0)
            .bolt("b", "lowCompute", 1.0, &["s"])
            .build()
            .unwrap();
        let top2 = TopologyBuilder::new("w2")
            .spout("s", "spout", 1.0)
            .bolt("b", "lowCompute", 1.0, &["s"])
            .input_weight("s", 2.0)
            .build()
            .unwrap();
        let (p1, s1) = hetero(top1);
        let (p2, _) = hetero(top2);
        let rate = s1.rate * 0.3;
        let a = simulate(&p1, &s1.placement, rate, &det(20.0, 4.0)).unwrap();
        // reuse an equally-shaped placement for the weighted topology
        let b = simulate(&p2, &s1.placement, rate, &det(20.0, 4.0)).unwrap();
        let ratio = b.comp_rate[0] / a.comp_rate[0].max(1e-9);
        assert!((ratio - 2.0).abs() < 0.1, "weighted spout rate ratio {ratio}");
    }

    #[test]
    fn grouped_run_reports_per_tenant_slices() {
        use crate::scheduler::{Workload, WorkloadProblem};
        use std::sync::Arc;

        let (cluster, db) = presets::paper_cluster();
        let db = Arc::new(db);
        let w = Workload::new("duo")
            .tenant("search", benchmarks::linear(), db.clone(), 1.0)
            .tenant("ads", benchmarks::rolling_count(), db.clone(), 2.0);
        let wp = WorkloadProblem::new(w, &cluster).unwrap();
        let sched = registry::create("hetero", &PolicyParams::default()).unwrap();
        let ws = wp.schedule_joint(sched.as_ref(), &ScheduleRequest::max_throughput()).unwrap();
        let groups: Vec<CompGroup> = wp
            .event_groups()
            .into_iter()
            .map(|(name, comps)| CompGroup { name, comps })
            .collect();
        let merged = wp.merged_placement(&ws);
        let rate = ws.scale * 0.5;
        let (rep, slices) =
            simulate_grouped(wp.merged().unwrap(), &merged, rate, &det(20.0, 4.0), &groups)
                .unwrap();
        assert_eq!(slices.len(), 2);
        assert!(!rep.backpressure, "half the certified scale must be stable");
        // per-tenant throughput: linear = 4 comps at 1x rate, rolling
        // count = (1 + 1 + 1.5) gains at 2x rate
        let want_search = 4.0 * rate;
        let want_ads = 3.5 * 2.0 * rate;
        let rel_s = (slices[0].throughput - want_search).abs() / want_search;
        let rel_a = (slices[1].throughput - want_ads).abs() / want_ads;
        assert!(rel_s < 0.08, "search thpt {} vs {want_search}", slices[0].throughput);
        assert!(rel_a < 0.08, "ads thpt {} vs {want_ads}", slices[1].throughput);
        // group slices sum to the cluster-wide throughput
        let sum: f64 = slices.iter().map(|g| g.throughput).sum();
        assert!((sum - rep.throughput).abs() < 1e-6);
        // both tenants complete tuples at their sinks, stably
        for g in &slices {
            assert!(g.latency.is_some(), "{}: no sink latencies", g.name);
            assert!(!g.backpressure, "{}: spurious backpressure", g.name);
            assert_eq!(g.verdict(), "stable");
        }
    }

    #[test]
    fn grouped_rejects_out_of_range_components() {
        let (problem, s) = hetero(benchmarks::linear());
        let bad = [CompGroup { name: "x".into(), comps: vec![9] }];
        assert!(
            simulate_grouped(&problem, &s.placement, 10.0, &det(10.0, 2.0), &bad).is_err()
        );
    }

    #[test]
    fn queue_samples_cover_horizon() {
        let (problem, s) = hetero(benchmarks::linear());
        let rep = simulate(&problem, &s.placement, s.rate * 0.4, &det(12.0, 2.0)).unwrap();
        assert_eq!(rep.queue_samples.len(), 64);
        let (t_first, _) = rep.queue_samples[0];
        let (t_last, _) = *rep.queue_samples.last().unwrap();
        assert!(t_first > 0.0);
        assert!((t_last - 12.0).abs() < 1e-9, "last sample at {t_last}");
    }
}
