//! Table 4's large-scale simulation scenarios (paper §6.3).
//!
//! | scenario | type   | Pentium | Core i3 | Core i5 |
//! |----------|--------|---------|---------|---------|
//! | 1        | small  | 2       | 2       | 2       |
//! | 2        | medium | 10      | 10      | 10      |
//! | 3        | large  | 20      | 70      | 90      |
//!
//! Machine 1/2/3 in Table 4 map to Table 2's Pentium / Core i3 / Core i5
//! worker types.

use super::presets::{paper_profiles, CORE_I3, CORE_I5, PENTIUM};
use super::profile::ProfileDb;
use super::Cluster;

/// One Table 4 row.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub id: usize,
    pub label: &'static str,
    pub n_pentium: usize,
    pub n_i3: usize,
    pub n_i5: usize,
}

/// All three Table 4 scenarios.
pub const SCENARIOS: [Scenario; 3] = [
    Scenario { id: 1, label: "small", n_pentium: 2, n_i3: 2, n_i5: 2 },
    Scenario { id: 2, label: "medium", n_pentium: 10, n_i3: 10, n_i5: 10 },
    Scenario { id: 3, label: "large", n_pentium: 20, n_i3: 70, n_i5: 90 },
];

impl Scenario {
    pub fn total_machines(&self) -> usize {
        self.n_pentium + self.n_i3 + self.n_i5
    }

    /// Materialize the cluster (+ the shared profile DB).
    pub fn build(&self) -> (Cluster, ProfileDb) {
        let mut c = Cluster::new(format!("scenario{}-{}", self.id, self.label));
        let p = c.add_type(PENTIUM, "Pentium Dual-Core 2.6 GHz");
        let i3 = c.add_type(CORE_I3, "Intel Core i3 2.9 GHz");
        let i5 = c.add_type(CORE_I5, "Intel Core i5 2.5 GHz");
        c.add_machines(p, self.n_pentium, "pentium");
        c.add_machines(i3, self.n_i3, "i3");
        c.add_machines(i5, self.n_i5, "i5");
        (c, paper_profiles())
    }
}

/// Scenario lookup by id (1-based, as in the paper).
pub fn by_id(id: usize) -> Option<Scenario> {
    SCENARIOS.iter().copied().find(|s| s.id == id)
}

/// One-line summary of the valid scenarios for CLI error messages,
/// e.g. `1=small(6), 2=medium(30), 3=large(180)`.
pub fn describe_all() -> String {
    SCENARIOS
        .iter()
        .map(|s| format!("{}={}({})", s.id, s.label, s.total_machines()))
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_counts() {
        assert_eq!(SCENARIOS[0].total_machines(), 6);
        assert_eq!(SCENARIOS[1].total_machines(), 30);
        assert_eq!(SCENARIOS[2].total_machines(), 180);
    }

    #[test]
    fn build_all() {
        for s in SCENARIOS {
            let (c, db) = s.build();
            c.validate().unwrap();
            assert_eq!(c.n_machines(), s.total_machines());
            assert!(db.get("highCompute", CORE_I5).is_ok());
        }
    }

    #[test]
    fn by_id_lookup() {
        assert_eq!(by_id(3).unwrap().label, "large");
        assert!(by_id(4).is_none());
    }

    #[test]
    fn describe_all_lists_every_scenario() {
        let d = describe_all();
        for s in SCENARIOS {
            assert!(d.contains(&format!("{}={}", s.id, s.label)), "{d}");
        }
    }
}
