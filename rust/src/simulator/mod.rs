//! Simulators (paper §6.3): the closed-form analytic model and its
//! discrete-event companion.
//!
//! [`simulate`] is the faithful equivalent of the paper's
//! Scheduling-Simulator repo — purely model-driven, no queueing: given a
//! placement it reports per-node throughput and CPU utilization at the
//! placement's max sustainable input rate, plus the paper's aggregate
//! metrics (overall throughput, eq. 2, and **weighted overall
//! utilization**, eq. 7/8 — machines with more processing capacity weigh
//! more, with weights derived from the profiling data `1/e_ij`).
//!
//! [`event`] runs the same placement as a tuple-level discrete-event
//! simulation (per-task FIFO queues, seeded service draws, shuffle
//! fan-out), adding the axes the closed form cannot express: latency
//! percentiles, queue occupancy over time and a backpressure verdict.
//! The threaded engine ([`crate::engine`]) remains the wall-clock "real
//! cluster" substitute; the event simulator is its virtual-time sibling
//! for scales the engine cannot reach.
//!
//! Both entry points take the [`Problem`] the schedulers already hold,
//! reusing its cached [`crate::predict::Evaluator`] tables instead of
//! re-expanding profiles per call.

pub mod event;
pub mod stats;

use std::collections::BTreeMap;

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::Placement;
use crate::scheduler::Problem;
use crate::topology::Topology;
use crate::Result;

/// Per-machine simulation row.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub machine: String,
    pub machine_type: String,
    /// Tasks hosted.
    pub tasks: usize,
    /// CPU utilization at the operating rate, percent.
    pub util: f64,
    /// Sum of processing rates of hosted tasks, tuples/s.
    pub throughput: f64,
}

/// Whole-run simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Operating topology input rate (tuples/s).
    pub rate: f64,
    /// Overall throughput (paper eq. 2 objective), tuples/s.
    pub throughput: f64,
    /// Weighted overall utilization (eq. 7), percent.
    pub weighted_util: f64,
    /// Mean (unweighted) utilization, percent.
    pub mean_util: f64,
    pub nodes: Vec<NodeReport>,
}

/// Run the analytic simulation of `placement` at its max stable rate
/// (or at `rate_override` if given — used for like-for-like comparisons
/// where both schedulers must run the same input rate).  Evaluates
/// through the problem's cached [`crate::predict::Evaluator`] — no
/// per-call profile re-expansion.
pub fn simulate(
    problem: &Problem,
    placement: &Placement,
    rate_override: Option<f64>,
) -> Result<SimReport> {
    let top = problem.topology();
    let cluster = problem.cluster();
    let ev = problem.evaluator();
    let rate = match rate_override {
        Some(r) => r,
        None => ev.max_stable_rate_or_zero(placement)?,
    };
    let eval = ev.evaluate(placement, rate)?;
    let counts = placement.counts();

    let mut nodes = Vec::with_capacity(cluster.n_machines());
    for (m, mach) in cluster.machines.iter().enumerate() {
        // Tasks on machine m process their share of their component's
        // stream; a machine's throughput is the sum of those shares.
        let mut thpt = 0.0;
        for c in 0..top.n_components() {
            if placement.x[c][m] > 0 {
                let share = eval.ir_comp[c] / counts[c].max(1) as f64;
                thpt += placement.x[c][m] as f64 * share;
            }
        }
        nodes.push(NodeReport {
            machine: mach.name.clone(),
            machine_type: cluster.type_name(m).to_string(),
            tasks: placement.tasks_on(m),
            util: eval.util[m],
            throughput: thpt,
        });
    }

    let weighted_util = weighted_utilization(top, cluster, problem.profiles(), &eval.util)?;
    let mean_util = eval.util.iter().sum::<f64>() / eval.util.len().max(1) as f64;
    Ok(SimReport { rate, throughput: eval.throughput, weighted_util, mean_util, nodes })
}

/// Paper eq. 7/8: overall utilization as a weighted average over machine
/// types, with type weights proportional to profiled speed `1/e_ij`
/// summed over the topology's distinct component types.
pub fn weighted_utilization(
    top: &Topology,
    cluster: &Cluster,
    profiles: &ProfileDb,
    util: &[f64],
) -> Result<f64> {
    // distinct component (task) types — the paper's C <= n
    let mut task_types: Vec<&str> = top.components.iter().map(|c| c.task_type.as_str()).collect();
    task_types.sort_unstable();
    task_types.dedup();

    // x_{ij} = (1/e_ij) / sum_k (1/e_ik), i = machine type, j = task type
    let type_names: Vec<&str> = cluster.types.iter().map(|t| t.name.as_str()).collect();
    let mut x_i = vec![0.0f64; type_names.len()];
    for tt in &task_types {
        let inv: Vec<f64> = type_names
            .iter()
            .map(|mt| profiles.get(tt, mt).map(|p| 1.0 / p.e))
            .collect::<Result<_>>()?;
        let denom: f64 = inv.iter().sum();
        for (i, v) in inv.iter().enumerate() {
            x_i[i] += v / denom;
        }
    }
    // normalize weights across types so Σ x_i = 1
    let total: f64 = x_i.iter().sum();
    for v in &mut x_i {
        *v /= total;
    }

    // \bar u_i — mean utilization of machines of type i
    let mut sum_u: BTreeMap<usize, (f64, usize)> = BTreeMap::new();
    for (m, mach) in cluster.machines.iter().enumerate() {
        let e = sum_u.entry(mach.type_id).or_insert((0.0, 0));
        e.0 += util[m];
        e.1 += 1;
    }
    let mut u = 0.0;
    for (tid, w) in x_i.iter().enumerate() {
        if let Some((s, n)) = sum_u.get(&tid) {
            u += w * (s / *n as f64);
        }
    }
    Ok(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::{hetero::HeteroScheduler, Problem, ScheduleRequest, Scheduler};
    use crate::topology::benchmarks;

    fn hetero_schedule(
        top: &crate::topology::Topology,
        cluster: &Cluster,
        db: &ProfileDb,
    ) -> (Problem, crate::scheduler::Schedule) {
        let problem = Problem::new(top, cluster, db).unwrap();
        let s = HeteroScheduler::default()
            .schedule(&problem, &ScheduleRequest::max_throughput())
            .unwrap();
        (problem, s)
    }

    #[test]
    fn simulate_hetero_schedule() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let (problem, s) = hetero_schedule(&top, &cluster, &db);
        let rep = simulate(&problem, &s.placement, None).unwrap();
        assert!(rep.throughput > 0.0);
        assert!(rep.rate > 0.0);
        assert_eq!(rep.nodes.len(), cluster.n_machines());
        // node throughputs sum to overall throughput
        let node_sum: f64 = rep.nodes.iter().map(|n| n.throughput).sum();
        assert!((node_sum - rep.throughput).abs() < 1e-6, "{node_sum} vs {}", rep.throughput);
        // utilization within budget
        for n in &rep.nodes {
            assert!(n.util <= 100.0 + 1e-6, "{}: {}", n.machine, n.util);
        }
    }

    #[test]
    fn weighted_util_uniform_is_mean() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        // all machines equally utilized -> weighted = that value
        let u = weighted_utilization(&top, &cluster, &db, &[50.0, 50.0, 50.0]).unwrap();
        assert!((u - 50.0).abs() < 1e-9, "{u}");
    }

    #[test]
    fn weighted_util_prefers_fast_machines() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        // Table 3: Pentium (machine 0) is the fastest per tuple, so a run
        // that only loads the Pentium scores higher than one that only
        // loads the i3.
        let only_pentium = weighted_utilization(&top, &cluster, &db, &[90.0, 0.0, 0.0]).unwrap();
        let only_i3 = weighted_utilization(&top, &cluster, &db, &[0.0, 90.0, 0.0]).unwrap();
        assert!(only_pentium > only_i3, "{only_pentium} vs {only_i3}");
    }

    #[test]
    fn weighted_util_bounded_by_extremes() {
        // eq. 7 is a convex combination of per-type means, so it can
        // never leave the [min, max] envelope of the inputs
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        for utils in [[12.0, 77.0, 41.0], [0.0, 0.0, 95.0], [33.3, 33.3, 33.3]] {
            let w = weighted_utilization(&top, &cluster, &db, &utils).unwrap();
            let lo = utils.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = utils.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(w >= lo - 1e-9 && w <= hi + 1e-9, "{utils:?} -> {w}");
        }
    }

    #[test]
    fn weighted_util_single_machine_type_is_plain_mean() {
        // one machine type: its weight is 1, so eq. 7 collapses to the
        // plain mean over the machines
        let (cluster, db) = presets::homogeneous_cluster(4);
        let top = benchmarks::linear();
        let w = weighted_utilization(&top, &cluster, &db, &[10.0, 20.0, 30.0, 40.0]).unwrap();
        assert!((w - 25.0).abs() < 1e-9, "{w}");
    }

    #[test]
    fn weighted_util_single_task_type_uniform_speed_is_plain_mean() {
        // a single task type whose profiled speed is identical on every
        // machine type makes the type weights uniform; with one machine
        // per type that is again the plain mean
        use crate::cluster::profile::{ProfileDb, TaskProfile};
        use crate::topology::builder::TopologyBuilder;
        let (cluster, _) = presets::paper_cluster();
        let top = TopologyBuilder::new("mono")
            .spout("s", "uni", 1.0)
            .bolt("b", "uni", 1.0, &["s"])
            .build()
            .unwrap();
        let mut db = ProfileDb::new();
        for mt in ["pentium", "core-i3", "core-i5"] {
            db.insert("uni", mt, TaskProfile { e: 0.1, met: 1.0 });
        }
        let w = weighted_utilization(&top, &cluster, &db, &[30.0, 60.0, 90.0]).unwrap();
        assert!((w - 60.0).abs() < 1e-9, "{w}");
    }

    #[test]
    fn rate_override_respected() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let (problem, s) = hetero_schedule(&top, &cluster, &db);
        let rep = simulate(&problem, &s.placement, Some(10.0)).unwrap();
        assert!((rep.rate - 10.0).abs() < 1e-12);
        // linear topology with alpha=1: throughput = n_comp * rate
        assert!((rep.throughput - 40.0).abs() < 1e-6);
    }

    #[test]
    fn scenario_scale_simulation() {
        use crate::cluster::scenarios;
        let (cluster, db) = scenarios::by_id(1).unwrap().build();
        let top = benchmarks::diamond();
        let (problem, s) = hetero_schedule(&top, &cluster, &db);
        let rep = simulate(&problem, &s.placement, None).unwrap();
        assert!(rep.throughput > 0.0);
        assert_eq!(rep.nodes.len(), 6);
    }
}
