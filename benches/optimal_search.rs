//! Bench: the §3 complexity story — design-space sizes, candidate
//! scoring rate through the batched AOT scorer (PJRT) vs the native
//! mirror, and the measured wall time of a full bounded optimal search
//! (the paper's comparator needed ~18 h on its server).
//! Run: cargo bench --bench optimal_search  [HSTORM_FAST=1 for quick mode]

use hstorm::cluster::presets;
use hstorm::experiments::complexity;
use hstorm::predict::Placement;
use hstorm::runtime::scorer::{NativeScorer, PlacementScorer};
use hstorm::scheduler::optimal::OptimalScheduler;
use hstorm::scheduler::{Problem, ScheduleRequest, Scheduler};
use hstorm::topology::benchmarks;
use hstorm::util::bench;
use hstorm::util::rng::Rng;

fn random_batch(n: usize, n_comp: usize, m: usize, seed: u64) -> Vec<Placement> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut p = Placement::empty(n_comp, m);
            for c in 0..n_comp {
                for _ in 0..rng.range(1, 3) {
                    p.x[c][rng.range(0, m - 1)] += 1;
                }
            }
            p
        })
        .collect()
}

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, _) = bench::time_once(|| complexity::run(fast).expect("complexity runs"));
    println!("{}", result.render());

    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::linear();
    let n = top.n_components();
    let m = cluster.n_machines();
    let batch = random_batch(256, n, m, 0xBEEF);
    let rates = vec![1.0; batch.len()];

    // scoring backends head-to-head, 256 candidates per call
    let native = NativeScorer::new(&top, &cluster, &db).expect("native scorer");
    let mn = bench::run("score 256 candidates (native)", 3, if fast { 20 } else { 100 }, || {
        native.score_batch(&batch, &rates).expect("scores");
    });
    println!("  native: {:.0} candidates/s", mn.throughput(256.0));

    #[cfg(feature = "pjrt")]
    {
        use hstorm::runtime::scorer::PjRtScorer;
        use hstorm::runtime::PjRtRuntime;
        match PjRtRuntime::cpu_default() {
            Ok(rt) => {
                let pjrt = PjRtScorer::new(&rt, &top, &cluster, &db).expect("pjrt scorer");
                let iters = if fast { 20 } else { 100 };
                let mp = bench::run("score 256 candidates (pjrt AOT)", 3, iters, || {
                    pjrt.score_batch(&batch, &rates).expect("scores");
                });
                println!("  pjrt:   {:.0} candidates/s", mp.throughput(256.0));
            }
            Err(e) => println!("  (pjrt scorer skipped: {e})"),
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("  (pjrt scorer skipped: built without the `pjrt` feature)");

    // the full bounded optimal search, end to end
    let os = OptimalScheduler { max_instances_per_component: if fast { 2 } else { 3 }, ..Default::default() };
    let space = os.design_space_size(n, m);
    let problem = Problem::new(&top, &cluster, &db).expect("problem");
    let (s, dt) = bench::time_once(|| {
        os.schedule(&problem, &ScheduleRequest::max_throughput()).expect("optimal schedules")
    });
    println!(
        "full optimal search over {space} placements: {dt:?} -> rate {:.1} t/s (paper's comparator: hours)",
        s.rate
    );
}
