//! The paper's experimental setup as ready-made presets.
//!
//! * [`paper_cluster`] — Table 2's worker nodes (the second Core i3 is
//!   the master and runs Nimbus/Zookeeper, so three workers schedule
//!   tasks) plus Table 3's measured profile data.
//! * [`paper_profiles`] — Table 3 `e_ij` values verbatim; `MET_ij` is not
//!   published, we use small constants recovered the same way the paper
//!   does (eq. 5 inversion at the saturation point) from our engine —
//!   see DESIGN.md §5.

use super::profile::{ProfileDb, TaskProfile};
use super::Cluster;

/// Machine-type names used throughout the experiments.
pub const PENTIUM: &str = "pentium";
pub const CORE_I3: &str = "core-i3";
pub const CORE_I5: &str = "core-i5";

/// Table 3 `e_ij` (%·s/tuple) for the Micro-Benchmark task types, plus a
/// near-zero spout row (spouts only emit) and Storm-Benchmark rows for
/// RollingCount/UniqueVisitor (profiled on our engine; same machine
/// ordering as Table 3: Machine 1 = Pentium, 2 = i3, 3 = i5).
pub fn paper_profiles() -> ProfileDb {
    let mut db = ProfileDb::new();
    // (task_type, [e on pentium, e on i3, e on i5], met)
    let rows: &[(&str, [f64; 3], f64)] = &[
        // Table 3 verbatim. NB the paper measured the *Pentium* cheapest
        // per tuple for these CPU-bound microbenchmark bodies.
        ("lowCompute", [0.0581, 0.1070, 0.0916], 2.0),
        ("midCompute", [0.1030, 0.1844, 0.1680], 2.0),
        ("highCompute", [0.1915, 0.3449, 0.3207], 2.0),
        // Spout: emit-only, tiny serialization cost.
        ("spout", [0.0040, 0.0072, 0.0062], 1.0),
        // Storm-Benchmark profile rows (our engine measurements).
        ("splitSentence", [0.0900, 0.1600, 0.1450], 2.0),
        ("rollingCount", [0.0520, 0.0940, 0.0820], 2.0),
        ("extractVisit", [0.0480, 0.0870, 0.0760], 2.0),
        ("uniqueCount", [0.1100, 0.1980, 0.1760], 2.0),
    ];
    for (task, e, met) in rows {
        for (mi, mt) in [PENTIUM, CORE_I3, CORE_I5].iter().enumerate() {
            db.insert(task, mt, TaskProfile { e: e[mi], met: *met });
        }
    }
    db
}

/// Table 2's heterogeneous worker set: one Pentium Dual-Core, one Core
/// i3, one Core i5 (the other i3 is the master node).
pub fn paper_cluster() -> (Cluster, ProfileDb) {
    let mut c = Cluster::new("paper-table2");
    let p = c.add_type(PENTIUM, "Pentium Dual-Core 2.6 GHz, 2 GB");
    let i3 = c.add_type(CORE_I3, "Intel Core i3 2.9 GHz, 4 GB");
    let i5 = c.add_type(CORE_I5, "Intel Core i5 2.5 GHz, 6 GB");
    c.add_machines(p, 1, "pentium");
    c.add_machines(i3, 1, "i3");
    c.add_machines(i5, 1, "i5");
    (c, paper_profiles())
}

/// A homogeneous control cluster (used by ablation benches): `n` machines
/// all of the i3 type.
pub fn homogeneous_cluster(n: usize) -> (Cluster, ProfileDb) {
    let mut c = Cluster::new(format!("homogeneous-i3-x{n}"));
    let i3 = c.add_type(CORE_I3, "Intel Core i3 2.9 GHz, 4 GB");
    c.add_machines(i3, n, "i3");
    (c, paper_profiles())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_shape() {
        let (c, db) = paper_cluster();
        c.validate().unwrap();
        assert_eq!(c.n_machines(), 3);
        assert_eq!(c.n_types(), 3);
        // Table 3 spot checks
        assert_eq!(db.get("lowCompute", PENTIUM).unwrap().e, 0.0581);
        assert_eq!(db.get("midCompute", CORE_I3).unwrap().e, 0.1844);
        assert_eq!(db.get("highCompute", CORE_I5).unwrap().e, 0.3207);
    }

    #[test]
    fn homogeneous_shape() {
        let (c, _) = homogeneous_cluster(4);
        c.validate().unwrap();
        assert_eq!(c.n_machines(), 4);
        assert_eq!(c.machines_per_type(), vec![4]);
    }
}
