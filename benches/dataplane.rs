//! Bench: the batched ring dataplane vs the legacy per-tuple channel
//! dataplane, racing the same placement at the same offered load in a
//! transport-bound regime (service compressed to ~nothing, so the
//! measured wall tuples/s is pure dataplane overhead).
//!
//! CI asserts the headline: `ring >= 10x legacy tuples/s : PASS`.
//! Run: cargo bench --bench dataplane  [HSTORM_FAST=1 for quick mode]

use std::time::Duration;

use hstorm::cluster::presets;
use hstorm::engine::{self, Dataplane, EngineConfig};
use hstorm::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use hstorm::topology::benchmarks;

fn race(
    label: &str,
    dataplane: Dataplane,
    cfg: &EngineConfig,
    world: (&hstorm::topology::Topology, &hstorm::cluster::Cluster),
    db: &hstorm::cluster::profile::ProfileDb,
    placement: &hstorm::predict::Placement,
    rate: f64,
) -> f64 {
    let (top, cluster) = world;
    // two runs, best-of: the first pass also warms caches/allocator
    let mut best = 0.0f64;
    for _ in 0..2 {
        let run_cfg = EngineConfig { dataplane, ..cfg.clone() };
        let rep = engine::run(top, cluster, db, placement, rate, &run_cfg).expect("engine run");
        println!(
            "{label:<8} {:>12.0} wall tuples/s   (virtual {:>10.0}/s, shed {}, {})",
            rep.wall_throughput,
            rep.throughput,
            rep.shed,
            if rep.throttled { "throttled" } else { "unthrottled" }
        );
        best = best.max(rep.wall_throughput);
    }
    best
}

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let top = benchmarks::rolling_count();
    let (cluster, db) = presets::paper_cluster();
    let problem = Problem::new(&top, &cluster, &db).expect("problem");
    let hetero = registry::create("hetero", &PolicyParams::default()).expect("policy");
    let s = hetero.schedule(&problem, &ScheduleRequest::max_throughput()).expect("schedule");

    // compress service to ~nothing so both engines run transport-bound:
    // the offered wall rate (rate / time_scale) saturates either
    // dataplane and the measured wall tuples/s is its transport ceiling
    let cfg = EngineConfig {
        duration: Duration::from_millis(if fast { 800 } else { 1500 }),
        warmup: Duration::from_millis(if fast { 250 } else { 400 }),
        time_scale: 1e-5,
        ..Default::default()
    };
    println!(
        "racing dataplanes on '{}' (hetero placement, certified rate {:.1}, \
         offered wall rate {:.1}M tuples/s)",
        top.name,
        s.rate,
        s.rate / cfg.time_scale / 1e6
    );
    let world = (&top, &cluster);
    let ring = race("ring", Dataplane::Ring, &cfg, world, &db, &s.placement, s.rate);
    let legacy = race("legacy", Dataplane::Legacy, &cfg, world, &db, &s.placement, s.rate);

    let ratio = ring / legacy.max(1.0);
    let pass = ratio >= 10.0;
    println!(
        "ring {:.2}M vs legacy {:.2}M wall tuples/s -> {ratio:.1}x",
        ring / 1e6,
        legacy / 1e6
    );
    println!("ring >= 10x legacy tuples/s : {}", if pass { "PASS" } else { "FAIL" });
    assert!(pass, "ring dataplane only {ratio:.1}x the legacy dataplane");
}
