//! Native evaluation model: eq. 5 (CPU prediction), eq. 6 (rate
//! propagation), feasibility and throughput — an exact Rust mirror of the
//! AOT JAX/Pallas model in `python/compile/model.py`.
//!
//! The schedulers can evaluate placements through either this module or
//! the PJRT-compiled scorer ([`crate::runtime`]); integration tests
//! cross-check the two paths on identical inputs.
//!
//! Rates here are computed in exact topological order (no fixed-point
//! iteration needed natively); the closed-form [`max_stable_rate`] uses
//! the linearity of eq. 5 in the input rate: for a fixed placement,
//! `util_m(R0) = a_m * R0 + b_m`, so the largest feasible rate is
//! `min_m (cap_m - b_m) / a_m`.

pub mod kernel;

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::topology::Topology;
use crate::{Error, Result};

/// A placement: instance counts of every component on every machine.
/// `x[c][m]` = number of instances of component `c` on machine `m`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub x: Vec<Vec<usize>>,
}

impl Placement {
    /// All-zero placement for `n_comp` components over `n_machines`.
    pub fn empty(n_comp: usize, n_machines: usize) -> Self {
        Placement { x: vec![vec![0; n_machines]; n_comp] }
    }

    /// Total instances of component `c` — `N_{C_c}` in the paper.
    pub fn count(&self, c: usize) -> usize {
        self.x[c].iter().sum()
    }

    /// Instance counts per component (the ETG this placement realizes).
    pub fn counts(&self) -> Vec<usize> {
        (0..self.x.len()).map(|c| self.count(c)).collect()
    }

    /// Total tasks across all components.
    pub fn total_tasks(&self) -> usize {
        self.x.iter().map(|row| row.iter().sum::<usize>()).sum()
    }

    /// Tasks hosted on machine `m`.
    pub fn tasks_on(&self, m: usize) -> usize {
        self.x.iter().map(|row| row[m]).sum()
    }

    pub fn n_components(&self) -> usize {
        self.x.len()
    }

    pub fn n_machines(&self) -> usize {
        self.x.first().map_or(0, |r| r.len())
    }
}

/// Result of evaluating one placement at one input rate.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Predicted utilization per machine, percent (eq. 5 summed).
    pub util: Vec<f64>,
    /// Sum of the processing rates of all tasks (the paper's overall
    /// throughput objective, eq. 2), tuples/s.
    pub throughput: f64,
    /// No machine over budget and every component has >= 1 instance.
    pub feasible: bool,
    /// Component-level input rates (eq. 6 fixed point), tuples/s.
    pub ir_comp: Vec<f64>,
}

/// Static per-problem tables: expanded profiles + rate gains, computed
/// once and reused across the scheduler's many evaluations.
#[derive(Debug, Clone)]
pub struct Evaluator {
    /// `e[c][m]`: per-tuple cost of component c on machine m (%·s/tuple).
    pub e_m: Vec<Vec<f64>>,
    /// `met[c][m]`: per-instance overhead (%).
    pub met_m: Vec<Vec<f64>>,
    /// Machine CPU budgets (MAC), percent.
    pub cap: Vec<f64>,
    /// `IR_c = gain_c * R0` (eq. 6 solved symbolically).
    pub gains: Vec<f64>,
    n_comp: usize,
    n_machines: usize,
}

impl Evaluator {
    /// Build the evaluator for a (topology, cluster, profiles) triple.
    pub fn new(top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Self> {
        top.validate()?;
        cluster.validate()?;
        profiles.check_coverage(top, cluster)?;
        let (e_m, met_m) = profiles.expand(top, cluster)?;
        let gains = top.rate_gains()?;
        Ok(Evaluator {
            e_m,
            met_m,
            cap: cluster.machines.iter().map(|m| m.cap).collect(),
            gains,
            n_comp: top.n_components(),
            n_machines: cluster.n_machines(),
        })
    }

    pub fn n_components(&self) -> usize {
        self.n_comp
    }

    pub fn n_machines(&self) -> usize {
        self.n_machines
    }

    // ---- delta patches (copy-on-write world state) ----------------------
    //
    // The control plane applies cluster events as O(C) column patches
    // instead of rebuilding the whole evaluator (O(C·M) expand + full
    // re-validation).  Each patch reads the same `profiles.get(task_type,
    // type_name)` cells as [`ProfileDb::expand`], so a patched evaluator
    // is bit-identical to one built from scratch on the mutated inputs —
    // the equivalence suite in `rust/tests/fleet_equivalence.rs` pins
    // this across randomized event sequences.

    /// Append the column for the machine at index `cluster.n_machines()-1`
    /// (a machine just pushed onto `cluster`).  `O(C)`.
    pub fn patch_machine_join(
        &mut self,
        top: &Topology,
        cluster: &Cluster,
        profiles: &ProfileDb,
    ) -> Result<()> {
        if cluster.n_machines() != self.n_machines + 1 {
            return Err(Error::Cluster(format!(
                "join patch expects exactly one new machine: cluster has {}, evaluator has {}",
                cluster.n_machines(),
                self.n_machines
            )));
        }
        let mach = cluster.machines.last().expect("non-empty after join");
        let type_name = &cluster.types[mach.type_id].name;
        // read the profile cells first so a coverage gap leaves the
        // evaluator untouched
        let mut col = Vec::with_capacity(top.components.len());
        for comp in &top.components {
            let p = profiles.get(&comp.task_type, type_name)?;
            col.push((p.e, p.met));
        }
        for (ci, (e, met)) in col.into_iter().enumerate() {
            self.e_m[ci].push(e);
            self.met_m[ci].push(met);
        }
        self.cap.push(mach.cap);
        self.n_machines += 1;
        Ok(())
    }

    /// Remove machine column `m` (the machine already removed from the
    /// cluster).  `O(C·M)` worst case from the `Vec::remove` shifts, but
    /// no profile lookups or re-validation.
    pub fn patch_machine_leave(&mut self, m: usize) -> Result<()> {
        if m >= self.n_machines {
            return Err(Error::Cluster(format!(
                "leave patch: machine index {m} out of range ({} machines)",
                self.n_machines
            )));
        }
        for row in &mut self.e_m {
            row.remove(m);
        }
        for row in &mut self.met_m {
            row.remove(m);
        }
        self.cap.remove(m);
        self.n_machines -= 1;
        Ok(())
    }

    /// Remove several machine columns at once (`ms` strictly increasing,
    /// already removed from the cluster): one retain pass per row, so a
    /// whole-rack outage costs `O(C·M)` total instead of `O(C·M)` per
    /// removed machine.
    pub fn patch_machine_leave_batch(&mut self, ms: &[usize]) -> Result<()> {
        if ms.is_empty() {
            return Ok(());
        }
        if ms.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::Cluster(
                "leave batch: indices must be strictly increasing".into(),
            ));
        }
        if ms[ms.len() - 1] >= self.n_machines {
            return Err(Error::Cluster(format!(
                "leave batch: machine index {} out of range ({} machines)",
                ms[ms.len() - 1],
                self.n_machines
            )));
        }
        for row in self.e_m.iter_mut().chain(self.met_m.iter_mut()) {
            drop_indices(row, ms);
        }
        drop_indices(&mut self.cap, ms);
        self.n_machines -= ms.len();
        Ok(())
    }

    /// Re-read every `(task_type, machine_type)` cell after a profile
    /// drift mutated `profiles`.  Only the affected rows/columns are
    /// rewritten; untouched cells keep their exact bits.
    pub fn patch_profile_drift(
        &mut self,
        top: &Topology,
        cluster: &Cluster,
        profiles: &ProfileDb,
        task_type: &str,
        machine_type: &str,
    ) -> Result<()> {
        for (ci, comp) in top.components.iter().enumerate() {
            if comp.task_type != task_type {
                continue;
            }
            let p = profiles.get(task_type, machine_type)?;
            for (mi, mach) in cluster.machines.iter().enumerate() {
                if cluster.types[mach.type_id].name != machine_type {
                    continue;
                }
                self.e_m[ci][mi] = p.e;
                self.met_m[ci][mi] = p.met;
            }
        }
        Ok(())
    }

    /// Component input rates at topology rate `r0` (eq. 6).
    pub fn rates(&self, r0: f64) -> Vec<f64> {
        self.gains.iter().map(|g| g * r0).collect()
    }

    /// Predicted TCU (eq. 5) of **one instance** of component `c` on
    /// machine `m`, given the component has `n_c` instances total and the
    /// topology runs at `r0` (shuffle grouping divides the stream evenly).
    pub fn tcu_one(&self, c: usize, m: usize, n_c: usize, r0: f64) -> f64 {
        let ir_task = self.gains[c] * r0 / (n_c.max(1) as f64);
        self.e_m[c][m] * ir_task + self.met_m[c][m]
    }

    /// Full evaluation of a placement at rate `r0` — mirrors
    /// `evaluate_placements` in the AOT model (same semantics, exact
    /// arithmetic).
    pub fn evaluate(&self, p: &Placement, r0: f64) -> Result<Evaluation> {
        if p.n_components() != self.n_comp || p.n_machines() != self.n_machines {
            return Err(Error::Schedule(format!(
                "placement shape {}x{} != problem {}x{}",
                p.n_components(),
                p.n_machines(),
                self.n_comp,
                self.n_machines
            )));
        }
        let ir_comp = self.rates(r0);
        let counts = p.counts();
        let mut util = vec![0.0f64; self.n_machines];
        for c in 0..self.n_comp {
            let n_c = counts[c].max(1) as f64;
            let ir_task = ir_comp[c] / n_c;
            for m in 0..self.n_machines {
                let k = p.x[c][m] as f64;
                if k > 0.0 {
                    util[m] += k * (self.e_m[c][m] * ir_task + self.met_m[c][m]);
                }
            }
        }
        let over = util
            .iter()
            .zip(&self.cap)
            .any(|(u, c)| *u > *c + 1e-6);
        let missing = counts.iter().any(|&n| n == 0);
        let throughput = ir_comp.iter().sum();
        Ok(Evaluation { util, throughput, feasible: !over && !missing, ir_comp })
    }

    /// Closed-form largest feasible topology input rate for a placement:
    /// `util_m(R0) = a_m R0 + b_m` with
    /// `a_m = Σ_c x[c][m] e[c][m] gain_c / n_c` and
    /// `b_m = Σ_c x[c][m] met[c][m]`, so
    /// `R0* = min_m (cap_m - b_m) / a_m` (∞ if every a_m = 0, 0 if some
    /// machine is over budget on MET alone).
    pub fn max_stable_rate(&self, p: &Placement) -> Result<f64> {
        let counts = p.counts();
        if counts.iter().any(|&n| n == 0) {
            return Err(Error::Schedule("placement misses a component".into()));
        }
        let mut best = f64::INFINITY;
        for m in 0..self.n_machines {
            let mut a = 0.0f64;
            let mut b = 0.0f64;
            for c in 0..self.n_comp {
                let k = p.x[c][m] as f64;
                if k > 0.0 {
                    a += k * self.e_m[c][m] * self.gains[c] / (counts[c] as f64);
                    b += k * self.met_m[c][m];
                }
            }
            if b > self.cap[m] + 1e-9 {
                return Ok(0.0); // MET alone over budget
            }
            if a > 0.0 {
                best = best.min((self.cap[m] - b) / a);
            }
        }
        Ok(best)
    }

    /// [`max_stable_rate`](Self::max_stable_rate) clamped to an
    /// operating point: a placement whose utilization slope is zero on
    /// every machine has an unbounded symbolic rate (`∞`); callers that
    /// need a concrete rate to run at treat that as 0 — nothing real can
    /// be certified.  Shared by the schedulers, the simulator and the
    /// control plane.
    pub fn max_stable_rate_or_zero(&self, p: &Placement) -> Result<f64> {
        let r = self.max_stable_rate(p)?;
        Ok(if r.is_finite() { r } else { 0.0 })
    }

    /// Throughput at a placement's max stable rate — the objective the
    /// optimal scheduler maximizes (`Σ_c gain_c * R0*`).
    pub fn best_throughput(&self, p: &Placement) -> Result<f64> {
        let r = self.max_stable_rate(p)?;
        if !r.is_finite() {
            return Ok(0.0);
        }
        Ok(r * self.gains.iter().sum::<f64>())
    }

    // ---- speed-weighted grouping (the paper's §8 future work) -----------
    //
    // Storm's shuffle grouping divides a component's stream evenly over
    // its instances; the paper names this "simple grouping" as the main
    // obstacle to full utilization and proposes an intelligent grouping
    // that "determines adequate rates for each task depending on the
    // computation power of the machine".  The natural choice: give each
    // instance a share proportional to its machine's speed for that
    // component, `w = 1 / e[c][m]` — every instance then saturates at the
    // same input rate.

    /// Per-machine instance share weights for component `c`:
    /// `share[m] = x[c][m]·(1/e[c][m]) / Σ_m' x[c][m']·(1/e[c][m'])`.
    fn weighted_shares(&self, p: &Placement, c: usize) -> Vec<f64> {
        let mut w = vec![0.0; self.n_machines];
        let mut total = 0.0;
        for m in 0..self.n_machines {
            if p.x[c][m] > 0 && self.e_m[c][m] > 0.0 {
                w[m] = p.x[c][m] as f64 / self.e_m[c][m];
                total += w[m];
            }
        }
        if total > 0.0 {
            for v in &mut w {
                *v /= total;
            }
        }
        w
    }

    /// [`evaluate`](Self::evaluate) under speed-weighted grouping.
    pub fn evaluate_weighted(&self, p: &Placement, r0: f64) -> Result<Evaluation> {
        if p.n_components() != self.n_comp || p.n_machines() != self.n_machines {
            return Err(Error::Schedule("placement shape mismatch".into()));
        }
        let ir_comp = self.rates(r0);
        let counts = p.counts();
        let mut util = vec![0.0f64; self.n_machines];
        for c in 0..self.n_comp {
            let shares = self.weighted_shares(p, c);
            for m in 0..self.n_machines {
                let k = p.x[c][m] as f64;
                if k > 0.0 {
                    // machine m's instances of c process shares[m] of the
                    // component stream collectively
                    util[m] += self.e_m[c][m] * ir_comp[c] * shares[m]
                        + k * self.met_m[c][m];
                }
            }
        }
        let over = util.iter().zip(&self.cap).any(|(u, c)| *u > *c + 1e-6);
        let missing = counts.iter().any(|&n| n == 0);
        let throughput = ir_comp.iter().sum();
        Ok(Evaluation { util, throughput, feasible: !over && !missing, ir_comp })
    }

    /// [`max_stable_rate`](Self::max_stable_rate) under speed-weighted
    /// grouping (still closed form: shares are rate-independent).
    /// Per-component shares are computed once and accumulated over the
    /// machines, `O(C·M)` — not per `(m, c)` pair.
    pub fn max_stable_rate_weighted(&self, p: &Placement) -> Result<f64> {
        if p.counts().iter().any(|&n| n == 0) {
            return Err(Error::Schedule("placement misses a component".into()));
        }
        let mut a = vec![0.0f64; self.n_machines];
        let mut b = vec![0.0f64; self.n_machines];
        for c in 0..self.n_comp {
            let shares = self.weighted_shares(p, c);
            for m in 0..self.n_machines {
                let k = p.x[c][m] as f64;
                if k > 0.0 {
                    a[m] += self.e_m[c][m] * self.gains[c] * shares[m];
                    b[m] += k * self.met_m[c][m];
                }
            }
        }
        let mut best = f64::INFINITY;
        for m in 0..self.n_machines {
            if b[m] > self.cap[m] + 1e-9 {
                return Ok(0.0);
            }
            if a[m] > 0.0 {
                best = best.min((self.cap[m] - b[m]) / a[m]);
            }
        }
        Ok(best)
    }
}

/// Drop the strictly-increasing indices `ms` from `xs` in one pass
/// (the retain kernel behind [`Evaluator::patch_machine_leave_batch`],
/// shared with the fleet runner's placement column patching).
pub(crate) fn drop_indices<T>(xs: &mut Vec<T>, ms: &[usize]) {
    let mut mi = 0;
    let mut w = 0;
    for r in 0..xs.len() {
        if mi < ms.len() && ms[mi] == r {
            mi += 1;
            continue;
        }
        xs.swap(w, r);
        w += 1;
    }
    xs.truncate(w);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn setup() -> (Topology, Cluster, ProfileDb) {
        let (c, db) = presets::paper_cluster();
        (benchmarks::linear(), c, db)
    }

    fn one_per_machine(ev: &Evaluator) -> Placement {
        // place component c on machine c % M
        let mut p = Placement::empty(ev.n_components(), ev.n_machines());
        for c in 0..ev.n_components() {
            p.x[c][c % ev.n_machines()] = 1;
        }
        p
    }

    #[test]
    fn rates_linear_gain_one() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let r = ev.rates(42.0);
        for v in r {
            assert!((v - 42.0).abs() < 1e-9);
        }
    }

    #[test]
    fn evaluate_matches_manual() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let mut p = Placement::empty(4, 3);
        // spout->m0, low->m0, mid->m1, high->m2
        p.x[0][0] = 1;
        p.x[1][0] = 1;
        p.x[2][1] = 1;
        p.x[3][2] = 1;
        let r0 = 100.0;
        let e = ev.evaluate(&p, r0).unwrap();
        // m0: spout (0.0040*100+1) + low (0.0581*100+2) = 0.4+1 + 5.81+2
        let want0 = 0.0040 * 100.0 + 1.0 + 0.0581 * 100.0 + 2.0;
        assert!((e.util[0] - want0).abs() < 1e-9, "{} vs {want0}", e.util[0]);
        // m1: mid on i3 = 0.1844*100 + 2
        assert!((e.util[1] - (0.1844 * 100.0 + 2.0)).abs() < 1e-9);
        // m2: high on i5 = 0.3207*100 + 2
        assert!((e.util[2] - (0.3207 * 100.0 + 2.0)).abs() < 1e-9);
        assert!(e.feasible);
        assert!((e.throughput - 400.0).abs() < 1e-9);
    }

    #[test]
    fn two_instances_halve_per_task_rate() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let mut p = Placement::empty(4, 3);
        p.x[0][0] = 1;
        p.x[1][0] = 1;
        p.x[2][1] = 1;
        p.x[3][1] = 1;
        p.x[3][2] = 1; // highCompute has 2 instances
        let e = ev.evaluate(&p, 100.0).unwrap();
        // high on i5 gets half the stream: 0.3207*50 + 2
        assert!((e.util[2] - (0.3207 * 50.0 + 2.0)).abs() < 1e-9);
    }

    #[test]
    fn max_stable_rate_closed_form() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let mut p = Placement::empty(4, 3);
        p.x[0][0] = 1;
        p.x[1][0] = 1;
        p.x[2][1] = 1;
        p.x[3][2] = 1;
        let r = ev.max_stable_rate(&p).unwrap();
        // at r the binding machine sits exactly at cap
        let e = ev.evaluate(&p, r).unwrap();
        let max_u = e.util.iter().cloned().fold(0.0, f64::max);
        assert!((max_u - 100.0).abs() < 1e-6, "max util {max_u}");
        assert!(e.feasible);
        // any higher rate is infeasible
        let e2 = ev.evaluate(&p, r * 1.01).unwrap();
        assert!(!e2.feasible);
    }

    #[test]
    fn missing_component_is_error_for_rate() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let p = Placement::empty(4, 3);
        assert!(ev.max_stable_rate(&p).is_err());
    }

    #[test]
    fn met_over_budget_rate_zero() {
        let (t, c, mut db) = setup();
        // blow up MET for highCompute on every machine
        for mt in ["pentium", "core-i3", "core-i5"] {
            let profile = crate::cluster::profile::TaskProfile { e: 0.1, met: 200.0 };
            db.insert("highCompute", mt, profile);
        }
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let p = one_per_machine(&ev);
        assert_eq!(ev.max_stable_rate(&p).unwrap(), 0.0);
    }

    #[test]
    fn max_stable_rate_or_zero_clamps_unbounded() {
        // zero per-tuple cost everywhere -> the symbolic rate is infinite
        let (t, c, _) = setup();
        let mut db = crate::cluster::profile::ProfileDb::new();
        for task in ["spout", "lowCompute", "midCompute", "highCompute"] {
            for mt in ["pentium", "core-i3", "core-i5"] {
                db.insert(task, mt, crate::cluster::profile::TaskProfile { e: 0.0, met: 1.0 });
            }
        }
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let p = one_per_machine(&ev);
        assert!(ev.max_stable_rate(&p).unwrap().is_infinite());
        assert_eq!(ev.max_stable_rate_or_zero(&p).unwrap(), 0.0);
        // finite rates pass through untouched
        let (t2, c2, db2) = setup();
        let ev2 = Evaluator::new(&t2, &c2, &db2).unwrap();
        let p2 = one_per_machine(&ev2);
        assert_eq!(
            ev2.max_stable_rate_or_zero(&p2).unwrap(),
            ev2.max_stable_rate(&p2).unwrap()
        );
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (t, c, db) = setup();
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let p = Placement::empty(2, 3);
        assert!(ev.evaluate(&p, 1.0).is_err());
    }

    fn assert_bit_identical(a: &Evaluator, b: &Evaluator) {
        assert_eq!(a.n_components(), b.n_components());
        assert_eq!(a.n_machines(), b.n_machines());
        for c in 0..a.n_components() {
            for m in 0..a.n_machines() {
                assert_eq!(a.e_m[c][m].to_bits(), b.e_m[c][m].to_bits(), "e[{c}][{m}]");
                assert_eq!(a.met_m[c][m].to_bits(), b.met_m[c][m].to_bits(), "met[{c}][{m}]");
            }
        }
        for m in 0..a.n_machines() {
            assert_eq!(a.cap[m].to_bits(), b.cap[m].to_bits(), "cap[{m}]");
        }
        for c in 0..a.n_components() {
            assert_eq!(a.gains[c].to_bits(), b.gains[c].to_bits(), "gain[{c}]");
        }
    }

    #[test]
    fn patch_join_matches_rebuild() {
        let (t, mut c, db) = setup();
        let mut ev = Evaluator::new(&t, &c, &db).unwrap();
        c.machines.push(crate::cluster::Machine { name: "joined-0".into(), type_id: 1, cap: 100.0 });
        ev.patch_machine_join(&t, &c, &db).unwrap();
        let rebuilt = Evaluator::new(&t, &c, &db).unwrap();
        assert_bit_identical(&ev, &rebuilt);
    }

    #[test]
    fn patch_leave_matches_rebuild() {
        let (t, mut c, db) = setup();
        let mut ev = Evaluator::new(&t, &c, &db).unwrap();
        c.machines.remove(1);
        ev.patch_machine_leave(1).unwrap();
        let rebuilt = Evaluator::new(&t, &c, &db).unwrap();
        assert_bit_identical(&ev, &rebuilt);
    }

    #[test]
    fn patch_leave_batch_matches_rebuild() {
        let (t, c, db) = setup();
        // a bigger cluster so the batch removes a non-trivial subset
        let mut big = c.clone();
        for k in 0..6 {
            big.machines.push(crate::cluster::Machine {
                name: format!("extra-{k}"),
                type_id: k % big.types.len(),
                cap: 100.0,
            });
        }
        let mut ev = Evaluator::new(&t, &big, &db).unwrap();
        let ms = [1usize, 4, 5, 8];
        for &m in ms.iter().rev() {
            big.machines.remove(m);
        }
        ev.patch_machine_leave_batch(&ms).unwrap();
        let rebuilt = Evaluator::new(&t, &big, &db).unwrap();
        assert_bit_identical(&ev, &rebuilt);
        // and rejects unsorted / out-of-range batches untouched
        assert!(ev.patch_machine_leave_batch(&[2, 1]).is_err());
        assert!(ev.patch_machine_leave_batch(&[99]).is_err());
    }

    #[test]
    fn patch_drift_matches_rebuild() {
        let (t, c, mut db) = setup();
        let mut ev = Evaluator::new(&t, &c, &db).unwrap();
        let mut p = db.get("midCompute", "core-i3").unwrap();
        p.e *= 1.3;
        db.insert("midCompute", "core-i3", p);
        ev.patch_profile_drift(&t, &c, &db, "midCompute", "core-i3").unwrap();
        let rebuilt = Evaluator::new(&t, &c, &db).unwrap();
        assert_bit_identical(&ev, &rebuilt);
    }

    #[test]
    fn patch_join_rejects_stale_cluster() {
        let (t, c, db) = setup();
        let mut ev = Evaluator::new(&t, &c, &db).unwrap();
        // cluster unchanged: no new machine to patch in
        assert!(ev.patch_machine_join(&t, &c, &db).is_err());
        assert!(ev.patch_machine_leave(99).is_err());
    }

    #[test]
    fn best_throughput_scales_with_gain() {
        let (c, db) = presets::paper_cluster();
        let t = benchmarks::diamond(); // sink gain = 3
        let ev = Evaluator::new(&t, &c, &db).unwrap();
        let p = one_per_machine(&ev);
        let r = ev.max_stable_rate(&p).unwrap();
        let thpt = ev.best_throughput(&p).unwrap();
        let gain_sum: f64 = t.rate_gains().unwrap().iter().sum();
        assert!((thpt - r * gain_sum).abs() < 1e-9);
    }
}

#[cfg(test)]
mod weighted_tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn setup() -> Evaluator {
        let (c, db) = presets::paper_cluster();
        Evaluator::new(&benchmarks::linear(), &c, &db).unwrap()
    }

    fn two_high() -> Placement {
        // spout/low/mid on pentium, high x2 on pentium + i3
        let mut p = Placement::empty(4, 3);
        p.x[0][0] = 1;
        p.x[1][0] = 1;
        p.x[2][0] = 1;
        p.x[3][0] = 1;
        p.x[3][1] = 1;
        p
    }

    #[test]
    fn weighted_shares_prefer_fast_machine() {
        let ev = setup();
        let p = two_high();
        let shares = ev.weighted_shares(&p, 3);
        // pentium (e=0.1915) is faster than i3 (e=0.3449) for highCompute
        assert!(shares[0] > shares[1], "{shares:?}");
        assert!((shares[0] + shares[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_equalizes_saturation_when_isolated() {
        // When a component's instances are alone on their machines,
        // speed-proportional shares make both saturate at the same rate,
        // so the weighted max rate beats the even split (whose binding
        // instance is the one on the slower machine).  Probe topology:
        // spout (on the idle i5) -> high, split pentium + i3.
        use crate::topology::builder::TopologyBuilder;
        let (cluster, db) = presets::paper_cluster();
        let top = TopologyBuilder::new("probe")
            .spout("s", "spout", 1.0)
            .bolt("h", "highCompute", 1.0, &["s"])
            .build()
            .unwrap();
        let ev = Evaluator::new(&top, &cluster, &db).unwrap();
        let mut p = Placement::empty(2, 3);
        p.x[0][2] = 1; // spout on i5
        p.x[1][0] = 1; // high on pentium + i3, isolated
        p.x[1][1] = 1;
        let shuffle = ev.max_stable_rate(&p).unwrap();
        let weighted = ev.max_stable_rate_weighted(&p).unwrap();
        assert!(
            weighted > shuffle * 1.2,
            "weighted {weighted} should clearly beat shuffle {shuffle}"
        );
    }

    #[test]
    fn weighted_can_lose_under_colocation() {
        // ...but weighting by speed alone ignores co-located load: the
        // fast machine may already be busy, so weighted is NOT uniformly
        // better — exactly why the paper leaves grouping as future work.
        let ev = setup();
        let p = two_high(); // pentium also hosts spout/low/mid
        let shuffle = ev.max_stable_rate(&p).unwrap();
        let weighted = ev.max_stable_rate_weighted(&p).unwrap();
        assert!(weighted < shuffle, "expected colocation to hurt weighted");
    }

    #[test]
    fn weighted_single_instance_equals_shuffle() {
        // one instance per component: shares are 1.0, modes identical
        let ev = setup();
        let mut p = Placement::empty(4, 3);
        for c in 0..4 {
            p.x[c][c % 3] = 1;
        }
        let a = ev.evaluate(&p, 50.0).unwrap();
        let b = ev.evaluate_weighted(&p, 50.0).unwrap();
        for (x, y) in a.util.iter().zip(&b.util) {
            assert!((x - y).abs() < 1e-9);
        }
        let ra = ev.max_stable_rate(&p).unwrap();
        let rb = ev.max_stable_rate_weighted(&p).unwrap();
        assert!((ra - rb).abs() < 1e-9);
    }

    /// The old implementation recomputed `weighted_shares` inside the
    /// nested `(m, c)` loop; the hoisted `O(C·M)` form must agree with
    /// that reference exactly.
    #[test]
    fn weighted_rate_matches_per_pair_reference() {
        fn reference(ev: &Evaluator, p: &Placement) -> f64 {
            let mut best = f64::INFINITY;
            for m in 0..ev.n_machines() {
                let mut a = 0.0f64;
                let mut b = 0.0f64;
                for c in 0..ev.n_components() {
                    let k = p.x[c][m] as f64;
                    if k > 0.0 {
                        let shares = ev.weighted_shares(p, c);
                        a += ev.e_m[c][m] * ev.gains[c] * shares[m];
                        b += k * ev.met_m[c][m];
                    }
                }
                if b > ev.cap[m] + 1e-9 {
                    return 0.0;
                }
                if a > 0.0 {
                    best = best.min((ev.cap[m] - b) / a);
                }
            }
            best
        }
        let ev = setup();
        let mut rng = crate::util::rng::Rng::new(0x5EED);
        for _ in 0..32 {
            let mut p = Placement::empty(4, 3);
            for c in 0..4 {
                for _ in 0..rng.range(1, 3) {
                    p.x[c][rng.range(0, 2)] += 1;
                }
            }
            let got = ev.max_stable_rate_weighted(&p).unwrap();
            let want = reference(&ev, &p);
            assert!((got - want).abs() < 1e-9, "{got} vs {want} for {p:?}");
        }
    }

    #[test]
    fn weighted_rate_is_boundary() {
        let ev = setup();
        let p = two_high();
        let r = ev.max_stable_rate_weighted(&p).unwrap();
        let at = ev.evaluate_weighted(&p, r).unwrap();
        assert!(at.feasible);
        let above = ev.evaluate_weighted(&p, r * 1.01).unwrap();
        assert!(!above.feasible);
    }
}
