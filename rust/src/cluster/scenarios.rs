//! Table 4's large-scale simulation scenarios (paper §6.3).
//!
//! | scenario | type   | Pentium | Core i3 | Core i5 |
//! |----------|--------|---------|---------|---------|
//! | 1        | small  | 2       | 2       | 2       |
//! | 2        | medium | 10      | 10      | 10      |
//! | 3        | large  | 20      | 70      | 90      |
//!
//! Machine 1/2/3 in Table 4 map to Table 2's Pentium / Core i3 / Core i5
//! worker types.

use super::presets::{paper_profiles, CORE_I3, CORE_I5, PENTIUM};
use super::profile::ProfileDb;
use super::{Cluster, Machine};

/// One Table 4 row.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub id: usize,
    pub label: &'static str,
    pub n_pentium: usize,
    pub n_i3: usize,
    pub n_i5: usize,
}

/// All three Table 4 scenarios.
pub const SCENARIOS: [Scenario; 3] = [
    Scenario { id: 1, label: "small", n_pentium: 2, n_i3: 2, n_i5: 2 },
    Scenario { id: 2, label: "medium", n_pentium: 10, n_i3: 10, n_i5: 10 },
    Scenario { id: 3, label: "large", n_pentium: 20, n_i3: 70, n_i5: 90 },
];

impl Scenario {
    pub fn total_machines(&self) -> usize {
        self.n_pentium + self.n_i3 + self.n_i5
    }

    /// Materialize the cluster (+ the shared profile DB).
    pub fn build(&self) -> (Cluster, ProfileDb) {
        let mut c = Cluster::new(format!("scenario{}-{}", self.id, self.label));
        let p = c.add_type(PENTIUM, "Pentium Dual-Core 2.6 GHz");
        let i3 = c.add_type(CORE_I3, "Intel Core i3 2.9 GHz");
        let i5 = c.add_type(CORE_I5, "Intel Core i5 2.5 GHz");
        c.add_machines(p, self.n_pentium, "pentium");
        c.add_machines(i3, self.n_i3, "i3");
        c.add_machines(i5, self.n_i5, "i5");
        (c, paper_profiles())
    }
}

/// Scenario lookup by id (1-based, as in the paper).
pub fn by_id(id: usize) -> Option<Scenario> {
    SCENARIOS.iter().copied().find(|s| s.id == id)
}

/// Synthetic fleet for the incremental-control-plane harness: machines
/// grouped into racks of `rack_size`, one worker type per rack (the
/// three Table 2 types striped round-robin across racks), named
/// `r{rack}-{slot}` so correlated rack outages can address a whole
/// rack by name prefix.  Shares the paper's profile DB — the fleet is
/// a scaled-out Table 4, not a new hardware model.
pub fn fleet(n_machines: usize, rack_size: usize) -> (Cluster, ProfileDb) {
    let n = n_machines.max(1);
    let rack_size = rack_size.max(1);
    let mut c = Cluster::new(format!("fleet-{n}"));
    let types = [
        c.add_type(PENTIUM, "Pentium Dual-Core 2.6 GHz"),
        c.add_type(CORE_I3, "Intel Core i3 2.9 GHz"),
        c.add_type(CORE_I5, "Intel Core i5 2.5 GHz"),
    ];
    for m in 0..n {
        let rack = m / rack_size;
        let slot = m % rack_size;
        c.machines.push(Machine {
            name: format!("r{rack}-{slot}"),
            type_id: types[rack % types.len()],
            cap: 100.0,
        });
    }
    (c, paper_profiles())
}

/// Names of every machine in rack `rack` of a [`fleet`] cluster
/// (prefix match on `r{rack}-`).
pub fn rack_members(cluster: &Cluster, rack: usize) -> Vec<String> {
    let prefix = format!("r{rack}-");
    cluster
        .machines
        .iter()
        .filter(|m| m.name.starts_with(&prefix))
        .map(|m| m.name.clone())
        .collect()
}

/// Number of racks a [`fleet`] cluster of `n_machines` machines with
/// `rack_size`-machine racks has.
pub fn n_racks(n_machines: usize, rack_size: usize) -> usize {
    let rack_size = rack_size.max(1);
    n_machines.max(1).div_ceil(rack_size)
}

/// One-line summary of the valid scenarios for CLI error messages,
/// e.g. `1=small(6), 2=medium(30), 3=large(180)`.
pub fn describe_all() -> String {
    SCENARIOS
        .iter()
        .map(|s| format!("{}={}({})", s.id, s.label, s.total_machines()))
        .collect::<Vec<_>>()
        .join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_counts() {
        assert_eq!(SCENARIOS[0].total_machines(), 6);
        assert_eq!(SCENARIOS[1].total_machines(), 30);
        assert_eq!(SCENARIOS[2].total_machines(), 180);
    }

    #[test]
    fn build_all() {
        for s in SCENARIOS {
            let (c, db) = s.build();
            c.validate().unwrap();
            assert_eq!(c.n_machines(), s.total_machines());
            assert!(db.get("highCompute", CORE_I5).is_ok());
        }
    }

    #[test]
    fn by_id_lookup() {
        assert_eq!(by_id(3).unwrap().label, "large");
        assert!(by_id(4).is_none());
    }

    #[test]
    fn fleet_builds_racked_clusters() {
        let (c, db) = fleet(1000, 20);
        c.validate().unwrap();
        assert_eq!(c.n_machines(), 1000);
        assert_eq!(n_racks(1000, 20), 50);
        // every rack is full and uniformly typed
        for rack in 0..n_racks(1000, 20) {
            let members = rack_members(&c, rack);
            assert_eq!(members.len(), 20, "rack {rack}");
            let ids: Vec<usize> = c
                .machines
                .iter()
                .filter(|m| members.contains(&m.name))
                .map(|m| m.type_id)
                .collect();
            assert!(ids.windows(2).all(|w| w[0] == w[1]), "rack {rack} mixes types");
        }
        // all three Table 2 types are represented
        assert_eq!(c.types.len(), 3);
        assert!(db.get("highCompute", CORE_I5).is_ok());
        // ragged tail still builds
        let (c2, _) = fleet(55, 20);
        c2.validate().unwrap();
        assert_eq!(rack_members(&c2, 2).len(), 15);
    }

    #[test]
    fn describe_all_lists_every_scenario() {
        let d = describe_all();
        for s in SCENARIOS {
            assert!(d.contains(&format!("{}={}", s.id, s.label)), "{d}");
        }
    }
}
