//! Multi-tenant workloads: many topologies scheduled on one shared
//! cluster.
//!
//! The paper schedules a single application graph, but a production
//! Storm deployment runs many topologies concurrently on shared
//! machines — the setting R-Storm (Peng et al.) and "Scheduling Storms
//! and Streams in the Cloud" (Ghaderi et al.) treat as the real
//! scheduling problem.  A [`Workload`] is an ordered set of named
//! tenants, each a (topology, profiles, rate-weight) triple; a
//! [`WorkloadProblem`] validates all tenants once against one shared
//! [`Cluster`], caching a per-tenant [`Problem`] (each with its own
//! [`Evaluator`](crate::predict::Evaluator) tables, all sharing a single
//! `Arc<Cluster>` — no per-tenant world copies) plus the merged joint
//! problem.
//!
//! ## Rate-weights
//!
//! Tenant rates are coupled proportionally: at workload **scale** `R`,
//! tenant `t` runs at `w_t · R`.  Eq.-5 linearity makes the shared
//! capacity constraint a single closed form —
//! `Σ_t (a_t,m · w_t R + b_t,m) ≤ cap_m` — so the largest feasible
//! scale is again `min_m (cap_m − B_m)/A_m`, and every existing policy
//! maximizes it unmodified on the merged problem.
//!
//! ## Scheduling modes
//!
//! * **Joint** ([`WorkloadProblem::schedule_joint`]) — all tenants
//!   scheduled together.  The workload merges into one disjoint-union
//!   topology (components namespaced `tenant/component`, tenant
//!   rate-weights folded into the spouts' input-rate weights — see
//!   [`crate::topology::Component::weight`]), and any registry policy
//!   maximizes the shared scale under shared eq.-5 machine capacity.
//!   The objective is the weighted sum of per-tenant max stable rates
//!   along the weight direction.  Bounded by the AOT component limit
//!   ([`crate::runtime::dims::MAX_COMPONENTS`]); larger workloads use
//!   incremental admission, which scales per tenant.
//! * **Incremental admission**
//!   ([`WorkloadProblem::schedule_incremental`] /
//!   [`WorkloadProblem::admit`]) — tenants admitted one at a time, each
//!   scheduled against the **residual capacity** residents leave: the
//!   residents' predicted load at their certified rates is reserved
//!   machine by machine
//!   ([`Constraints::reserve_machine_load`](super::Constraints::reserve_machine_load)),
//!   so the kernel's row-table/`DeltaEval` arithmetic certifies
//!   `min_m (cap_m − resident_m − b_m)/a_m` — per-machine intercepts
//!   offset by resident load (see
//!   [`Row::fixed_load`](crate::predict::kernel::Row::fixed_load)).
//!   Residents are never touched: admission is cheap and
//!   migration-free, the online path for "admit tenant at step t".
//! * **Isolated** ([`WorkloadProblem::schedule_isolated`]) — the
//!   no-sharing baseline: machines are partitioned round-robin across
//!   tenants and each tenant is scheduled alone on its partition.  The
//!   `tenancy` experiment compares all three.
//!
//! A one-tenant `Workload` is the degenerate case: joint, incremental
//! and isolated all reduce to exactly the single-tenant [`Problem`]
//! path — same placement, same certified rate (the equivalence suite in
//! `rust/tests/workload_equivalence.rs` pins this).

use std::sync::Arc;

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::Placement;
use crate::topology::{Component, ComponentKind, Topology};
use crate::{Error, Result};

use super::problem::IntoCow;
use super::{Problem, Provenance, Schedule, ScheduleRequest, Scheduler};

/// One tenant: a named (topology, profiles, rate-weight) triple.
#[derive(Clone)]
pub struct TenantSpec {
    /// Unique tenant name (no '/'; it namespaces merged components).
    pub name: String,
    pub topology: Arc<Topology>,
    /// Profile database — tenants typically share one `Arc`.
    pub profiles: Arc<ProfileDb>,
    /// Rate-weight: at workload scale `R` this tenant runs at
    /// `weight · R` tuples/s.
    pub weight: f64,
}

/// An ordered set of named tenants over one shared cluster.
#[derive(Clone, Default)]
pub struct Workload {
    pub name: String,
    pub tenants: Vec<TenantSpec>,
}

impl Workload {
    pub fn new(name: impl Into<String>) -> Self {
        Workload { name: name.into(), tenants: Vec::new() }
    }

    /// Add a tenant (builder style).  The topology moves in; profiles
    /// are shared by `Arc` so M tenants reading one db keep one copy.
    pub fn tenant(
        mut self,
        name: impl Into<String>,
        topology: Topology,
        profiles: Arc<ProfileDb>,
        weight: f64,
    ) -> Self {
        self.tenants.push(TenantSpec {
            name: name.into(),
            topology: Arc::new(topology),
            profiles,
            weight,
        });
        self
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Structural validation: at least one tenant, unique '/'-free
    /// names, finite positive weights.  Topology/profile validation is
    /// per-tenant, at [`WorkloadProblem::new`] time.
    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(Error::Config("workload has no tenants".into()));
        }
        for t in &self.tenants {
            if t.name.is_empty() || t.name.contains('/') {
                return Err(Error::Config(format!(
                    "tenant name '{}' invalid (must be non-empty, without '/')",
                    t.name
                )));
            }
            if !(t.weight.is_finite() && t.weight > 0.0) {
                return Err(Error::Config(format!(
                    "tenant '{}' rate-weight {} must be finite and > 0",
                    t.name, t.weight
                )));
            }
        }
        let mut names: Vec<&str> = self.tenants.iter().map(|t| t.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        if names.len() != self.tenants.len() {
            return Err(Error::Config("duplicate tenant names".into()));
        }
        Ok(())
    }

    /// Verify profile coverage for every tenant in one pass, reporting
    /// **all** missing (tenant, component, machine type) triples at
    /// once.  Tenants sharing a profile db (same `Arc`) are checked as
    /// one group through
    /// [`ProfileDb::check_coverage_many`], so a shared gap is listed
    /// once with every affected tenant named.
    pub fn check_coverage(&self, cluster: &Cluster) -> Result<()> {
        let mut groups: Vec<(&Arc<ProfileDb>, Vec<(&str, &Topology)>)> = Vec::new();
        for t in &self.tenants {
            match groups.iter_mut().find(|(db, _)| Arc::ptr_eq(db, &t.profiles)) {
                Some((_, members)) => members.push((t.name.as_str(), &t.topology)),
                None => groups.push((&t.profiles, vec![(t.name.as_str(), &t.topology)])),
            }
        }
        let mut errors = Vec::new();
        for (db, members) in &groups {
            if let Err(e) = db.check_coverage_many(members, cluster) {
                errors.push(e.to_string());
            }
        }
        if errors.is_empty() {
            Ok(())
        } else {
            Err(Error::Cluster(errors.join("; ")))
        }
    }
}

/// One tenant's validated state inside a [`WorkloadProblem`].
pub struct TenantProblem {
    pub name: String,
    pub weight: f64,
    /// The tenant's own problem (cached evaluator), sharing the
    /// workload's `Arc<Cluster>`.
    pub problem: Problem,
    /// Σ of the tenant's own eq.-6 rate gains (weight excluded): its
    /// throughput per unit of its own input rate.
    pub gain_sum: f64,
}

/// A validated multi-tenant scheduling problem over one shared cluster.
pub struct WorkloadProblem {
    workload: Workload,
    cluster: Arc<Cluster>,
    tenants: Vec<TenantProblem>,
    /// Merged joint problem; `None` when the disjoint union exceeds the
    /// AOT component bound (incremental admission still works).
    merged: Option<Problem>,
    /// Component index ranges per tenant inside the merged topology.
    spans: Vec<std::ops::Range<usize>>,
}

impl WorkloadProblem {
    /// Validate every tenant once against the shared cluster and cache
    /// per-tenant evaluators plus the merged joint problem.
    pub fn new<'a>(workload: Workload, cluster: impl IntoCow<'a, Cluster>) -> Result<Self> {
        Self::with_cluster_arc(workload, Arc::new(cluster.into_cow().into_owned()))
    }

    /// [`new`](Self::new) over an already-shared cluster (no copy) —
    /// what [`subset`](Self::subset) and the workload controller use to
    /// derive problems over the same world.
    pub fn with_cluster_arc(workload: Workload, cluster: Arc<Cluster>) -> Result<Self> {
        workload.validate()?;
        // aggregated coverage first: one error names every missing
        // (tenant, component, machine type) triple
        workload.check_coverage(&cluster)?;

        let mut tenants = Vec::with_capacity(workload.n_tenants());
        let mut spans = Vec::with_capacity(workload.n_tenants());
        let mut next = 0usize;
        for spec in &workload.tenants {
            let problem = Problem::from_shared(
                spec.topology.clone(),
                cluster.clone(),
                spec.profiles.clone(),
            )?;
            let gain_sum = spec.topology.rate_gains()?.iter().sum();
            spans.push(next..next + spec.topology.n_components());
            next += spec.topology.n_components();
            tenants.push(TenantProblem {
                name: spec.name.clone(),
                weight: spec.weight,
                problem,
                gain_sum,
            });
        }

        let merged = if next <= crate::runtime::dims::MAX_COMPONENTS {
            let (top, profiles) = Self::merge(&workload, &cluster)?;
            Some(Problem::from_shared(Arc::new(top), cluster.clone(), Arc::new(profiles))?)
        } else {
            None
        };

        Ok(WorkloadProblem { workload, cluster, tenants, merged, spans })
    }

    /// A derived problem over a subset of this workload's tenants (by
    /// index, in the given order), sharing the same `Arc<Cluster>` —
    /// how the workload controller re-plans the currently-active tenant
    /// set after admissions and drains.
    pub fn subset(&self, idx: &[usize]) -> Result<WorkloadProblem> {
        let mut w = Workload::new(self.workload.name.clone());
        for &i in idx {
            let spec = self.workload.tenants.get(i).ok_or_else(|| {
                Error::Schedule(format!("subset index {i} out of range"))
            })?;
            w.tenants.push(spec.clone());
        }
        Self::with_cluster_arc(w, self.cluster.clone())
    }

    /// Disjoint-union topology + namespaced profile db for the joint
    /// path.  Components become `tenant/component`, task types
    /// `tenant/task_type` (so tenants with conflicting profile rows
    /// cannot collide), and each tenant's spouts carry
    /// `spout.weight · tenant.weight` as their input-rate weight — one
    /// shared `R0` then drives tenant `t` at `w_t · R0`.
    fn merge(workload: &Workload, cluster: &Cluster) -> Result<(Topology, ProfileDb)> {
        let mut components = Vec::new();
        let mut edges = Vec::new();
        let mut profiles = ProfileDb::new();
        let mut base = 0usize;
        for spec in &workload.tenants {
            for c in &spec.topology.components {
                components.push(Component {
                    name: format!("{}/{}", spec.name, c.name),
                    kind: c.kind,
                    task_type: format!("{}/{}", spec.name, c.task_type),
                    alpha: c.alpha,
                    weight: if c.kind == ComponentKind::Spout {
                        c.weight * spec.weight
                    } else {
                        c.weight
                    },
                });
                for t in &cluster.types {
                    let p = spec.profiles.get(&c.task_type, &t.name)?;
                    profiles.insert(&format!("{}/{}", spec.name, c.task_type), &t.name, p);
                }
            }
            for &(a, b) in &spec.topology.edges {
                edges.push((base + a, base + b));
            }
            base += spec.topology.n_components();
        }
        let top = Topology { name: workload.name.clone(), components, edges };
        top.validate()?;
        Ok((top, profiles))
    }

    pub fn workload(&self) -> &Workload {
        &self.workload
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// The shared cluster's `Arc`, for building further problems over
    /// the same world without copies.
    pub fn cluster_arc(&self) -> &Arc<Cluster> {
        &self.cluster
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    pub fn tenants(&self) -> &[TenantProblem] {
        &self.tenants
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantProblem> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// The merged joint problem (errors when the disjoint union exceeds
    /// the AOT component bound — use incremental admission there).
    pub fn merged(&self) -> Result<&Problem> {
        self.merged.as_ref().ok_or_else(|| {
            Error::Schedule(format!(
                "workload '{}' has {} merged components, above the joint-mode bound of {}; \
                 schedule it with incremental admission instead",
                self.workload.name,
                self.spans.last().map_or(0, |s| s.end),
                crate::runtime::dims::MAX_COMPONENTS
            ))
        })
    }

    /// Component index range of tenant `i` inside the merged topology.
    pub fn tenant_span(&self, i: usize) -> std::ops::Range<usize> {
        self.spans[i].clone()
    }

    /// `(tenant name, merged component indices)` per tenant — the
    /// grouping the event simulator reports per-tenant stats by.
    pub fn event_groups(&self) -> Vec<(String, Vec<usize>)> {
        self.tenants
            .iter()
            .zip(&self.spans)
            .map(|(t, span)| (t.name.clone(), span.clone().collect()))
            .collect()
    }

    /// Slice a merged placement into per-tenant placements.
    pub fn split_placement(&self, merged: &Placement) -> Vec<Placement> {
        self.spans
            .iter()
            .map(|span| Placement { x: merged.x[span.clone()].to_vec() })
            .collect()
    }

    /// Concatenate per-tenant placements back into merged component
    /// order (tenants must appear in workload order).
    pub fn merged_placement(&self, ws: &WorkloadSchedule) -> Placement {
        let mut x = Vec::with_capacity(self.spans.last().map_or(0, |s| s.end));
        for ts in &ws.tenants {
            x.extend(ts.schedule.placement.x.iter().cloned());
        }
        Placement { x }
    }

    /// The shared residual-capacity view: per-machine utilization the
    /// given resident schedules occupy at their certified rates (what
    /// [`admit`](Self::admit) reserves before scheduling a new tenant).
    pub fn residual_load(&self, residents: &[TenantSchedule]) -> Result<Vec<f64>> {
        let mut load = vec![0.0f64; self.cluster.n_machines()];
        for r in residents {
            let tp = self.tenant(&r.tenant).ok_or_else(|| {
                Error::Schedule(format!("resident '{}' is not in this workload", r.tenant))
            })?;
            let eval = tp.problem.evaluator().evaluate(&r.schedule.placement, r.schedule.rate)?;
            for (m, u) in eval.util.iter().enumerate() {
                load[m] += u;
            }
        }
        Ok(load)
    }

    /// Combined per-machine predicted utilization of a set of tenant
    /// schedules at their certified rates.
    pub fn combined_util(&self, tenants: &[TenantSchedule]) -> Result<Vec<f64>> {
        self.residual_load(tenants)
    }

    /// Schedule all tenants together on the merged problem: one policy
    /// run maximizes the shared scale, then the placement splits back
    /// into per-tenant schedules (tenant `t` certified at
    /// `w_t · scale`, evaluated through its own cached evaluator).
    ///
    /// Request constraints resolve against the **merged** namespace:
    /// machines keep their names, components are `tenant/component`.
    pub fn schedule_joint(
        &self,
        policy: &dyn Scheduler,
        req: &ScheduleRequest,
    ) -> Result<WorkloadSchedule> {
        let merged = self.merged()?;
        let s = policy.schedule(merged, req)?;
        let scale = s.rate;
        let parts = self.split_placement(&s.placement);
        let mut tenants = Vec::with_capacity(self.tenants.len());
        for (tp, placement) in self.tenants.iter().zip(parts) {
            let rate = tp.weight * scale;
            let eval = tp.problem.evaluator().evaluate(&placement, rate)?;
            tenants.push(TenantSchedule {
                tenant: tp.name.clone(),
                weight: tp.weight,
                schedule: Schedule { placement, rate, eval, provenance: s.provenance.clone() },
            });
        }
        self.finish(TenancyMode::Joint, tenants, s.provenance)
    }

    /// Admit tenant `idx` against the residual capacity the given
    /// residents leave: their load at certified rates is reserved
    /// machine by machine and the tenant is scheduled alone on what
    /// remains.  Residents are not touched — no migration, the online
    /// admission path.  Errors when the residual cannot host the tenant
    /// at all (admission denied).
    pub fn admit(
        &self,
        residents: &[TenantSchedule],
        idx: usize,
        policy: &dyn Scheduler,
        req: &ScheduleRequest,
    ) -> Result<TenantSchedule> {
        let tp = self.tenants.get(idx).ok_or_else(|| {
            Error::Schedule(format!("tenant index {idx} out of range"))
        })?;
        let load = self.residual_load(residents)?;
        let mut constraints = req.constraints.clone();
        for (m, l) in load.iter().enumerate() {
            if *l > 1e-12 {
                let name = &self.cluster.machines[m].name;
                constraints = constraints.reserve_machine_load(name, *l);
            }
        }
        let tenant_req = req.clone().with_constraints(constraints);
        let s = policy.schedule(&tp.problem, &tenant_req).map_err(|e| {
            Error::Schedule(format!(
                "admission denied for tenant '{}' against the residual capacity: {e}",
                tp.name
            ))
        })?;
        Ok(TenantSchedule { tenant: tp.name.clone(), weight: tp.weight, schedule: s })
    }

    /// Schedule tenants one at a time in workload order, each admitted
    /// against the residual capacity of those before it (greedy,
    /// order-dependent; each tenant certifies its own residual max
    /// rate).  A tenant the residual cannot host at all is **denied**:
    /// it stays out (rate 0, empty placement) and is listed in
    /// [`WorkloadSchedule::denied`] — the rest of the workload still
    /// schedules.  A one-tenant workload reduces exactly to the
    /// single-tenant [`Problem`] path.
    pub fn schedule_incremental(
        &self,
        policy: &dyn Scheduler,
        req: &ScheduleRequest,
    ) -> Result<WorkloadSchedule> {
        // Surface configuration errors (unknown machine/component names
        // in the request's constraints) loudly up front — the per-tenant
        // loop below deliberately swallows scheduling failures as
        // capacity denials, and a typo must not masquerade as one.
        for tp in &self.tenants {
            tp.problem.resolve(&req.constraints)?;
        }
        let mut admitted: Vec<TenantSchedule> = Vec::with_capacity(self.tenants.len());
        let mut denied = Vec::new();
        let mut provenance = Provenance::default();
        for idx in 0..self.tenants.len() {
            match self.admit(&admitted, idx, policy, req) {
                Ok(ts) => {
                    provenance.absorb(&ts.schedule.provenance);
                    admitted.push(ts);
                }
                Err(_) => {
                    let tp = &self.tenants[idx];
                    let placement = Placement::empty(
                        tp.problem.topology().n_components(),
                        self.cluster.n_machines(),
                    );
                    let eval = tp.problem.evaluator().evaluate(&placement, 0.0)?;
                    denied.push(tp.name.clone());
                    admitted.push(TenantSchedule {
                        tenant: tp.name.clone(),
                        weight: tp.weight,
                        schedule: Schedule {
                            placement,
                            rate: 0.0,
                            eval,
                            provenance: Provenance::default(),
                        },
                    });
                }
            }
        }
        let mut ws = self.finish(TenancyMode::Incremental, admitted, provenance)?;
        ws.denied = denied;
        Ok(ws)
    }

    /// The no-sharing baseline: machines partitioned round-robin across
    /// tenants (tenant `i` owns machines `m` with `m % K == i`), each
    /// tenant scheduled alone on its slice.  Errors when there are
    /// fewer machines than tenants.
    pub fn schedule_isolated(
        &self,
        policy: &dyn Scheduler,
        req: &ScheduleRequest,
    ) -> Result<WorkloadSchedule> {
        let k = self.tenants.len();
        let n_m = self.cluster.n_machines();
        if n_m < k {
            return Err(Error::Schedule(format!(
                "isolated mode needs >= 1 machine per tenant ({k} tenants, {n_m} machines)"
            )));
        }
        let mut out = Vec::with_capacity(k);
        let mut provenance = Provenance::default();
        for (i, tp) in self.tenants.iter().enumerate() {
            let foreign: Vec<String> = self
                .cluster
                .machines
                .iter()
                .enumerate()
                .filter(|(m, _)| (k > 1) && (m % k != i))
                .map(|(_, mach)| mach.name.clone())
                .collect();
            let constraints = req.constraints.clone().exclude_machines(foreign);
            let s = policy.schedule(&tp.problem, &req.clone().with_constraints(constraints))?;
            provenance.absorb(&s.provenance);
            out.push(TenantSchedule { tenant: tp.name.clone(), weight: tp.weight, schedule: s });
        }
        self.finish(TenancyMode::Isolated, out, provenance)
    }

    /// Assemble the workload-level schedule: scale, combined predicted
    /// utilization and feasibility at the certified rates.
    fn finish(
        &self,
        mode: TenancyMode,
        tenants: Vec<TenantSchedule>,
        provenance: Provenance,
    ) -> Result<WorkloadSchedule> {
        let scale = tenants
            .iter()
            .map(|t| t.schedule.rate / t.weight)
            .fold(f64::INFINITY, f64::min)
            .max(0.0);
        let scale = if scale.is_finite() { scale } else { 0.0 };
        // each tenant's eval already holds its per-machine utilization
        // at its certified rate; summing the cached vectors avoids a
        // redundant O(T·C·M) re-evaluation per mode
        let mut util = vec![0.0f64; self.cluster.n_machines()];
        for t in &tenants {
            for (m, u) in t.schedule.eval.util.iter().enumerate() {
                util[m] += u;
            }
        }
        let over = util
            .iter()
            .zip(self.cluster.machines.iter())
            .any(|(u, m)| *u > m.cap + 1e-6);
        let feasible = !over && tenants.iter().all(|t| t.schedule.eval.feasible);
        let gain: f64 = self.tenants.iter().map(|t| t.weight * t.gain_sum).sum();
        let weighted_throughput = scale * gain;
        Ok(WorkloadSchedule {
            mode,
            scale,
            weighted_throughput,
            tenants,
            util,
            feasible,
            denied: Vec::new(),
            provenance,
        })
    }
}

/// Which multi-tenant scheduling mode produced a [`WorkloadSchedule`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenancyMode {
    Joint,
    Incremental,
    Isolated,
}

impl TenancyMode {
    pub const ALL: [TenancyMode; 3] =
        [TenancyMode::Joint, TenancyMode::Incremental, TenancyMode::Isolated];

    pub fn name(&self) -> &'static str {
        match self {
            TenancyMode::Joint => "joint",
            TenancyMode::Incremental => "incremental",
            TenancyMode::Isolated => "isolated",
        }
    }

    pub fn by_name(name: &str) -> Option<TenancyMode> {
        TenancyMode::ALL.iter().copied().find(|m| m.name() == name)
    }
}

/// One tenant's slice of a workload schedule: its placement on the
/// shared cluster and its certified rate (`schedule.rate` is the
/// tenant's own input rate, tuples/s).
#[derive(Debug, Clone)]
pub struct TenantSchedule {
    pub tenant: String,
    pub weight: f64,
    pub schedule: Schedule,
}

/// All tenants' placements on the shared cluster, plus workload-level
/// aggregates.
#[derive(Debug, Clone)]
pub struct WorkloadSchedule {
    pub mode: TenancyMode,
    /// Workload scale: the largest `R` with every tenant certified at
    /// `>= w_t · R` (for joint mode, exactly the merged certified
    /// rate).  0 when some tenant was denied any rate.
    pub scale: f64,
    /// Throughput the workload delivers at proportional rates
    /// `w_t · scale`: `scale · Σ_t w_t · gain_sum_t` — the headline the
    /// `tenancy` experiment compares across modes.
    pub weighted_throughput: f64,
    pub tenants: Vec<TenantSchedule>,
    /// Combined predicted per-machine utilization at the certified
    /// rates, percent.
    pub util: Vec<f64>,
    /// No shared machine over budget and every tenant's own evaluation
    /// feasible (a denied tenant's empty placement makes this false).
    pub feasible: bool,
    /// Tenants incremental admission could not host at all (rate 0,
    /// empty placement); always empty for joint/isolated.
    pub denied: Vec<String>,
    /// Aggregated provenance (joint: the merged search; incremental /
    /// isolated: per-tenant runs summed).
    pub provenance: Provenance,
}

impl WorkloadSchedule {
    pub fn tenant(&self, name: &str) -> Option<&TenantSchedule> {
        self.tenants.iter().find(|t| t.tenant == name)
    }

    /// Machines hosting at least one instance of any tenant.
    pub fn machines_used(&self) -> usize {
        let n_m = self.util.len();
        (0..n_m)
            .filter(|&m| self.tenants.iter().any(|t| t.schedule.placement.tasks_on(m) > 0))
            .count()
    }

    /// Σ of tenants' predicted throughput at their certified rates
    /// (unlike [`weighted_throughput`](Self::weighted_throughput) this
    /// credits incremental admission's uneven rates).
    pub fn total_throughput(&self) -> f64 {
        self.tenants.iter().map(|t| t.schedule.eval.throughput).sum()
    }

    /// Render per-tenant assignments for CLI output.
    pub fn describe(&self, wp: &WorkloadProblem) -> String {
        let mut out = String::new();
        for ts in &self.tenants {
            out.push_str(&format!(
                "tenant '{}' (weight {:.2}): rate {:.1} tuple/s, throughput {:.1} tuple/s\n",
                ts.tenant, ts.weight, ts.schedule.rate, ts.schedule.eval.throughput
            ));
            // a schedule rendered against a foreign problem (tenant not
            // in `wp`) degrades to the summary row instead of panicking
            match wp.tenant(&ts.tenant) {
                Some(tp) => {
                    out.push_str(&ts.schedule.describe(tp.problem.topology(), wp.cluster()))
                }
                None => out.push_str("  (tenant not present in this workload problem)\n"),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::{registry, PolicyParams};
    use crate::topology::benchmarks;

    fn shared_db() -> (Cluster, Arc<ProfileDb>) {
        let (cluster, db) = presets::paper_cluster();
        (cluster, Arc::new(db))
    }

    fn hetero() -> Box<dyn Scheduler> {
        registry::create("hetero", &PolicyParams::default()).unwrap()
    }

    fn duo() -> WorkloadProblem {
        let (cluster, db) = shared_db();
        let w = Workload::new("duo")
            .tenant("search", benchmarks::linear(), db.clone(), 1.0)
            .tenant("ads", benchmarks::rolling_count(), db.clone(), 1.0);
        WorkloadProblem::new(w, &cluster).unwrap()
    }

    #[test]
    fn validate_rejects_bad_workloads() {
        let (_, db) = shared_db();
        assert!(Workload::new("empty").validate().is_err());
        let dup = Workload::new("dup")
            .tenant("a", benchmarks::linear(), db.clone(), 1.0)
            .tenant("a", benchmarks::star(), db.clone(), 1.0);
        assert!(dup.validate().is_err());
        let slash = Workload::new("s").tenant("a/b", benchmarks::linear(), db.clone(), 1.0);
        assert!(slash.validate().is_err());
        let w0 = Workload::new("w").tenant("a", benchmarks::linear(), db.clone(), 0.0);
        assert!(w0.validate().is_err());
    }

    #[test]
    fn tenant_problems_share_one_cluster() {
        let wp = duo();
        let a = wp.tenants()[0].problem.cluster();
        let b = wp.tenants()[1].problem.cluster();
        assert!(std::ptr::eq(a, b), "tenants must share one cluster copy");
        assert!(std::ptr::eq(a, wp.cluster()));
    }

    #[test]
    fn merged_topology_namespaces_tenants() {
        let wp = duo();
        let merged = wp.merged().unwrap();
        assert_eq!(merged.topology().n_components(), 4 + 3);
        assert!(merged
            .topology()
            .components
            .iter()
            .any(|c| c.name == "search/spout" && c.task_type == "search/spout"));
        assert!(merged.topology().components.iter().any(|c| c.name == "ads/split"));
        assert_eq!(wp.tenant_span(0), 0..4);
        assert_eq!(wp.tenant_span(1), 4..7);
        // merged gains mirror each tenant's own gains (weights 1)
        let g = merged.topology().rate_gains().unwrap();
        let ga = benchmarks::linear().rate_gains().unwrap();
        let gb = benchmarks::rolling_count().rate_gains().unwrap();
        for (i, want) in ga.iter().chain(gb.iter()).enumerate() {
            assert!((g[i] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn joint_shares_capacity_and_splits_back() {
        let wp = duo();
        let ws = wp.schedule_joint(hetero().as_ref(), &ScheduleRequest::max_throughput()).unwrap();
        assert_eq!(ws.mode, TenancyMode::Joint);
        assert!(ws.scale > 0.0);
        assert!(ws.feasible, "joint schedule must be feasible at certified rates");
        for ts in &ws.tenants {
            assert!((ts.schedule.rate - ts.weight * ws.scale).abs() < 1e-9);
            assert!(ts.schedule.eval.feasible);
        }
        // combined predicted utilization within every machine budget
        for (m, u) in ws.util.iter().enumerate() {
            assert!(*u <= wp.cluster().machines[m].cap + 1e-6, "machine {m} at {u}%");
        }
        // the split placements concatenate back to the merged placement
        let merged = wp.merged_placement(&ws);
        assert_eq!(merged.n_components(), 7);
        assert_eq!(
            merged.total_tasks(),
            ws.tenants.iter().map(|t| t.schedule.placement.total_tasks()).sum::<usize>()
        );
        assert!(ws.weighted_throughput > 0.0);
    }

    #[test]
    fn heavier_weight_shifts_rates_toward_the_tenant() {
        let (cluster, db) = shared_db();
        let even = WorkloadProblem::new(
            Workload::new("even")
                .tenant("a", benchmarks::linear(), db.clone(), 1.0)
                .tenant("b", benchmarks::unique_visitor(), db.clone(), 1.0),
            &cluster,
        )
        .unwrap();
        let skew = WorkloadProblem::new(
            Workload::new("skew")
                .tenant("a", benchmarks::linear(), db.clone(), 1.0)
                .tenant("b", benchmarks::unique_visitor(), db.clone(), 3.0),
            &cluster,
        )
        .unwrap();
        let req = ScheduleRequest::max_throughput();
        let e = even.schedule_joint(hetero().as_ref(), &req).unwrap();
        let s = skew.schedule_joint(hetero().as_ref(), &req).unwrap();
        // b's rate relative to a's triples under the 3x weight
        let ratio_even = e.tenants[1].schedule.rate / e.tenants[0].schedule.rate;
        let ratio_skew = s.tenants[1].schedule.rate / s.tenants[0].schedule.rate;
        assert!((ratio_even - 1.0).abs() < 1e-9);
        assert!((ratio_skew - 3.0).abs() < 1e-9);
        // and the shared scale pays for it
        assert!(s.scale < e.scale, "3x tenant b must lower the shared scale");
    }

    #[test]
    fn incremental_never_touches_residents() {
        let wp = duo();
        let policy = hetero();
        let req = ScheduleRequest::max_throughput();
        let solo =
            policy.schedule(&wp.tenants()[0].problem, &req).expect("tenant 0 solo schedule");
        let ws = wp.schedule_incremental(policy.as_ref(), &req).unwrap();
        assert_eq!(ws.mode, TenancyMode::Incremental);
        // tenant 0 is scheduled exactly as if alone (no residents yet)
        assert_eq!(ws.tenants[0].schedule.placement, solo.placement);
        assert!((ws.tenants[0].schedule.rate - solo.rate).abs() < 1e-9);
        // whatever was admitted fits in the residual: combined within caps
        for (m, u) in ws.util.iter().enumerate() {
            assert!(*u <= wp.cluster().machines[m].cap + 1e-6, "machine {m} at {u}%");
        }
        for ts in &ws.tenants {
            if ts.schedule.rate > 0.0 {
                assert!(ts.schedule.eval.feasible, "admitted tenant '{}' infeasible", ts.tenant);
            }
        }
    }

    #[test]
    fn admission_to_a_full_cluster_is_denied_cleanly() {
        let (cluster, db) = {
            let (c, db) = presets::homogeneous_cluster(1);
            (c, Arc::new(db))
        };
        let w = Workload::new("overfull")
            .tenant("a", benchmarks::linear(), db.clone(), 1.0)
            .tenant("b", benchmarks::linear(), db.clone(), 1.0);
        let wp = WorkloadProblem::new(w, &cluster).unwrap();
        let req = ScheduleRequest::max_throughput();
        // the explicit admission API reports the denial as an error...
        let first = wp.admit(&[], 0, hetero().as_ref(), &req).unwrap();
        let err =
            wp.admit(&[first], 1, hetero().as_ref(), &req).unwrap_err().to_string();
        assert!(err.contains("admission denied"), "{err}");
        assert!(err.contains("'b'"), "{err}");
        // ...while the batch path keeps the rest of the workload and
        // lists the denied tenant at rate 0
        let ws = wp.schedule_incremental(hetero().as_ref(), &req).unwrap();
        assert_eq!(ws.denied, vec!["b".to_string()]);
        assert_eq!(ws.tenants[1].schedule.rate, 0.0);
        assert_eq!(ws.tenants[1].schedule.placement.total_tasks(), 0);
        assert!(ws.tenants[0].schedule.rate > 0.0);
        assert_eq!(ws.scale, 0.0);
        assert!(!ws.feasible);
    }

    #[test]
    fn isolated_partitions_machines() {
        let wp = duo();
        let ws =
            wp.schedule_isolated(hetero().as_ref(), &ScheduleRequest::max_throughput()).unwrap();
        assert_eq!(ws.mode, TenancyMode::Isolated);
        // tenant i only uses machines m with m % 2 == i
        for (i, ts) in ws.tenants.iter().enumerate() {
            for m in 0..wp.cluster().n_machines() {
                if m % 2 != i {
                    assert_eq!(
                        ts.schedule.placement.tasks_on(m),
                        0,
                        "tenant {i} leaked onto foreign machine {m}"
                    );
                }
            }
        }
        // more tenants than machines is rejected
        let (cluster, db) = {
            let (c, db) = presets::homogeneous_cluster(1);
            (c, Arc::new(db))
        };
        let w = Workload::new("crowded")
            .tenant("a", benchmarks::linear(), db.clone(), 1.0)
            .tenant("b", benchmarks::linear(), db.clone(), 1.0);
        let wp = WorkloadProblem::new(w, &cluster).unwrap();
        assert!(wp
            .schedule_isolated(hetero().as_ref(), &ScheduleRequest::max_throughput())
            .is_err());
    }

    #[test]
    fn joint_beats_isolated_on_the_paper_cluster() {
        // statistical multiplexing: sharing all three heterogeneous
        // machines must beat a hard 2/1 partition on weighted throughput
        let wp = duo();
        let req = ScheduleRequest::max_throughput();
        let joint = wp.schedule_joint(hetero().as_ref(), &req).unwrap();
        let isolated = wp.schedule_isolated(hetero().as_ref(), &req).unwrap();
        assert!(
            joint.weighted_throughput >= isolated.weighted_throughput * (1.0 - 1e-9),
            "joint {} < isolated {}",
            joint.weighted_throughput,
            isolated.weighted_throughput
        );
    }

    #[test]
    fn oversized_workload_reports_joint_bound_but_keeps_tenant_problems() {
        let (cluster, db) = shared_db();
        let mut w = Workload::new("big");
        for i in 0..5 {
            w = w.tenant(format!("t{i}"), benchmarks::diamond(), db.clone(), 1.0);
        }
        // 5 x 5 = 25 components > MAX_COMPONENTS
        let wp = WorkloadProblem::new(w, &cluster).unwrap();
        let err = wp.merged().unwrap_err().to_string();
        assert!(err.contains("incremental"), "{err}");
        assert_eq!(wp.n_tenants(), 5);
        assert!(wp.tenants().iter().all(|t| t.problem.evaluator().n_components() == 5));
    }

    #[test]
    fn coverage_error_names_tenant_triples() {
        let (cluster, _) = presets::paper_cluster();
        let mut db = ProfileDb::new();
        // cover only the spout type
        for mt in ["pentium", "core-i3", "core-i5"] {
            db.insert(
                "spout",
                mt,
                crate::cluster::profile::TaskProfile { e: 0.004, met: 1.0 },
            );
        }
        let db = Arc::new(db);
        let w = Workload::new("gappy")
            .tenant("search", benchmarks::linear(), db.clone(), 1.0)
            .tenant("ads", benchmarks::linear(), db.clone(), 1.0);
        let err = WorkloadProblem::new(w, &cluster).unwrap_err().to_string();
        assert!(err.contains("search/"), "{err}");
        assert!(err.contains("ads/"), "{err}");
        assert!(err.contains("tenant, component, machine type"), "{err}");
    }

    #[test]
    fn event_groups_cover_all_components() {
        let wp = duo();
        let groups = wp.event_groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].1, vec![0, 1, 2, 3]);
        assert_eq!(groups[1].1, vec![4, 5, 6]);
    }
}
