//! Failure/drain rescheduling (paper §4.2 & §8): "in case of machine
//! failure, a slow scheduler leads the cluster to tuple overloading
//! state... during the execution, by any change in the cluster state
//! this algorithm can be used to recalculate the new number of instances
//! and their suitable assignment."
//!
//! Losing (or draining) a machine is just a scheduling request with that
//! machine excluded: [`after_failure`] issues
//! `Objective::MaxThroughput` + `Constraints::exclude_machine` on the
//! *same* [`Problem`] — no cluster surgery, no profile re-expansion —
//! and returns a schedule of unchanged shape with zero tasks on the dead
//! machine.  The whole point is that this finishes in
//! microseconds-to-milliseconds (see `benches/scheduler_micro.rs`),
//! where the exhaustive comparator would strand the cluster for hours.

use super::{Constraints, Problem, Schedule, ScheduleRequest, Scheduler};
use crate::{Error, Result};

/// Outcome of a failure-rescheduling step.
#[derive(Debug, Clone)]
pub struct Reschedule {
    /// The recomputed schedule: same (component × machine) shape as the
    /// problem, zero tasks on every excluded machine.
    pub schedule: Schedule,
    /// Machines excluded from the new schedule.
    pub excluded: Vec<String>,
    /// Throughput retained vs the pre-failure schedule (1.0 = all).
    pub retained: f64,
}

/// Reschedule around one failed/drained machine.
pub fn after_failure(
    problem: &Problem,
    before: &Schedule,
    failed: &str,
    policy: &dyn Scheduler,
) -> Result<Reschedule> {
    after_failures(problem, before, &[failed], policy)
}

/// Reschedule around any number of failed/drained machines.
pub fn after_failures(
    problem: &Problem,
    before: &Schedule,
    failed: &[&str],
    policy: &dyn Scheduler,
) -> Result<Reschedule> {
    if failed.is_empty() {
        return Err(Error::Cluster("no machine named to reschedule around".into()));
    }
    if failed.len() >= problem.cluster().n_machines() {
        return Err(Error::Cluster("cannot lose every worker".into()));
    }
    let req = ScheduleRequest::max_throughput()
        .with_constraints(Constraints::new().exclude_machines(failed.iter().copied()))
        // search policies resume from the pre-failure placement (repaired
        // off the dead machines); heuristics ignore the warm start
        .with_warm_start(before.placement.clone());
    // unknown machine names are rejected by constraint resolution
    let schedule = policy.schedule(problem, &req)?;
    let retained = if before.eval.throughput > 0.0 {
        schedule.eval.throughput / before.eval.throughput
    } else {
        1.0
    };
    Ok(Reschedule {
        schedule,
        excluded: failed.iter().map(|s| s.to_string()).collect(),
        retained,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::hetero::HeteroScheduler;
    use crate::topology::benchmarks;

    fn setup() -> (Problem, Schedule, HeteroScheduler) {
        let (cluster, db) = presets::paper_cluster();
        let problem = Problem::new(&benchmarks::linear(), &cluster, &db).unwrap();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        (problem, before, hs)
    }

    #[test]
    fn excluded_machine_hosts_zero_tasks() {
        let (problem, before, hs) = setup();
        let idx = problem.cluster().machines.iter().position(|m| m.name == "i3-0").unwrap();
        let r = after_failure(&problem, &before, "i3-0", &hs).unwrap();
        // shape unchanged, dead machine empty
        assert_eq!(r.schedule.placement.n_machines(), problem.cluster().n_machines());
        assert_eq!(r.schedule.placement.tasks_on(idx), 0);
        assert_eq!(r.excluded, vec!["i3-0"]);
    }

    #[test]
    fn reschedule_is_feasible_at_a_lower_rate() {
        let (problem, before, hs) = setup();
        let r = after_failure(&problem, &before, "i3-0", &hs).unwrap();
        assert!(r.schedule.eval.feasible);
        assert!(r.schedule.rate > 0.0);
        // losing a worker cannot raise the certified rate
        assert!(
            r.schedule.rate <= before.rate + 1e-9,
            "post-failure rate {} exceeds pre-failure rate {}",
            r.schedule.rate,
            before.rate
        );
        // losing 1 of 3 workers keeps a meaningful share of throughput
        assert!(r.retained > 0.3, "retained only {:.2}", r.retained);
        assert!(r.retained < 1.0, "throughput should drop after failure");
    }

    #[test]
    fn losing_the_strongest_costs_more() {
        let (problem, before, hs) = setup();
        // Table 3 makes the Pentium the per-tuple fastest worker here
        let lose_fast = after_failure(&problem, &before, "pentium-0", &hs).unwrap();
        let lose_slow = after_failure(&problem, &before, "i3-0", &hs).unwrap();
        assert!(
            lose_fast.retained <= lose_slow.retained + 1e-9,
            "losing the fast worker ({}) should cost >= losing the slow one ({})",
            lose_fast.retained,
            lose_slow.retained
        );
    }

    #[test]
    fn unknown_machine_rejected() {
        let (problem, before, hs) = setup();
        assert!(after_failure(&problem, &before, "ghost", &hs).is_err());
    }

    #[test]
    fn cannot_lose_last_worker() {
        let (cluster, db) = presets::homogeneous_cluster(1);
        let problem = Problem::new(&benchmarks::linear(), &cluster, &db).unwrap();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        let name = cluster.machines[0].name.clone();
        assert!(after_failure(&problem, &before, &name, &hs).is_err());
    }

    #[test]
    fn two_simultaneous_failures_empty_both_machines() {
        use crate::cluster::scenarios;
        let (cluster, db) = scenarios::by_id(1).unwrap().build();
        let problem = Problem::new(&benchmarks::linear(), &cluster, &db).unwrap();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        let dead = ["pentium-0", "i5-1"];
        let r = after_failures(&problem, &before, &dead, &hs).unwrap();
        assert!(r.schedule.eval.feasible);
        assert!(r.schedule.rate > 0.0);
        assert!(r.schedule.rate <= before.rate + 1e-9);
        assert_eq!(r.schedule.placement.n_machines(), cluster.n_machines());
        for name in dead {
            let idx = cluster.machines.iter().position(|m| m.name == name).unwrap();
            assert_eq!(r.schedule.placement.tasks_on(idx), 0, "{name} still hosts tasks");
        }
        assert_eq!(r.excluded, dead.to_vec());
    }

    /// Killing two machines composes with multi-tenant exclusion: the
    /// failure request on the merged workload problem keeps **every**
    /// tenant's slice off both dead machines while every tenant keeps
    /// at least one instance per component.
    #[test]
    fn two_failures_compose_with_workload_tenants() {
        use crate::cluster::scenarios;
        use crate::scheduler::workload::{Workload, WorkloadProblem};
        use std::sync::Arc;

        let (cluster, db) = scenarios::by_id(1).unwrap().build();
        let db = Arc::new(db);
        let w = Workload::new("duo")
            .tenant("search", benchmarks::linear(), db.clone(), 1.0)
            .tenant("ads", benchmarks::rolling_count(), db.clone(), 1.0);
        let wp = WorkloadProblem::new(w, &cluster).unwrap();
        let merged = wp.merged().unwrap();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(merged, &ScheduleRequest::max_throughput()).unwrap();
        let dead = ["pentium-1", "i3-0"];
        let r = after_failures(merged, &before, &dead, &hs).unwrap();
        assert!(r.schedule.eval.feasible);
        let dead_idx: Vec<usize> = dead
            .iter()
            .map(|n| cluster.machines.iter().position(|m| &m.name == n).unwrap())
            .collect();
        for (t, part) in wp.split_placement(&r.schedule.placement).iter().enumerate() {
            for &m in &dead_idx {
                assert_eq!(part.tasks_on(m), 0, "tenant {t} still on dead machine {m}");
            }
            for c in 0..part.n_components() {
                assert!(part.count(c) >= 1, "tenant {t} lost component {c}");
            }
        }
    }

    #[test]
    fn cascading_failures_stay_feasible() {
        // exclude machines one by one in a Table-4 small scenario; every
        // intermediate schedule must stay feasible with the excluded
        // machines empty
        use crate::cluster::scenarios;
        let (cluster, db) = scenarios::by_id(1).unwrap().build();
        let top = benchmarks::diamond();
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        let hs = HeteroScheduler::default();
        let mut schedule = hs.schedule(&problem, &ScheduleRequest::max_throughput()).unwrap();
        let mut gone: Vec<String> = Vec::new();
        for k in 0..3 {
            gone.push(cluster.machines[k].name.clone());
            let names: Vec<&str> = gone.iter().map(|s| s.as_str()).collect();
            let r = after_failures(&problem, &schedule, &names, &hs).unwrap();
            assert!(r.schedule.eval.feasible);
            for name in &gone {
                let idx =
                    cluster.machines.iter().position(|m| &m.name == name).unwrap();
                assert_eq!(r.schedule.placement.tasks_on(idx), 0, "{name} still hosts tasks");
            }
            schedule = r.schedule;
        }
    }
}
