//! Schedulers (paper §5 + §6 comparators) behind one request API.
//!
//! The unit of work is a [`Problem`] (topology + cluster + profiles,
//! validated once, owning the cached [`Evaluator`] tables and an
//! optional PJRT scorer) scheduled under a [`ScheduleRequest`]
//! (an [`Objective`] plus [`Constraints`]).  Policies implement
//!
//! ```ignore
//! fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule>
//! ```
//!
//! and are constructed by name through [`registry`] — the single place a
//! policy string resolves, shared by the CLI, the JSON config runner,
//! the experiment harness and the control plane.
//!
//! * [`default_rr::DefaultScheduler`] — Storm's default Round-Robin task
//!   assignment (the baseline the paper beats).
//! * [`hetero::HeteroScheduler`] — the paper's contribution: Alg. 1
//!   (`FirstAssignment`) + Alg. 2 (`MaximizeThroughput`).
//! * [`optimal::OptimalScheduler`] — exhaustive/sampled search over the
//!   placement design space (the paper's upper-bound comparator),
//!   batch-scored through the AOT model.
//!
//! All three honor the request's constraints inside their search
//! (excluded machines host nothing, pins restrict candidate hosts,
//! instance caps bound growth, reserved headroom shrinks machine
//! budgets) and serve every objective — see the
//! [`request`] module docs for the exact objective semantics.
//!
//! A [`Schedule`] carries the placement, the certified topology input
//! rate, the predicted evaluation at that rate, and [`Provenance`]
//! (which policy, which objective, how many placements were evaluated,
//! through which scoring backend, in how much wall time).
//!
//! Many topologies on one shared cluster go through [`workload`]: a
//! [`Workload`] names its tenants, a [`WorkloadProblem`] validates them
//! all once, and the same policies schedule them jointly (merged
//! problem, weighted shared scale) or by incremental admission against
//! residual capacity — see the module docs for the exact semantics.

pub mod default_rr;
pub mod hetero;
pub mod optimal;
pub mod problem;
pub mod registry;
pub mod request;
pub mod reschedule;
pub mod search;
pub mod workload;

pub use problem::{IntoCow, Problem, ProblemDelta, ResolvedConstraints};
pub use registry::PolicyParams;
pub use request::{Constraints, Objective, ScheduleRequest, SearchBudget};
pub use workload::{
    TenancyMode, TenantSchedule, TenantSpec, Workload, WorkloadProblem, WorkloadSchedule,
};

use std::time::Duration;

use crate::cluster::Cluster;
use crate::predict::{Evaluation, Evaluator, Placement};
use crate::topology::Topology;
use crate::{Error, Result};

/// Why a search run stopped.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum Termination {
    /// The search covered its whole (possibly bound-pruned) space.
    #[default]
    Exhausted,
    /// The request's [`request::SearchBudget`] ran out first.
    Budget,
    /// The certified optimality gap reached the requested target first.
    TargetGap,
}

impl Termination {
    /// Stable lower-case name for rendering and JSON export.
    pub fn name(&self) -> &'static str {
        match self {
            Termination::Exhausted => "exhausted",
            Termination::Budget => "budget",
            Termination::TargetGap => "target-gap",
        }
    }
}

/// How a [`Schedule`] came to be.
#[derive(Debug, Clone, Default)]
pub struct Provenance {
    /// Registry name of the policy that produced it.
    pub policy: String,
    /// Rendered objective ([`Objective::describe`]).
    pub objective: String,
    /// Candidate placements evaluated during the search.
    pub placements_evaluated: u64,
    /// Scoring backend the search ran through ("native" / "pjrt").
    pub backend: String,
    /// Wall-clock time spent inside the scheduler.
    pub wall: Duration,
    /// Certified upper bound on the rate of *any* candidate in the
    /// search space, when the search can prove one (`None` for
    /// heuristics that carry no bound).
    pub bound: Option<f64>,
    /// Relative optimality gap `(bound − rate) / rate`: how far the
    /// returned schedule could be from the best possible one.  Always
    /// ≥ 0, and exactly 0 whenever the search ran to exhaustion.
    pub optimality_gap: Option<f64>,
    /// Why the search stopped.
    pub terminated: Termination,
}

impl Provenance {
    /// Fold another run's provenance into this one: identity fields
    /// (policy, objective, backend) take the latest value, counters
    /// (placements evaluated, wall time) accumulate — how multi-run
    /// schedules (per-tenant workload modes) aggregate provenance.
    pub fn absorb(&mut self, other: &Provenance) {
        self.policy = other.policy.clone();
        self.objective = other.objective.clone();
        self.backend = other.backend.clone();
        self.placements_evaluated += other.placements_evaluated;
        self.wall += other.wall;
        // certainty fields describe the latest run, like identity
        self.bound = other.bound;
        self.optimality_gap = other.optimality_gap;
        self.terminated = other.terminated;
    }

    /// One-line rendering for CLI output and reports.
    pub fn render(&self) -> String {
        let mut line = format!(
            "policy={} objective={} backend={} evaluated={} wall={:.1}ms",
            self.policy,
            self.objective,
            self.backend,
            self.placements_evaluated,
            self.wall.as_secs_f64() * 1e3
        );
        if let Some(b) = self.bound {
            if b.is_finite() {
                line.push_str(&format!(" bound={b:.1}"));
            }
        }
        if let Some(g) = self.optimality_gap {
            line.push_str(&format!(" gap={:.2}%", g * 100.0));
        }
        if self.terminated != Termination::Exhausted {
            line.push_str(&format!(" terminated={}", self.terminated.name()));
        }
        line
    }
}

/// A scheduler's output: the execution topology graph (implied by the
/// placement's instance counts), its task assignment, the topology
/// input rate the scheduler certifies, and provenance.
#[derive(Debug, Clone)]
pub struct Schedule {
    pub placement: Placement,
    /// Certified topology input rate (tuples/s).
    pub rate: f64,
    /// Predicted evaluation at `rate`.
    pub eval: Evaluation,
    /// Who produced this schedule, and how.
    pub provenance: Provenance,
}

impl Schedule {
    /// Render the assignment as `component -> [machine names]` rows.
    pub fn describe(&self, top: &Topology, cluster: &Cluster) -> String {
        let mut out = String::new();
        for (c, comp) in top.components.iter().enumerate() {
            let mut homes = Vec::new();
            for (m, mach) in cluster.machines.iter().enumerate() {
                for _ in 0..self.placement.x[c][m] {
                    homes.push(mach.name.as_str());
                }
            }
            out.push_str(&format!(
                "  {:<16} x{:<2} -> [{}]\n",
                comp.name,
                self.placement.count(c),
                homes.join(", ")
            ));
        }
        out
    }

    /// Machines hosting at least one task instance.
    pub fn machines_used(&self) -> usize {
        (0..self.placement.n_machines())
            .filter(|&m| self.placement.tasks_on(m) > 0)
            .count()
    }
}

/// Common scheduler interface: solve `problem` under `req`.
///
/// Implementations certify that the returned `rate` is feasible under
/// the prediction model *with the request's constraints applied* (zero
/// tasks on excluded machines, pins respected, instance counts within
/// their caps, utilization within the headroom-reduced budgets).
pub trait Scheduler {
    /// Registry name of this policy.
    fn name(&self) -> &'static str;

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule>;
}

/// Finish a schedule from a placement: certify its max stable rate and
/// evaluate there (shared by every policy; provenance is stamped by the
/// caller).
pub(crate) fn finish(ev: &Evaluator, placement: Placement) -> Result<Schedule> {
    let rate = ev.max_stable_rate_or_zero(&placement)?;
    let eval = ev.evaluate(&placement, rate)?;
    Ok(Schedule { placement, rate, eval, provenance: Provenance::default() })
}

/// Flush one finished search into the global telemetry layer: the
/// per-policy wall-time histogram, evaluated/pruned counters and the
/// `candidate_pruned` / `schedule_chosen` journal events.  Called once
/// per `schedule()` after provenance is stamped — no hot-path cost, and
/// a no-op entirely when telemetry is disabled ([`crate::obs`]).
pub(crate) fn record_schedule_telemetry(s: &Schedule, pruned: u64) {
    if !crate::obs::enabled() {
        return;
    }
    let reg = crate::obs::global();
    let pv = &s.provenance;
    reg.histogram(&format!("sched.{}.wall_s", pv.policy)).observe(pv.wall.as_secs_f64());
    reg.counter(&format!("sched.{}.evaluated", pv.policy)).add(pv.placements_evaluated);
    reg.counter(&format!("sched.{}.pruned", pv.policy)).add(pruned);
    if pruned > 0 {
        reg.journal().record(crate::obs::Event::CandidatePruned {
            policy: pv.policy.clone(),
            count: pruned,
            reason: "infeasible".into(),
        });
    }
    reg.journal().record(crate::obs::Event::ScheduleChosen {
        policy: pv.policy.clone(),
        backend: pv.backend.clone(),
        objective: pv.objective.clone(),
        rate: s.rate,
        evaluated: pv.placements_evaluated,
        pruned,
        wall_ms: pv.wall.as_secs_f64() * 1e3,
    });
}

/// Debug-build invariant net: every `schedule()` exit re-derives the
/// structural invariants through [`crate::check::validate`] (from-scratch
/// eq.-5 recomputation, constraint compliance, flag consistency) and
/// panics on the first violation, so property/fuzz runs trip at the
/// emitting policy instead of downstream.  Release builds compile to a
/// no-op.  The determinism replay check is CLI/test-only: re-running the
/// policy from inside the hook would recurse through the policies'
/// internal seed schedules.
#[cfg(debug_assertions)]
pub(crate) fn debug_validate(problem: &Problem, req: &ScheduleRequest, s: &Schedule) {
    match crate::check::validate(problem, req, s) {
        Ok(report) => {
            if !report.passed() {
                panic!(
                    "debug invariant check failed for policy '{}':\n{}",
                    s.provenance.policy,
                    report.render()
                );
            }
        }
        Err(e) => panic!("debug invariant check errored: {e}"),
    }
}

#[cfg(not(debug_assertions))]
#[inline(always)]
pub(crate) fn debug_validate(_problem: &Problem, _req: &ScheduleRequest, _s: &Schedule) {}

/// Utilization spread (max − min predicted utilization over non-excluded
/// machines) of `p` at rate `r` — the tie-breaker
/// [`Objective::BalancedUtilization`] minimizes.
pub(crate) fn util_spread(
    ev: &Evaluator,
    rc: &ResolvedConstraints,
    p: &Placement,
    r: f64,
) -> Result<f64> {
    let eval = ev.evaluate(p, r)?;
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for (m, &u) in eval.util.iter().enumerate() {
        if rc.excluded[m] {
            continue;
        }
        lo = lo.min(u);
        hi = hi.max(u);
    }
    Ok(if hi >= lo { hi - lo } else { 0.0 })
}

/// Greedy machine consolidation for [`Objective::MinMachinesAtRate`]:
/// repeatedly drain the used machine with the fewest tasks by moving its
/// instances onto other already-used, allowed machines, as long as the
/// certified rate stays `>= target`.
pub(crate) fn consolidate_machines(
    ev: &Evaluator,
    rc: &ResolvedConstraints,
    mut p: Placement,
    target: f64,
    max_tasks_per_machine: usize,
    evaluated: &mut u64,
) -> Result<Placement> {
    let n_comp = p.n_components();
    let n_m = p.n_machines();
    loop {
        let mut used: Vec<(usize, usize)> = (0..n_m)
            .filter_map(|m| {
                let t = p.tasks_on(m);
                (t > 0).then_some((t, m))
            })
            .collect();
        if used.len() <= 1 {
            return Ok(p);
        }
        used.sort_unstable();
        let mut drained = false;
        'victims: for &(_, d) in &used {
            let targets: Vec<usize> = used
                .iter()
                .map(|&(_, m)| m)
                .filter(|&m| m != d && !rc.excluded[m])
                .collect();
            let mut trial = p.clone();
            for c in 0..n_comp {
                while trial.x[c][d] > 0 {
                    let mut best: Option<(usize, f64)> = None;
                    for &t in &targets {
                        if !rc.allows(c, t) || trial.tasks_on(t) >= max_tasks_per_machine {
                            continue;
                        }
                        trial.x[c][d] -= 1;
                        trial.x[c][t] += 1;
                        let r = ev.max_stable_rate_or_zero(&trial)?;
                        *evaluated += 1;
                        trial.x[c][t] -= 1;
                        trial.x[c][d] += 1;
                        if r + 1e-9 >= target && best.map_or(true, |(_, br)| r > br) {
                            best = Some((t, r));
                        }
                    }
                    match best {
                        Some((t, _)) => {
                            trial.x[c][d] -= 1;
                            trial.x[c][t] += 1;
                        }
                        None => continue 'victims, // this machine cannot drain
                    }
                }
            }
            p = trial;
            drained = true;
            break;
        }
        if !drained {
            return Ok(p);
        }
    }
}

/// Hill-climb for [`Objective::BalancedUtilization`]: single-instance
/// moves that keep the certified rate (never worse) and strictly shrink
/// the utilization spread at that rate.
pub(crate) fn balance_utilization(
    ev: &Evaluator,
    rc: &ResolvedConstraints,
    mut p: Placement,
    max_tasks_per_machine: usize,
    evaluated: &mut u64,
) -> Result<Placement> {
    let n_comp = p.n_components();
    let n_m = p.n_machines();
    let mut best_rate = ev.max_stable_rate_or_zero(&p)?;
    let mut best_spread = util_spread(ev, rc, &p, best_rate)?;
    *evaluated += 1;
    for _sweep in 0..64 {
        let mut improved = false;
        for c in 0..n_comp {
            for from in 0..n_m {
                if p.x[c][from] == 0 {
                    continue;
                }
                for to in 0..n_m {
                    if to == from
                        || !rc.allows(c, to)
                        || p.tasks_on(to) >= max_tasks_per_machine
                    {
                        continue;
                    }
                    p.x[c][from] -= 1;
                    p.x[c][to] += 1;
                    let r = ev.max_stable_rate_or_zero(&p)?;
                    *evaluated += 1;
                    let better = r + 1e-9 >= best_rate && {
                        let s = util_spread(ev, rc, &p, r)?;
                        if s + 1e-9 < best_spread {
                            best_rate = best_rate.max(r);
                            best_spread = s;
                            true
                        } else {
                            false
                        }
                    };
                    if better {
                        improved = true;
                        if p.x[c][from] == 0 {
                            break;
                        }
                    } else {
                        p.x[c][to] -= 1;
                        p.x[c][from] += 1;
                    }
                }
            }
        }
        if !improved {
            break;
        }
    }
    Ok(p)
}

/// Apply the request's objective to a max-throughput schedule — the
/// shared post-pass used by the heuristic policies (the optimal search
/// compares candidates objective-aware instead).  Preserves provenance;
/// the returned schedule is re-certified through `ev`.
pub(crate) fn apply_objective(
    ev: &Evaluator,
    rc: &ResolvedConstraints,
    objective: &Objective,
    s: Schedule,
    max_tasks_per_machine: usize,
    evaluated: &mut u64,
) -> Result<Schedule> {
    match objective {
        Objective::MaxThroughput => Ok(s),
        Objective::MinMachinesAtRate(target) => {
            if s.rate + 1e-9 < *target {
                return Err(Error::Schedule(format!(
                    "objective infeasible: certified rate {:.3} < requested rate {:.3}",
                    s.rate, target
                )));
            }
            let p = consolidate_machines(
                ev,
                rc,
                s.placement,
                *target,
                max_tasks_per_machine,
                evaluated,
            )?;
            let mut out = finish(ev, p)?;
            out.provenance = s.provenance;
            Ok(out)
        }
        Objective::BalancedUtilization => {
            let p = balance_utilization(ev, rc, s.placement, max_tasks_per_machine, evaluated)?;
            let mut out = finish(ev, p)?;
            out.provenance = s.provenance;
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    #[test]
    fn describe_lists_all_components() {
        let p = problem();
        let ev = p.evaluator();
        let mut pl = Placement::empty(p.topology().n_components(), p.cluster().n_machines());
        for c in 0..p.topology().n_components() {
            pl.x[c][0] = 1;
        }
        let s = finish(ev, pl).unwrap();
        let d = s.describe(p.topology(), p.cluster());
        for comp in &p.topology().components {
            assert!(d.contains(&comp.name), "missing {}", comp.name);
        }
        assert_eq!(s.machines_used(), 1);
    }

    #[test]
    fn finish_rate_is_feasible_boundary() {
        let p = problem();
        let ev = p.evaluator();
        let mut pl = Placement::empty(p.topology().n_components(), p.cluster().n_machines());
        for c in 0..p.topology().n_components() {
            pl.x[c][c % 3] = 1;
        }
        let s = finish(ev, pl).unwrap();
        assert!(s.eval.feasible);
        assert!(s.rate > 0.0);
    }

    #[test]
    fn absorb_takes_latest_identity_and_accumulates_counters() {
        let mut acc = Provenance {
            policy: "hetero".into(),
            objective: "max-throughput".into(),
            placements_evaluated: 10,
            backend: "native".into(),
            wall: Duration::from_millis(5),
            ..Default::default()
        };
        let other = Provenance {
            policy: "optimal".into(),
            objective: "balanced-utilization".into(),
            placements_evaluated: 32,
            backend: "pjrt".into(),
            wall: Duration::from_millis(7),
            bound: Some(120.0),
            optimality_gap: Some(0.05),
            terminated: Termination::Budget,
        };
        acc.absorb(&other);
        // identity fields follow the latest run...
        assert_eq!(acc.policy, "optimal");
        assert_eq!(acc.objective, "balanced-utilization");
        assert_eq!(acc.backend, "pjrt");
        // ...as do the certainty fields (they describe the latest run)
        assert_eq!(acc.bound, Some(120.0));
        assert_eq!(acc.optimality_gap, Some(0.05));
        assert_eq!(acc.terminated, Termination::Budget);
        // ...while the counters accumulate across runs
        assert_eq!(acc.placements_evaluated, 42);
        assert_eq!(acc.wall, Duration::from_millis(12));
    }

    #[test]
    fn absorb_from_default_clears_identity_but_keeps_counters() {
        // folding in a default provenance still overwrites identity
        // fields (latest wins, even when "latest" is empty) — callers
        // aggregating multi-run schedules must absorb stamped
        // provenance only
        let mut acc = Provenance {
            policy: "hetero".into(),
            objective: "max-throughput".into(),
            placements_evaluated: 9,
            backend: "native".into(),
            wall: Duration::from_millis(3),
            ..Default::default()
        };
        acc.absorb(&Provenance::default());
        assert_eq!(acc.policy, "");
        assert_eq!(acc.objective, "");
        assert_eq!(acc.backend, "");
        assert_eq!(acc.placements_evaluated, 9);
        assert_eq!(acc.wall, Duration::from_millis(3));
    }

    #[test]
    fn provenance_renders_fields() {
        let pv = Provenance {
            policy: "hetero".into(),
            objective: "max-throughput".into(),
            placements_evaluated: 42,
            backend: "native".into(),
            wall: Duration::from_millis(3),
            ..Default::default()
        };
        let line = pv.render();
        for needle in ["hetero", "max-throughput", "native", "42"] {
            assert!(line.contains(needle), "{line}");
        }
        // no bound/gap → none rendered; exhausted is the quiet default
        assert!(!line.contains("bound=") && !line.contains("gap="), "{line}");
        assert!(!line.contains("terminated="), "{line}");
        let pv = Provenance {
            bound: Some(110.0),
            optimality_gap: Some(0.1),
            terminated: Termination::Budget,
            ..pv
        };
        let line = pv.render();
        for needle in ["bound=110.0", "gap=10.00%", "terminated=budget"] {
            assert!(line.contains(needle), "{line}");
        }
    }

    /// Acceptance: every registered policy honors machine exclusion
    /// under the max-throughput objective — feasible schedule, zero
    /// tasks on the excluded machine.
    #[test]
    fn every_policy_honors_exclusion() {
        let p = problem();
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().exclude_machine("i3-0"));
        let excluded = p.cluster().machines.iter().position(|m| m.name == "i3-0").unwrap();
        // small search bound keeps the optimal policy fast in debug mode
        let params = PolicyParams { max_instances_per_component: 2, ..Default::default() };
        for info in registry::policies() {
            let sched = registry::create(info.name, &params).unwrap();
            let s = sched.schedule(&p, &req).unwrap_or_else(|e| {
                panic!("{}: schedule failed under exclusion: {e}", info.name)
            });
            assert!(s.eval.feasible, "{}: infeasible", info.name);
            assert!(s.rate > 0.0, "{}: rate 0", info.name);
            assert_eq!(
                s.placement.tasks_on(excluded),
                0,
                "{}: placed tasks on the excluded machine",
                info.name
            );
            assert_eq!(s.provenance.policy, info.name);
        }
    }

    #[test]
    fn min_machines_objective_consolidates() {
        let p = problem();
        let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
        let max = hetero.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        // ask for a rate the paper cluster can serve on fewer machines
        let target = max.rate * 0.3;
        let req = ScheduleRequest::new(Objective::MinMachinesAtRate(target));
        let s = hetero.schedule(&p, &req).unwrap();
        assert!(s.rate + 1e-9 >= target, "rate {} below target {target}", s.rate);
        assert!(
            s.machines_used() <= max.machines_used(),
            "consolidation used more machines ({}) than max-throughput ({})",
            s.machines_used(),
            max.machines_used()
        );
        // an unattainable target errors instead of silently under-delivering
        let req = ScheduleRequest::new(Objective::MinMachinesAtRate(max.rate * 100.0));
        assert!(hetero.schedule(&p, &req).is_err());
    }

    #[test]
    fn balanced_objective_never_loses_rate() {
        let p = problem();
        let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
        let max = hetero.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        let bal = hetero
            .schedule(&p, &ScheduleRequest::new(Objective::BalancedUtilization))
            .unwrap();
        assert!(
            bal.rate + 1e-6 >= max.rate,
            "balanced rate {} < max-throughput rate {}",
            bal.rate,
            max.rate
        );
        let rc = p.resolve(&Constraints::new()).unwrap();
        let s_max = util_spread(p.evaluator(), &rc, &max.placement, max.rate).unwrap();
        let s_bal = util_spread(p.evaluator(), &rc, &bal.placement, bal.rate).unwrap();
        assert!(
            s_bal <= s_max + 1e-6,
            "balanced spread {s_bal} worse than max-throughput spread {s_max}"
        );
    }

    #[test]
    fn headroom_lowers_certified_rate() {
        let p = problem();
        let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
        let free = hetero.schedule(&p, &ScheduleRequest::max_throughput()).unwrap();
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().reserve_headroom(30.0));
        let held = hetero.schedule(&p, &req).unwrap();
        assert!(
            held.rate < free.rate,
            "30pp headroom should cost rate: {} vs {}",
            held.rate,
            free.rate
        );
        // utilization at the certified rate stays under the reduced budget
        let rc = p.resolve(&req.constraints).unwrap();
        let ev = p.constrained_evaluator(&rc);
        let eval = ev.evaluate(&held.placement, held.rate).unwrap();
        for (m, u) in eval.util.iter().enumerate() {
            assert!(*u <= ev.cap[m] + 1e-6, "machine {m} at {u}% > reduced cap");
        }
    }

    #[test]
    fn pinned_component_stays_put() {
        let p = problem();
        let spout = 0;
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().pin_component("spout", ["i5-0"]));
        let i5 = p.cluster().machines.iter().position(|m| m.name == "i5-0").unwrap();
        for name in ["hetero", "default"] {
            let sched = registry::create(name, &PolicyParams::default()).unwrap();
            let s = sched.schedule(&p, &req).unwrap();
            assert_eq!(
                s.placement.count(spout),
                s.placement.x[spout][i5],
                "{name}: pinned component left its machine"
            );
        }
    }
}
