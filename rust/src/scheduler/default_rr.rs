//! Storm's default scheduler (paper §2.3): Round-Robin, heterogeneity
//! blind.
//!
//! Given an execution topology graph (instance counts per component), the
//! default scheduler maps executors to worker slots in a simple
//! Round-Robin over the available machines, "regardless of their
//! computing power" — exactly the behavior Fig. 2c illustrates.
//!
//! The counts are an *input* here (in Storm the user sets them).  For the
//! paper's comparisons the counts come from the proposed scheduler's ETG
//! (the methodology of §6.3: "we first run our algorithm to determine the
//! number of instances... now we can fairly compare only the
//! effectiveness of scheduling policies").

use super::{finish, Schedule, Scheduler};
use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::{Evaluator, Placement};
use crate::topology::{Etg, Topology};
use crate::{Error, Result};

/// Round-Robin baseline.
#[derive(Debug, Clone)]
pub struct DefaultScheduler {
    /// Instance counts to place.  `None` = minimal ETG (one per
    /// component), matching a user who submits the bare user graph.
    pub etg: Option<Etg>,
}

impl DefaultScheduler {
    /// Place the minimal ETG (1 instance per component).
    pub fn minimal() -> Self {
        DefaultScheduler { etg: None }
    }

    /// Place a caller-provided ETG.
    pub fn with_etg(etg: Etg) -> Self {
        DefaultScheduler { etg: Some(etg) }
    }

    /// The pure assignment step, usable without profiles: executors are
    /// enumerated component-major (Storm's executor list order) and dealt
    /// to machines cyclically.
    pub fn assign(top: &Topology, cluster: &Cluster, etg: &Etg) -> Result<Placement> {
        if etg.counts.len() != top.n_components() {
            return Err(Error::Schedule(format!(
                "ETG has {} counts for {} components",
                etg.counts.len(),
                top.n_components()
            )));
        }
        let m = cluster.n_machines();
        let mut p = Placement::empty(top.n_components(), m);
        let mut next = 0usize;
        for (c, &count) in etg.counts.iter().enumerate() {
            for _ in 0..count {
                p.x[c][next % m] += 1;
                next += 1;
            }
        }
        Ok(p)
    }
}

impl Scheduler for DefaultScheduler {
    fn name(&self) -> &'static str {
        "default-rr"
    }

    fn schedule(&self, top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Schedule> {
        let etg = self.etg.clone().unwrap_or_else(|| Etg::minimal(top));
        let placement = Self::assign(top, cluster, &etg)?;
        let ev = Evaluator::new(top, cluster, profiles)?;
        // Storm does not certify a rate; for throughput comparisons the
        // baseline gets credit for the largest rate its placement can
        // sustain (most favorable interpretation for the baseline).
        finish(&ev, placement)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    #[test]
    fn rr_deals_cyclically() {
        let (cluster, _) = presets::paper_cluster();
        let top = benchmarks::linear(); // 4 components
        let etg = Etg { counts: vec![1, 1, 1, 1] };
        let p = DefaultScheduler::assign(&top, &cluster, &etg).unwrap();
        // executors 0..3 dealt to machines 0,1,2,0
        assert_eq!(p.x[0][0], 1);
        assert_eq!(p.x[1][1], 1);
        assert_eq!(p.x[2][2], 1);
        assert_eq!(p.x[3][0], 1);
    }

    #[test]
    fn rr_balances_counts() {
        let (cluster, _) = presets::paper_cluster();
        let top = benchmarks::linear();
        let etg = Etg { counts: vec![2, 3, 4, 3] }; // 12 tasks over 3 machines
        let p = DefaultScheduler::assign(&top, &cluster, &etg).unwrap();
        for m in 0..cluster.n_machines() {
            assert_eq!(p.tasks_on(m), 4);
        }
        assert_eq!(p.counts(), etg.counts);
    }

    #[test]
    fn rr_ignores_heterogeneity() {
        // identical task loads land on machines in index order, not by power
        let (cluster, _) = presets::paper_cluster();
        let top = benchmarks::star();
        let etg = Etg { counts: vec![1; top.n_components()] };
        let p = DefaultScheduler::assign(&top, &cluster, &etg).unwrap();
        // first executor always on machine 0 (the slow Pentium)
        assert_eq!(p.x[0][0], 1);
    }

    #[test]
    fn schedule_is_feasible() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::diamond();
        let s = DefaultScheduler::minimal().schedule(&top, &cluster, &db).unwrap();
        assert!(s.eval.feasible);
        assert!(s.rate > 0.0);
    }

    #[test]
    fn wrong_etg_len_rejected() {
        let (cluster, _) = presets::paper_cluster();
        let top = benchmarks::linear();
        let etg = Etg { counts: vec![1, 1] };
        assert!(DefaultScheduler::assign(&top, &cluster, &etg).is_err());
    }
}
