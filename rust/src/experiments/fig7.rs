//! Fig. 7: maximum achievable throughput as a function of the
//! per-component instance counts ⟨x, y⟩ for the two Storm-Benchmark
//! topologies (RollingCount, UniqueVisitor), with the pair chosen by the
//! proposed algorithm marked.
//!
//! Methodology per the paper: every ⟨x, y⟩ execution graph is scheduled
//! by the *default* scheduler (Round-Robin); the figure shows the effect
//! of the instance-count choice alone.  The proposed scheduler is then
//! run to see how close its chosen pair gets to the best pair.

use crate::cluster::presets;
use crate::scheduler::default_rr::DefaultScheduler;
use crate::scheduler::{registry, PolicyParams, Problem, ScheduleRequest};
use crate::topology::{benchmarks, Etg, Topology};
use crate::Result;

use super::{f1, ExperimentResult};

/// Sweep result for one topology.
#[derive(Debug, Clone)]
pub struct PairSweep {
    pub topology: String,
    /// `(x, y, throughput)` for every pair.
    pub grid: Vec<(usize, usize, f64)>,
    pub best: (usize, usize, f64),
    /// Pair the proposed algorithm chose, with its throughput under the
    /// same (default-scheduler) placement rule.
    pub ours: (usize, usize, f64),
}

fn sweep(top: &Topology, max_n: usize) -> Result<PairSweep> {
    let (cluster, db) = presets::paper_cluster();
    let problem = Problem::new(top, &cluster, &db)?;
    let ev = problem.evaluator();
    let mut grid = Vec::new();
    let mut best = (1, 1, 0.0f64);
    for x in 1..=max_n {
        for y in 1..=max_n {
            let etg = Etg { counts: vec![1, x, y] };
            let placement = DefaultScheduler::assign(top, &cluster, &etg)?;
            let thpt = ev.best_throughput(&placement)?;
            grid.push((x, y, thpt));
            if thpt > best.2 {
                best = (x, y, thpt);
            }
        }
    }
    // The proposed algorithm's chosen counts, credited with its own
    // placement (the algorithm outputs counts *and* assignment; RR'ing
    // its counts would punish it for the default scheduler's blindness).
    let hetero = registry::create("hetero", &PolicyParams::default())?;
    let ours_sched = hetero.schedule(&problem, &ScheduleRequest::max_throughput())?;
    let counts = ours_sched.placement.counts();
    let (ox, oy) = (counts[1], counts[2]);
    let ours_thpt = ev.best_throughput(&ours_sched.placement)?;
    Ok(PairSweep { topology: top.name.clone(), grid, best, ours: (ox, oy, ours_thpt) })
}

pub fn run(fast: bool) -> Result<ExperimentResult> {
    let max_n = if fast { 4 } else { 6 };
    let mut out = ExperimentResult::new(
        "fig7",
        format!("throughput by instance pair <x,y> (default placement, x,y in 1..={max_n})"),
        &["topology", "pair", "throughput", "marker"],
    );
    for top in [benchmarks::rolling_count(), benchmarks::unique_visitor()] {
        let s = sweep(&top, max_n)?;
        for (x, y, t) in &s.grid {
            let mut marker = String::new();
            if (*x, *y) == (s.best.0, s.best.1) {
                marker.push_str("optimal ");
            }
            if (*x, *y) == (s.ours.0, s.ours.1) {
                marker.push_str("<-- ours");
            }
            out.row(vec![s.topology.clone(), format!("<{x},{y}>"), f1(*t), marker]);
        }
        let delta = (s.ours.2 - s.best.2) / s.best.2 * 100.0;
        out.note(format!(
            "{}: ours <{},{}> at {:.0} t/s (own placement) vs best RR pair <{},{}> at \
             {:.0} t/s ({:+.1}%) — paper: chosen pair exact for RollingCount, 2% off for \
             UniqueVisitor",
            s.topology, s.ours.0, s.ours.1, s.ours.2, s.best.0, s.best.1, s.best.2, delta
        ));
    }
    Ok(out)
}

/// Expose the raw sweep for tests / benches.
pub fn sweeps(max_n: usize) -> Result<Vec<PairSweep>> {
    Ok(vec![
        sweep(&benchmarks::rolling_count(), max_n)?,
        sweep(&benchmarks::unique_visitor(), max_n)?,
    ])
}

#[cfg(test)]
mod tests {
    #[test]
    fn ours_at_least_best_rr_pair() {
        for s in super::sweeps(4).unwrap() {
            assert!(s.best.2 > 0.0);
            // our scheduler (counts + placement) must stay within 10% of
            // the best instance pair under blind RR placement (the paper
            // reports 0%/2% on its profiles; see EXPERIMENTS.md)
            assert!(
                s.ours.2 >= s.best.2 * 0.90,
                "{}: ours {:?} best {:?}",
                s.topology,
                s.ours,
                s.best
            );
        }
    }

    #[test]
    fn grid_covers_all_pairs() {
        let s = &super::sweeps(3).unwrap()[0];
        assert_eq!(s.grid.len(), 9);
    }
}
