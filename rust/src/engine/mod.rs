//! The stream-processing engine: the "real heterogeneous cluster"
//! substitute (DESIGN.md §5 substitutions).
//!
//! The paper measures its schedulers on four physical machines running
//! Apache Storm.  This engine reproduces the mechanism that matters for
//! the paper's claims — heterogeneous per-tuple CPU cost and machine
//! capacity saturation — with real threads, real queueing and real
//! time:
//!
//! * every worker **machine** is a thread modeling one Storm worker
//!   process: a single-server queue with a CPU budget of 100 %·s per
//!   second (the paper's `MAC`);
//! * every **task** (executor) is pinned to its machine per the
//!   placement; work addressed to a task arrives over bounded
//!   lock-free SPSC **rings** ([`ring`]), one per (producer thread,
//!   task) pair, and moves in [tuple batches](worker) — the
//!   throughput-first dataplane of ROADMAP item 1;
//! * service spends `n · e_ij` percent-seconds of budget per batch
//!   (from the same profile DB the schedulers read, plus optional
//!   noise — the engine is the ground truth the prediction model is
//!   judged against, Fig. 6); per-instance **MET** overhead is burned
//!   as periodic background work;
//! * **spout pacing** threads inject the topology input rate `R0`;
//!   when downstream credits run out the spout is *throttled*
//!   (credit-based backpressure, lossless) instead of shedding — the
//!   legacy channel dataplane ([`legacy`], [`Dataplane::Legacy`])
//!   keeps the old `max.spout.pending` shedding behavior as the
//!   baseline;
//! * routing uses **shuffle grouping**: producers round-robin over the
//!   consumer component's instances; α > 1 fan-out is produced with
//!   the deterministic fractional accumulator shared with the event
//!   simulator ([`crate::topology::fanout`], eq. 6 semantics);
//! * in [`ComputeMode::Pjrt`] the service time is burned by executing
//!   the AOT work kernel (`work.hlo.txt`) instead of virtual work —
//!   real compute through PJRT on the data path.
//!
//! Throughput is the sum of tuples processed per second over all tasks
//! (the paper's eq. 2 objective); utilization is busy-time / wall-time
//! per machine.  Both are measured only inside the post-warmup window,
//! and only for tuples *emitted* inside it (the emit-epoch stamp —
//! warmup backlog is excluded from numerator and denominator alike).

mod legacy;
pub mod ring;
mod worker;

use std::sync::Mutex;
use std::time::Duration;

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::Placement;
use crate::simulator::event::LatencySummary;
use crate::topology::Topology;
use crate::{Error, Result};

pub use worker::ComputeMode;

/// Which dataplane executes the placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataplane {
    /// Batched SPSC-ring dataplane with credit-based backpressure
    /// (the default; millions of tuples/s).
    Ring,
    /// The original per-tuple mpsc dataplane with `max.spout.pending`
    /// shedding, kept as the bench baseline.
    Legacy,
}

/// Engine tunables.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Measurement window.
    pub duration: Duration,
    /// Warmup before measurement starts.
    pub warmup: Duration,
    /// Time compression: one wall-clock second simulates `1/time_scale`
    /// virtual (cluster) seconds.  Service times shrink by `time_scale`
    /// and emission rates grow by `1/time_scale`, so machines saturate at
    /// exactly the modeled capacity and utilization ratios are preserved;
    /// 1.0 = real time, 0.25 = 4x faster (test suite), ~0.001 = the
    /// millions-of-tuples/s regime of the `dataplane` experiment.
    pub time_scale: f64,
    /// Legacy dataplane only: spouts shed load once a target machine's
    /// pending queue passes this depth (Storm `max.spout.pending`).
    pub max_pending: i64,
    /// Multiplicative service-time noise amplitude (0.05 = ±5%).
    pub noise: f64,
    pub seed: u64,
    pub compute: ComputeMode,
    /// Which dataplane to run.
    pub dataplane: Dataplane,
    /// Ring dataplane: tuples per batch.
    pub batch: usize,
    /// Ring dataplane: ring capacity in batches per (producer, task)
    /// pair — the credit pool; a full ring throttles the producer.
    pub ring_capacity: usize,
    /// Ring dataplane: spin-burner floor in µs — service debts below
    /// this accumulate before the calibrated spin runs (the
    /// calibration knob; raise it to amortize clock polling, lower it
    /// for finer pacing).
    pub spin_floor_us: f64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            duration: Duration::from_secs(4),
            warmup: Duration::from_millis(800),
            time_scale: 1.0,
            max_pending: 2048,
            noise: 0.0,
            seed: 0x5EED,
            compute: ComputeMode::Simulated,
            dataplane: Dataplane::Ring,
            batch: 256,
            ring_capacity: 64,
            spin_floor_us: 1.0,
        }
    }
}

impl EngineConfig {
    /// Fast settings for unit/integration tests.
    pub fn fast_test() -> Self {
        EngineConfig {
            duration: Duration::from_millis(900),
            warmup: Duration::from_millis(300),
            time_scale: 0.25,
            ..Default::default()
        }
    }
}

/// Validated, expanded inputs shared by both dataplanes.
pub(crate) struct Plan {
    pub n_comp: usize,
    pub n_machines: usize,
    /// tasks[c][slot] = hosting machine.
    pub tasks: Vec<Vec<usize>>,
    pub e_m: Vec<Vec<f64>>,
    pub met_m: Vec<Vec<f64>>,
    pub alpha: Vec<f64>,
    pub downstream: Vec<Vec<usize>>,
    /// Spout weight per component (`weight · R0` arrives at weighted
    /// spouts).
    pub weights: Vec<f64>,
    pub spouts: Vec<usize>,
}

/// Measured results of an engine run.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Measurement window length (s).
    pub window: f64,
    /// Overall throughput in *virtual* tuples/s: tuples processed per
    /// virtual second summed over all tasks (same definition as the
    /// predictor's objective).
    pub throughput: f64,
    /// Measured CPU utilization per machine (%), busy / wall.
    pub util: Vec<f64>,
    /// Tuples processed per virtual second per component.
    pub comp_rate: Vec<f64>,
    /// Mean measured service time per (component, machine) where
    /// observed, in profile units (seconds of budget per tuple; the
    /// engine's `time_scale` is already divided out).
    pub service: Vec<Vec<Option<f64>>>,
    /// Tuples shed at the spouts in the window (legacy dataplane only;
    /// the ring dataplane is lossless and always reports 0).
    pub shed: u64,
    /// Effective spout emission rate achieved (virtual tuples/s).
    pub emitted_rate: f64,
    /// Tuples processed per *wall-clock* second — the executed
    /// dataplane rate the 1M-tuples/s roadmap target is scored on.
    pub wall_throughput: f64,
    /// End-to-end sink tuple latency in wall seconds (ring dataplane;
    /// `None` when nothing reached a sink inside the window).
    pub latency: Option<LatencySummary>,
    /// Producer-side events where a downstream ring was full (credits
    /// exhausted); ring dataplane only.
    pub credit_stalls: u64,
    /// True when a spout was throttled by exhausted credits inside the
    /// measurement window (the credit-based backpressure verdict).
    pub throttled: bool,
}

/// Engine runs measure wall-clock capacity with spinning worker
/// threads; two concurrent runs in one process would contend for cores
/// and corrupt each other's measurements (most visibly when the test
/// harness runs engine tests in parallel).  Nothing legitimate runs
/// two engines at once, so `run` is process-serialized.
static RUN_GATE: Mutex<()> = Mutex::new(());

/// Run `placement` on the engine at topology input rate `r0`.
pub fn run(
    top: &Topology,
    cluster: &Cluster,
    profiles: &ProfileDb,
    placement: &Placement,
    r0: f64,
    cfg: &EngineConfig,
) -> Result<EngineReport> {
    let _serial = RUN_GATE.lock().unwrap_or_else(|e| e.into_inner());
    top.validate()?;
    cluster.validate()?;
    profiles.check_coverage(top, cluster)?;
    let n_comp = top.n_components();
    let n_machines = cluster.n_machines();
    if placement.n_components() != n_comp || placement.n_machines() != n_machines {
        return Err(Error::Engine("placement shape mismatch".into()));
    }
    if placement.counts().iter().any(|&c| c == 0) {
        return Err(Error::Engine("every component needs >= 1 instance".into()));
    }
    let (e_m, met_m) = profiles.expand(top, cluster)?;

    // ---- task table: tasks[c][slot] = hosting machine --------------------
    let mut tasks: Vec<Vec<usize>> = vec![Vec::new(); n_comp];
    for c in 0..n_comp {
        for m in 0..n_machines {
            for _ in 0..placement.x[c][m] {
                tasks[c].push(m);
            }
        }
    }

    let plan = Plan {
        n_comp,
        n_machines,
        tasks,
        e_m,
        met_m,
        alpha: top.components.iter().map(|c| c.alpha).collect(),
        downstream: (0..n_comp).map(|c| top.downstream(c)).collect(),
        weights: top.components.iter().map(|c| c.weight).collect(),
        spouts: top.spouts(),
    };
    match cfg.dataplane {
        Dataplane::Ring => worker::run_ring(&plan, r0, cfg),
        Dataplane::Legacy => legacy::run_legacy(&plan, r0, cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn place_spread(top: &Topology, cluster: &Cluster) -> Placement {
        let mut p = Placement::empty(top.n_components(), cluster.n_machines());
        for c in 0..top.n_components() {
            p.x[c][c % cluster.n_machines()] = 1;
        }
        p
    }

    #[test]
    fn linear_low_rate_runs_clean() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 40.0, &EngineConfig::fast_test()).unwrap();
        for (c, r) in rep.comp_rate.iter().enumerate() {
            assert!((r - 40.0).abs() < 12.0, "comp {c}: rate {r}");
        }
        assert!(rep.shed == 0, "ring dataplane never sheds");
        assert!(rep.credit_stalls == 0, "no stalls at low rate: {}", rep.credit_stalls);
        assert!(!rep.throttled);
        assert!(rep.throughput > 110.0 && rep.throughput < 210.0, "{}", rep.throughput);
        assert!(rep.wall_throughput > rep.throughput, "time compression raises the wall rate");
        let lat = rep.latency.expect("sink latency must be observed");
        assert!(lat.samples > 0 && lat.p99 >= lat.p50 && lat.p50 > 0.0);
    }

    #[test]
    fn utilization_tracks_prediction() {
        use crate::predict::Evaluator;
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let r0 = 120.0;
        let rep = run(&top, &cluster, &db, &p, r0, &EngineConfig::fast_test()).unwrap();
        let ev = Evaluator::new(&top, &cluster, &db).unwrap();
        let pred = ev.evaluate(&p, r0).unwrap();
        for m in 0..cluster.n_machines() {
            let err = (rep.util[m] - pred.util[m]).abs();
            assert!(
                err < 12.0,
                "machine {m}: measured {:.1}% vs predicted {:.1}%",
                rep.util[m],
                pred.util[m]
            );
        }
    }

    #[test]
    fn overload_throttles_without_shedding() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let mut p = Placement::empty(top.n_components(), cluster.n_machines());
        for c in 0..top.n_components() {
            p.x[c][0] = 1; // everything on the Pentium worker
        }
        // small batches/rings: at the test's compressed wall rates a
        // full-size credit pool would hold several hundred ms of work,
        // and the (correctly) uncounted warmup backlog would eat the
        // measurement window
        let cfg = EngineConfig { batch: 8, ring_capacity: 4, ..EngineConfig::fast_test() };
        let rep = run(&top, &cluster, &db, &p, 4000.0, &cfg).unwrap();
        assert!(rep.shed == 0, "credit-based backpressure is lossless, got shed {}", rep.shed);
        assert!(rep.throttled, "spout must be throttled under overload");
        assert!(rep.credit_stalls > 0, "credits must run out under overload");
        assert!(
            rep.emitted_rate < 4000.0 * 0.8,
            "throttle must cut emission: {}",
            rep.emitted_rate
        );
        assert!(rep.util[0] > 60.0, "util {}", rep.util[0]);
        assert!(rep.util[1] < 5.0 && rep.util[2] < 5.0);
        // emit-epoch accounting: throughput cannot exceed what the
        // machine can actually process (warmup backlog must not inflate
        // the numerator)
        let (e_m, _) = db.expand(&top, &cluster).unwrap();
        let cap: f64 = 100.0 / (0..top.n_components()).map(|c| e_m[c][0]).sum::<f64>();
        let per_comp = rep.throughput / top.n_components() as f64;
        assert!(per_comp < cap * 1.25, "per-comp rate {per_comp} vs capacity {cap}");
    }

    #[test]
    fn alpha_fanout_amplifies_downstream() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::rolling_count(); // split has alpha 1.5
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 40.0, &EngineConfig::fast_test()).unwrap();
        let counter_rate = rep.comp_rate[2];
        assert!((counter_rate - 60.0).abs() < 18.0, "rate {counter_rate}");
    }

    #[test]
    fn multi_instance_divides_load() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let mut p = place_spread(&top, &cluster);
        p.x[3] = vec![0, 1, 1]; // high bolt: 2 instances on i3 + i5
        let rep = run(&top, &cluster, &db, &p, 100.0, &EngineConfig::fast_test()).unwrap();
        assert!((rep.comp_rate[3] - 100.0).abs() < 28.0, "{}", rep.comp_rate[3]);
    }

    #[test]
    fn missing_instance_rejected() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = Placement::empty(top.n_components(), cluster.n_machines());
        assert!(run(&top, &cluster, &db, &p, 10.0, &EngineConfig::fast_test()).is_err());
    }

    #[test]
    fn measured_service_matches_profile() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let rep = run(&top, &cluster, &db, &p, 60.0, &EngineConfig::fast_test()).unwrap();
        // placement c%3 puts component 3 (highCompute) on machine 0 (pentium)
        let svc = rep.service[3][0].expect("no service samples for highCompute");
        let e = db.get("highCompute", "pentium").unwrap().e;
        let want = e / 100.0; // %·s -> s of budget per tuple
        let rel = (svc - want).abs() / want;
        assert!(rel < 0.25, "measured {svc}, want {want}");
    }

    #[test]
    fn legacy_dataplane_still_runs() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let p = place_spread(&top, &cluster);
        let cfg = EngineConfig { dataplane: Dataplane::Legacy, ..EngineConfig::fast_test() };
        let rep = run(&top, &cluster, &db, &p, 40.0, &cfg).unwrap();
        assert!(rep.shed == 0, "shed {} at low rate", rep.shed);
        assert!(rep.throughput > 110.0 && rep.throughput < 210.0, "{}", rep.throughput);
        assert!(rep.latency.is_none(), "legacy path reports no latency");
    }
}
