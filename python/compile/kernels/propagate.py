"""L1 Pallas kernel: one rate-propagation step (paper eq. 6).

Component-level tuple-rate flow over the topology DAG.  One step:

    ir'[b, j] = src[b, j] + sum_i adj[i, j] * alpha[i] * ir[b, i]

i.e. every upstream component i forwards its output rate
``OR_i = IR_i * alpha_i`` to each downstream component it feeds (Storm
semantics: every subscribed consumer group receives the full stream).
``src[b, j]`` carries the topology input rate R0 into spout components.

The step is a [B, C] x [C, C] matmul; iterated DEPTH (>= longest path)
times in the L2 model it reaches the DAG fixed point.  Grid over the batch
axis; adj/alpha stay VMEM-resident.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..dims import BLOCK_B


def _prop_kernel(ir_ref, adj_ref, alpha_ref, src_ref, out_ref):
    ir = ir_ref[...]          # [bB, C]
    adj = adj_ref[...]        # [C, C]  adj[i, j] = 1 iff i feeds j
    alpha = alpha_ref[...]    # [1, C]  tuple division ratios
    src = src_ref[...]        # [bB, C] R0 injected at spouts
    out_ref[...] = src + (ir * alpha) @ adj


def propagate_step(ir, adj, alpha, src, *, block_b=None, interpret=True):
    """One eq.-6 step: f32[B, C] rates -> f32[B, C] rates."""
    B, C = ir.shape
    bb = block_b or min(BLOCK_B, B)
    assert B % bb == 0
    alpha2 = alpha.reshape(1, C)
    return pl.pallas_call(
        _prop_kernel,
        grid=(B // bb,),
        in_specs=[
            pl.BlockSpec((bb, C), lambda i: (i, 0)),
            pl.BlockSpec((C, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((bb, C), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, C), ir.dtype),
        interpret=interpret,
    )(ir, adj, alpha2, src)
