//! Bench: regenerate the paper's Fig.7-instance-pairs table (fig7) and time it.
//! Run: cargo bench --bench fig7_instances  [HSTORM_FAST=1 for quick mode]

use hstorm::experiments::fig7;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig7::run(fast).expect("fig7 runs"));
    println!("{}", result.render());
    println!("[fig7_instances] regenerated in {dt:?} (fast={fast})");
}
