//! Policy registry: the single place a scheduler *name* resolves to an
//! implementation.
//!
//! The CLI (`--scheduler`), the JSON config runner (`"scheduler":`),
//! the experiment harness and the control plane all construct policies
//! through [`create`], so the set of valid names — and their spellings —
//! cannot drift between entry points.  `hstorm schedule --list-policies`
//! prints [`describe_all`].

use super::default_rr::{DefaultScheduler, EtgSource};
use super::hetero::HeteroScheduler;
use super::optimal::{OptimalScheduler, SearchSpace};
use super::Scheduler;
use crate::{Error, Result};

/// Tunables a policy factory may consume.  Every field has the
/// documented default; policies ignore the fields that do not apply to
/// them (e.g. `r0` is meaningless to the optimal search).
#[derive(Debug, Clone)]
pub struct PolicyParams {
    /// Initial topology input rate `R0` for Alg. 2 (hetero; also the
    /// hetero pass inside the default policy's fair-comparison ETG).
    pub r0: f64,
    /// Post-pass refinement on/off (hetero).
    pub refine: bool,
    /// Upper bound on executors per worker, the paper's `k_j` (hetero).
    pub max_tasks_per_machine: usize,
    /// Instance-count bound on the design space (optimal).
    pub max_instances_per_component: usize,
    /// Seed the optimal search with the heuristics' solutions (optimal).
    pub seed_heuristics: bool,
    /// `Some((candidates, seed))` switches the optimal search to
    /// uniform sampling (optimal).
    pub sampled: Option<(usize, u64)>,
    /// Place the minimal user graph instead of the proposed scheduler's
    /// ETG (default policy; the paper's §6.3 fair-comparison protocol
    /// uses the proposed ETG, which is the default here).
    pub minimal_etg: bool,
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams {
            r0: 8.0,
            refine: true,
            max_tasks_per_machine: 32,
            max_instances_per_component: 3,
            seed_heuristics: true,
            sampled: None,
            minimal_etg: false,
        }
    }
}

/// One registry row.
pub struct PolicyInfo {
    /// Canonical name ([`Scheduler::name`] of the built policy).
    pub name: &'static str,
    /// Accepted alternative spellings.
    pub aliases: &'static [&'static str],
    /// One-line description for `--list-policies`.
    pub summary: &'static str,
    factory: fn(&PolicyParams) -> Box<dyn Scheduler>,
}

fn make_hetero(p: &PolicyParams) -> HeteroScheduler {
    HeteroScheduler {
        r0: p.r0,
        max_tasks_per_machine: p.max_tasks_per_machine,
        refine: p.refine,
        ..Default::default()
    }
}

static POLICIES: &[PolicyInfo] = &[
    PolicyInfo {
        name: "hetero",
        aliases: &["proposed"],
        summary: "the paper's heterogeneity-aware scheduler (Alg. 1 + Alg. 2 + refinement)",
        factory: |p| Box::new(make_hetero(p)),
    },
    PolicyInfo {
        name: "default",
        aliases: &["default-rr", "rr"],
        summary: "Storm's Round-Robin baseline (places the proposed ETG unless minimal_etg)",
        factory: |p| {
            let source = if p.minimal_etg {
                EtgSource::Minimal
            } else {
                EtgSource::Proposed(make_hetero(p))
            };
            Box::new(DefaultScheduler { etg: source })
        },
    },
    PolicyInfo {
        name: "optimal",
        aliases: &["exhaustive"],
        summary: "bounded exhaustive/sampled search over the placement design space",
        factory: |p| {
            Box::new(OptimalScheduler {
                max_instances_per_component: p.max_instances_per_component,
                space: match p.sampled {
                    Some((candidates, seed)) => SearchSpace::Sampled { candidates, seed },
                    None => SearchSpace::Exhaustive,
                },
                seed_heuristics: p.seed_heuristics,
                ..Default::default()
            })
        },
    },
];

/// Every registered policy, canonical-name order.
pub fn policies() -> &'static [PolicyInfo] {
    POLICIES
}

/// Canonical policy names.
pub fn names() -> Vec<&'static str> {
    POLICIES.iter().map(|p| p.name).collect()
}

/// Shared row lookup: one registry scan serves both [`canonical`] and
/// [`create`], so neither needs a second fallible lookup.
fn lookup(name: &str) -> Result<&'static PolicyInfo> {
    POLICIES.iter().find(|p| p.name == name || p.aliases.contains(&name)).ok_or_else(|| {
        Error::Config(format!(
            "unknown scheduler policy '{name}' (valid: {})",
            names().join("|")
        ))
    })
}

/// Resolve `name` (canonical or alias) to its canonical name.
pub fn canonical(name: &str) -> Result<&'static str> {
    lookup(name).map(|p| p.name)
}

/// Construct the policy registered under `name` (canonical or alias).
pub fn create(name: &str, params: &PolicyParams) -> Result<Box<dyn Scheduler>> {
    lookup(name).map(|info| (info.factory)(params))
}

/// Multi-line listing for `hstorm schedule --list-policies`.
pub fn describe_all() -> String {
    let mut out = String::from("registered scheduling policies:\n");
    for p in POLICIES {
        let aliases = if p.aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", p.aliases.join(", "))
        };
        out.push_str(&format!("  {:<10}{aliases}\n      {}\n", p.name, p.summary));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_names_and_aliases_resolve() {
        assert_eq!(canonical("hetero").unwrap(), "hetero");
        assert_eq!(canonical("proposed").unwrap(), "hetero");
        assert_eq!(canonical("default-rr").unwrap(), "default");
        assert_eq!(canonical("rr").unwrap(), "default");
        assert_eq!(canonical("exhaustive").unwrap(), "optimal");
        let err = canonical("round-robin").unwrap_err().to_string();
        assert!(err.contains("hetero") && err.contains("optimal"), "{err}");
    }

    #[test]
    fn create_builds_named_policy() {
        for info in policies() {
            let s = create(info.name, &PolicyParams::default()).unwrap();
            assert_eq!(s.name(), info.name);
            for alias in info.aliases {
                assert_eq!(create(alias, &PolicyParams::default()).unwrap().name(), info.name);
            }
        }
        assert!(create("nope", &PolicyParams::default()).is_err());
    }

    #[test]
    fn describe_all_mentions_every_policy() {
        let d = describe_all();
        for info in policies() {
            assert!(d.contains(info.name), "{d}");
        }
    }
}
