//! Elastic-scheduling experiment: static schedule vs reactive controller
//! vs clairvoyant oracle on trace-driven dynamic worlds (the control
//! plane's head-to-head, complementing the paper's one-shot failure
//! experiment in §4.2).
//!
//! Each row replays one named trace on the Table-4 small scenario with
//! the Linear topology and reports, per policy, the share of offered
//! load delivered, SLO-violation seconds, scheduling decisions and tasks
//! migrated.  The expected shape: `static <= reactive <= ~oracle` on
//! delivered load, with the reactive controller taking far fewer
//! decisions than the oracle.

use crate::cluster::scenarios;
use crate::controller::{self, traces, ControllerConfig, Policy};
use crate::topology::benchmarks;
use crate::Result;

use super::{f1, ExperimentResult};

/// Seed used for every trace (reported so runs are reproducible).
pub const SEED: u64 = 42;

pub fn run(fast: bool) -> Result<ExperimentResult> {
    let steps = if fast { 200 } else { 1000 };
    let top = benchmarks::linear();
    let (cluster, db) = scenarios::by_id(1).expect("scenario 1 exists").build();
    let mut out = ExperimentResult::new(
        "elastic",
        format!(
            "trace-driven elastic scheduling ({} steps, seed {SEED}, scenario 1, linear)",
            steps
        ),
        &["trace", "policy", "delivered %", "SLO-s", "reschedules", "migrated"],
    );
    let cfg = ControllerConfig::default();
    for trace_name in ["diurnal", "ramp", "bursty"] {
        let trace = traces::by_name(trace_name, &top, &cluster, steps, SEED)
            .expect("named trace exists");
        let rep = controller::run_trace(&top, &cluster, &db, &trace, &Policy::ALL, &cfg)?;
        for p in &rep.policies {
            out.row(vec![
                trace_name.to_string(),
                p.policy.to_string(),
                f1(p.delivered_pct()),
                f1(p.slo_violation_secs),
                p.reschedules.to_string(),
                p.tasks_migrated.to_string(),
            ]);
        }
    }
    out.note(
        "delivered %: share of the offered load volume actually delivered \
         (capacity-clipped, minus migration downtime)",
    );
    out.note(
        "static pins the day-zero placement; reactive reschedules on breach with \
         cooldown; oracle takes a decision every step",
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pick(rows: &[Vec<String>], trace: &str, policy: &str, col: usize) -> f64 {
        rows.iter()
            .find(|r| r[0] == trace && r[1] == policy)
            .unwrap_or_else(|| panic!("missing row {trace}/{policy}"))[col]
            .parse()
            .unwrap()
    }

    #[test]
    fn elastic_rows_complete() {
        let r = run(true).unwrap();
        assert_eq!(r.rows.len(), 9); // 3 traces x 3 policies
    }

    #[test]
    fn reactive_beats_static_everywhere() {
        let r = run(true).unwrap();
        for trace in ["diurnal", "ramp", "bursty"] {
            let st = pick(&r.rows, trace, "static", 2);
            let re = pick(&r.rows, trace, "reactive", 2);
            assert!(re > st, "{trace}: reactive {re}% <= static {st}%");
        }
    }

    #[test]
    fn reactive_decides_far_less_than_oracle() {
        let r = run(true).unwrap();
        for trace in ["diurnal", "ramp", "bursty"] {
            let re = pick(&r.rows, trace, "reactive", 4);
            let or = pick(&r.rows, trace, "oracle", 4);
            assert!(re < or, "{trace}: reactive took {re} decisions vs oracle {or}");
        }
    }
}
