//! Integration: the PJRT-compiled AOT scorer must agree with the native
//! evaluator on identical inputs, and the full schedulers must produce
//! the same results through either backend.
//!
//! Requires `artifacts/` (run `make artifacts` first); tests are skipped
//! with a notice if the artifacts are missing so `cargo test` stays
//! usable before the first build.

use hstorm::cluster::presets;
use hstorm::predict::Placement;
use hstorm::runtime::scorer::{NativeScorer, PjRtScorer, PlacementScorer};
use hstorm::runtime::PjRtRuntime;
use hstorm::scheduler::hetero::HeteroScheduler;
use hstorm::scheduler::optimal::OptimalScheduler;
use hstorm::scheduler::{Problem, ScheduleRequest, Scheduler};
use hstorm::topology::benchmarks;
use hstorm::util::rng::Rng;

fn runtime() -> Option<PjRtRuntime> {
    match PjRtRuntime::cpu_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn random_placement(rng: &mut Rng, n_comp: usize, n_machines: usize) -> Placement {
    let mut p = Placement::empty(n_comp, n_machines);
    for c in 0..n_comp {
        let k = rng.range(1, 3);
        for _ in 0..k {
            p.x[c][rng.range(0, n_machines - 1)] += 1;
        }
    }
    p
}

#[test]
fn pjrt_matches_native_on_random_placements() {
    let Some(rt) = runtime() else { return };
    let (cluster, db) = presets::paper_cluster();
    for top in benchmarks::all() {
        let pjrt = PjRtScorer::new(&rt, &top, &cluster, &db).unwrap();
        let native = NativeScorer::new(&top, &cluster, &db).unwrap();
        let mut rng = Rng::new(0xABCD);
        let n = top.n_components();
        let m = cluster.n_machines();
        let placements: Vec<Placement> =
            (0..64).map(|_| random_placement(&mut rng, n, m)).collect();
        let rates: Vec<f64> = (0..64).map(|_| rng.range_f64(1.0, 400.0)).collect();
        let got = pjrt.score_batch(&placements, &rates).unwrap();
        let want = native.score_batch(&placements, &rates).unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.feasible, w.feasible, "{} case {i}: feasibility", top.name);
            let rel = (g.throughput - w.throughput).abs() / w.throughput.max(1.0);
            assert!(rel < 1e-4, "{} case {i}: thpt {} vs {}", top.name, g.throughput, w.throughput);
            for (mu, (gu, wu)) in g.util.iter().zip(&w.util).enumerate() {
                assert!(
                    (gu - wu).abs() < 0.05 + wu.abs() * 1e-4,
                    "{} case {i} machine {mu}: util {gu} vs {wu}",
                    top.name
                );
            }
            for (c, (gi, wi)) in g.ir_comp.iter().zip(&w.ir_comp).enumerate() {
                assert!(
                    (gi - wi).abs() < 0.01 + wi.abs() * 1e-4,
                    "{} case {i} comp {c}: ir {gi} vs {wi}",
                    top.name
                );
            }
        }
    }
}

#[test]
fn pjrt_single_candidate_path() {
    let Some(rt) = runtime() else { return };
    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::linear();
    let pjrt = PjRtScorer::new(&rt, &top, &cluster, &db).unwrap();
    let native = NativeScorer::new(&top, &cluster, &db).unwrap();
    let mut p = Placement::empty(top.n_components(), cluster.n_machines());
    for c in 0..top.n_components() {
        p.x[c][c % 3] = 1;
    }
    let g = pjrt.score_one(&p, 100.0).unwrap();
    let w = native.score_one(&p, 100.0).unwrap();
    assert_eq!(g.feasible, w.feasible);
    assert!((g.throughput - w.throughput).abs() < 0.05);
}

#[test]
fn hetero_schedule_same_via_pjrt_and_native() {
    let Some(rt) = runtime() else { return };
    let (cluster, db) = presets::paper_cluster();
    let req = ScheduleRequest::max_throughput();
    for top in benchmarks::micro() {
        let hs = HeteroScheduler::default();
        let problem = Problem::new(&top, &cluster, &db).unwrap();
        let native = hs.schedule(&problem, &req).unwrap();
        assert_eq!(native.provenance.backend, "native");
        let pjrt_scorer = PjRtScorer::new(&rt, &top, &cluster, &db).unwrap();
        let pjrt = hs.schedule_with_scorer(&problem, &req, &pjrt_scorer).unwrap();
        assert_eq!(pjrt.provenance.backend, "pjrt");
        assert_eq!(
            pjrt.placement.counts(),
            native.placement.counts(),
            "{}: instance counts differ between backends",
            top.name
        );
        let rel = (pjrt.rate - native.rate).abs() / native.rate;
        assert!(rel < 1e-3, "{}: rate {} vs {}", top.name, pjrt.rate, native.rate);
    }
}

#[test]
fn optimal_search_via_pjrt_matches_native() {
    let Some(rt) = runtime() else { return };
    let (cluster, db) = presets::paper_cluster();
    let top = benchmarks::rolling_count();
    let req = ScheduleRequest::max_throughput();
    let os = OptimalScheduler { max_instances_per_component: 2, ..Default::default() };
    let problem = Problem::new(&top, &cluster, &db).unwrap();
    let native = os.schedule(&problem, &req).unwrap();
    let scorer = PjRtScorer::new(&rt, &top, &cluster, &db).unwrap();
    let pjrt = os.schedule_with_scorer(&problem, &req, &scorer).unwrap();
    let rel = (pjrt.rate - native.rate).abs() / native.rate;
    assert!(rel < 1e-3, "rate {} vs {}", pjrt.rate, native.rate);
    assert_eq!(pjrt.placement.counts(), native.placement.counts());
}

#[test]
fn work_kernel_runs() {
    let Some(rt) = runtime() else { return };
    let wk = rt.work_kernel().unwrap();
    let out = wk.run(&vec![0.25f32; hstorm::runtime::dims::WORK_N]).unwrap();
    assert_eq!(out.len(), hstorm::runtime::dims::WORK_N);
    assert!(out.iter().all(|v| v.is_finite()));
    // burn() chains invocations without error
    wk.burn(10).unwrap();
}
