//! Lightweight metrics registry used by the engine and the CLI.
//!
//! Counters and gauges are atomic and cheap to update from the tokio hot
//! path; snapshots are taken lock-free.  This replaces Storm's UI /
//! `get_execute_ms_avg()` surface the paper's profiling step reads.
//!
//! The registry also owns the observability layer's named
//! [`Histogram`]s and its event [`Journal`] (see [`crate::obs`]), so
//! engine counters and scheduler/controller telemetry share one
//! snapshot/export path.

mod meanstat_core;
pub(crate) mod sync_shim;

pub use meanstat_core::MeanStat;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::obs::{Histogram, Journal};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Instantaneous value (stored as micro-units to keep it atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store((v * 1e6) as i64, Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e6
    }
}

/// Named metric registry shared across engine actors.  The maps are
/// `BTreeMap`s, not `HashMap`s: iteration order feeds [`snapshot`]
/// (and through it every serialized export), and ordered maps keep
/// that deterministic by construction rather than by a trailing sort.
///
/// [`snapshot`]: Registry::snapshot
#[derive(Debug, Default, Clone)]
pub struct Registry {
    counters: Arc<RwLock<BTreeMap<String, Arc<Counter>>>>,
    gauges: Arc<RwLock<BTreeMap<String, Arc<Gauge>>>>,
    means: Arc<RwLock<BTreeMap<String, Arc<MeanStat>>>>,
    hists: Arc<RwLock<BTreeMap<String, Arc<Histogram>>>>,
    journal: Arc<Journal>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return c.clone();
        }
        self.counters
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Counter::default()))
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        if let Some(g) = self.gauges.read().unwrap().get(name) {
            return g.clone();
        }
        self.gauges
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Gauge::default()))
            .clone()
    }

    pub fn mean(&self, name: &str) -> Arc<MeanStat> {
        if let Some(m) = self.means.read().unwrap().get(name) {
            return m.clone();
        }
        self.means
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(MeanStat::default()))
            .clone()
    }

    /// Get or create a named log-bucketed histogram (see
    /// [`crate::obs::Histogram`]).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        if let Some(h) = self.hists.read().unwrap().get(name) {
            return h.clone();
        }
        self.hists
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(Histogram::default()))
            .clone()
    }

    /// The registry's structured event journal.
    pub fn journal(&self) -> &Journal {
        &self.journal
    }

    /// Snapshot all metrics as `(name, value)` rows, sorted by name
    /// and duplicate-free.  Histograms expand to `.count`, `.mean`,
    /// `.p50`, `.p95`, `.p99` and `.max` rows.  When the same name is
    /// registered under several metric kinds, the first in
    /// counter > gauge > mean > histogram priority wins (the sort is
    /// stable, so insertion order below is the tie-break).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> = Vec::new();
        for (k, v) in self.counters.read().unwrap().iter() {
            rows.push((k.clone(), v.get() as f64));
        }
        for (k, v) in self.gauges.read().unwrap().iter() {
            rows.push((k.clone(), v.get()));
        }
        for (k, v) in self.means.read().unwrap().iter() {
            rows.push((format!("{k}.mean"), v.mean().unwrap_or(0.0)));
        }
        for (k, v) in self.hists.read().unwrap().iter() {
            rows.push((format!("{k}.count"), v.count() as f64));
            rows.push((format!("{k}.mean"), v.mean()));
            rows.push((format!("{k}.p50"), v.quantile(0.50)));
            rows.push((format!("{k}.p95"), v.quantile(0.95)));
            rows.push((format!("{k}.p99"), v.quantile(0.99)));
            rows.push((format!("{k}.max"), v.max()));
        }
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows.dedup_by(|a, b| a.0 == b.0);
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_inc() {
        let r = Registry::new();
        let c = r.counter("tuples");
        c.inc();
        c.add(4);
        assert_eq!(r.counter("tuples").get(), 5);
    }

    #[test]
    fn gauge_roundtrip() {
        let r = Registry::new();
        r.gauge("util").set(73.25);
        assert!((r.gauge("util").get() - 73.25).abs() < 1e-5);
    }

    #[test]
    fn mean_stat() {
        let m = MeanStat::default();
        assert!(m.mean().is_none());
        m.observe(0.010);
        m.observe(0.020);
        assert!((m.mean().unwrap() - 0.015).abs() < 1e-6);
        m.reset();
        assert!(m.mean().is_none());
    }

    #[test]
    fn mean_stat_keeps_sub_microsecond_observations() {
        // 0.3 µs observations: micro-unit truncation recorded 0 for
        // every one (while still counting them), collapsing the mean
        // to zero; nanosecond accumulation preserves them exactly
        let m = MeanStat::default();
        for _ in 0..10 {
            m.observe(0.3e-6);
        }
        assert_eq!(m.count(), 10);
        assert!((m.mean().unwrap() - 0.3e-6).abs() < 1e-12, "{:?}", m.mean());
        // microsecond-scale values survive unchanged
        let m2 = MeanStat::default();
        m2.observe(1.6e-6);
        assert!((m2.mean().unwrap() - 1.6e-6).abs() < 1e-12, "{:?}", m2.mean());
    }

    #[test]
    fn mean_stat_reset_is_coherent_under_concurrency() {
        // regression: reset used to clear sum and count in two
        // independent stores, so an observe landing between them left
        // a half-applied sample skewing every later mean.  With the
        // gate, any surviving (sum, count) pair must satisfy
        // sum == count * value exactly.
        let m = Arc::new(MeanStat::default());
        let value = 0.5; // 5e8 ns: exactly representable, no rounding
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let m = m.clone();
                scope.spawn(move || {
                    for _ in 0..2_000 {
                        m.observe(value);
                    }
                });
            }
            let m = m.clone();
            scope.spawn(move || {
                for _ in 0..500 {
                    m.reset();
                    if let Some(mean) = m.mean() {
                        assert!((mean - value).abs() < 1e-12, "torn reset: mean {mean}");
                    }
                }
            });
        });
        if let Some(mean) = m.mean() {
            assert!((mean - value).abs() < 1e-12, "torn reset: final mean {mean}");
        }
    }

    #[test]
    fn snapshot_sorted_and_duplicate_free() {
        let r = Registry::new();
        r.counter("b").inc();
        r.gauge("a").set(1.0);
        // same name registered as a counter AND a gauge: one row
        // survives, and the counter (pushed first) wins
        r.counter("dup").add(7);
        r.gauge("dup").set(99.0);
        r.histogram("h").observe(2.0);
        let snap = r.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "snapshot not sorted");
        sorted.dedup();
        assert_eq!(names.len(), sorted.len(), "snapshot has duplicate names");
        let dup = snap.iter().find(|(n, _)| n == "dup").unwrap();
        assert_eq!(dup.1, 7.0, "counter must win the name collision");
    }

    #[test]
    fn histogram_rows_expand_in_snapshot() {
        let r = Registry::new();
        let h = r.histogram("lat_s");
        h.observe(0.010);
        h.observe(0.030);
        let snap = r.snapshot();
        let get = |suffix: &str| {
            snap.iter()
                .find(|(n, _)| n == &format!("lat_s.{suffix}"))
                .unwrap_or_else(|| panic!("missing lat_s.{suffix}"))
                .1
        };
        assert_eq!(get("count"), 2.0);
        assert!((get("mean") - 0.020).abs() < 1e-12);
        assert_eq!(get("max"), 0.030);
        assert!(get("p50") >= 0.010 && get("p50") <= 0.030);
        assert!(get("p99") >= get("p50"));
    }

    #[test]
    fn shared_across_clones() {
        let r = Registry::new();
        let r2 = r.clone();
        r.counter("x").inc();
        assert_eq!(r2.counter("x").get(), 1);
        r.journal().record(crate::obs::Event::AdmissionGranted { tenant: "t".into(), step: 1 });
        assert_eq!(r2.journal().len(), 1, "journal shared across clones");
    }
}
