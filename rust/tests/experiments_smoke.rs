//! Smoke the full experiment harness in fast mode: every figure/table
//! regenerator must produce a well-formed result with the paper's
//! qualitative shape (who wins, roughly by how much).

use hstorm::experiments::{complexity, fig10, fig3, fig6, fig7, fig8, fig9};

fn pct(cell: &str) -> f64 {
    cell.trim_end_matches('%').parse().unwrap()
}

#[test]
fn fig3_motivation_shape() {
    let r = fig3::run(true).unwrap();
    assert_eq!(r.rows.len(), 3);
    // optimal never loses; the gap is remarkable on at least one topology
    let mut max_gap = 0.0f64;
    for row in &r.rows {
        let gap = pct(&row[3]);
        assert!(gap >= -0.1, "optimal lost on {}", row[0]);
        max_gap = max_gap.max(gap);
    }
    assert!(max_gap > 20.0, "motivation gap only {max_gap}%");
}

#[test]
fn fig6_accuracy_headline() {
    let r = fig6::run(true).unwrap();
    // the accuracy note must report > 90% mean accuracy (paper: > 92%)
    let note = r.notes.iter().find(|n| n.contains("mean accuracy")).expect("accuracy note");
    let acc: f64 = note
        .rsplit_once("= ")
        .unwrap()
        .1
        .trim_end_matches('%')
        .parse()
        .unwrap();
    assert!(acc > 90.0, "prediction accuracy {acc}%");
}

#[test]
fn fig7_reports_both_topologies() {
    let r = fig7::run(true).unwrap();
    assert!(r.rows.iter().any(|row| row[0] == "rolling-count"));
    assert!(r.rows.iter().any(|row| row[0] == "unique-visitor"));
    // exactly one optimal marker per topology
    for t in ["rolling-count", "unique-visitor"] {
        let optimal_marks = r
            .rows
            .iter()
            .filter(|row| row[0] == t && row[3].contains("optimal"))
            .count();
        assert_eq!(optimal_marks, 1, "{t}: {optimal_marks} optimal markers");
    }
}

#[test]
fn fig8_ordering_holds() {
    let r = fig8::run(true).unwrap();
    assert_eq!(r.rows.len(), 9);
    for chunk in r.rows.chunks(3) {
        let def: f64 = chunk[0][3].parse().unwrap(); // sim column
        let ours: f64 = chunk[1][3].parse().unwrap();
        let opt: f64 = chunk[2][3].parse().unwrap();
        assert!(ours >= def * 0.999, "{}: proposed sim < default sim", chunk[0][0]);
        assert!(opt >= ours * 0.999, "{}: optimal sim < proposed sim", chunk[0][0]);
    }
}

#[test]
fn fig9_has_all_cells() {
    let r = fig9::run(true).unwrap();
    assert_eq!(r.rows.len(), 9);
    for row in &r.rows {
        assert_eq!(row.len(), 6); // topology, scheduler, 3 machines, total
    }
}

#[test]
fn fig10_and_table5_consistent() {
    let cells = fig10::cells(true).unwrap();
    assert_eq!(cells.len(), 6); // fast: 2 scenarios x 3 topologies
    for c in &cells {
        assert!(c.ours_thpt >= c.def_thpt, "scenario {} {}", c.scenario, c.topology);
        assert!(c.tasks >= 4);
    }
    let t5 = fig10::table5(true).unwrap();
    assert_eq!(t5.rows.len(), 2);
}

#[test]
fn complexity_counts_match_paper() {
    let r = complexity::run(true).unwrap();
    let row = r.rows.iter().find(|row| row[0].contains("count vectors")).unwrap();
    assert!(row[1].contains("27405"), "{}", row[1]);
}

#[test]
fn results_serialize_to_json() {
    let r = fig3::run(true).unwrap();
    let v = r.to_json();
    let text = hstorm::util::json::to_string_pretty(&v);
    let back = hstorm::util::json::parse(&text).unwrap();
    assert_eq!(back.str_field("id").unwrap(), "fig3");
}
