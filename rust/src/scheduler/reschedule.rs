//! Failure rescheduling (paper §4.2 & §8): "in case of machine failure,
//! a slow scheduler leads the cluster to tuple overloading state...
//! during the execution, by any change in the cluster state this
//! algorithm can be used to recalculate the new number of instances and
//! their suitable assignment."
//!
//! [`after_failure`] removes the failed worker from the cluster and
//! re-runs the heterogeneity-aware scheduler on the survivors — the
//! whole point being that it finishes in microseconds-to-milliseconds
//! (see `benches/scheduler_micro.rs`), where the exhaustive comparator
//! would strand the cluster for hours.

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::topology::Topology;
use crate::{Error, Result};

use super::hetero::HeteroScheduler;
use super::{Schedule, Scheduler};

/// Outcome of a failure-rescheduling step.
#[derive(Debug, Clone)]
pub struct Reschedule {
    /// The surviving cluster (failed machine removed).
    pub cluster: Cluster,
    /// The recomputed schedule on the survivors.
    pub schedule: Schedule,
    /// Throughput retained vs the pre-failure schedule (1.0 = all).
    pub retained: f64,
}

/// Remove `failed` (by machine name) and recompute the schedule.
pub fn after_failure(
    top: &Topology,
    cluster: &Cluster,
    profiles: &ProfileDb,
    before: &Schedule,
    failed: &str,
    scheduler: &HeteroScheduler,
) -> Result<Reschedule> {
    let idx = cluster
        .machines
        .iter()
        .position(|m| m.name == failed)
        .ok_or_else(|| Error::Cluster(format!("unknown machine '{failed}'")))?;
    if cluster.n_machines() == 1 {
        return Err(Error::Cluster("cannot lose the only worker".into()));
    }
    let mut survivors = cluster.clone();
    survivors.machines.remove(idx);
    survivors.name = format!("{}-minus-{failed}", cluster.name);
    survivors.validate()?;

    let schedule = scheduler.schedule(top, &survivors, profiles)?;
    let retained = if before.eval.throughput > 0.0 {
        schedule.eval.throughput / before.eval.throughput
    } else {
        1.0
    };
    Ok(Reschedule { cluster: survivors, schedule, retained })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::scheduler::Scheduler;
    use crate::topology::benchmarks;

    #[test]
    fn reschedule_survives_machine_loss() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&top, &cluster, &db).unwrap();
        let r = after_failure(&top, &cluster, &db, &before, "i3-0", &hs).unwrap();
        assert_eq!(r.cluster.n_machines(), 2);
        assert!(r.schedule.eval.feasible);
        // losing 1 of 3 workers keeps a meaningful share of throughput
        assert!(r.retained > 0.3, "retained only {:.2}", r.retained);
        assert!(r.retained < 1.0, "throughput should drop after failure");
        // no instance may remain on the failed machine (shape shrank)
        assert_eq!(r.schedule.placement.n_machines(), 2);
    }

    #[test]
    fn losing_the_strongest_costs_more() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&top, &cluster, &db).unwrap();
        // Table 3 makes the Pentium the per-tuple fastest worker here
        let lose_fast = after_failure(&top, &cluster, &db, &before, "pentium-0", &hs).unwrap();
        let lose_slow = after_failure(&top, &cluster, &db, &before, "i3-0", &hs).unwrap();
        assert!(
            lose_fast.retained <= lose_slow.retained + 1e-9,
            "losing the fast worker ({}) should cost >= losing the slow one ({})",
            lose_fast.retained,
            lose_slow.retained
        );
    }

    #[test]
    fn unknown_machine_rejected() {
        let (cluster, db) = presets::paper_cluster();
        let top = benchmarks::linear();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&top, &cluster, &db).unwrap();
        assert!(after_failure(&top, &cluster, &db, &before, "ghost", &hs).is_err());
    }

    #[test]
    fn cannot_lose_last_worker() {
        let (cluster, db) = presets::homogeneous_cluster(1);
        let top = benchmarks::linear();
        let hs = HeteroScheduler::default();
        let before = hs.schedule(&top, &cluster, &db).unwrap();
        let name = cluster.machines[0].name.clone();
        assert!(after_failure(&top, &cluster, &db, &before, &name, &hs).is_err());
    }

    #[test]
    fn cascading_failures() {
        // lose machines one by one in a Table-4 small scenario; every
        // intermediate schedule must stay feasible
        use crate::cluster::scenarios;
        let (mut cluster, db) = scenarios::by_id(1).unwrap().build();
        let top = benchmarks::diamond();
        let hs = HeteroScheduler::default();
        let mut schedule = hs.schedule(&top, &cluster, &db).unwrap();
        for _ in 0..3 {
            let victim = cluster.machines[0].name.clone();
            let r = after_failure(&top, &cluster, &db, &schedule, &victim, &hs).unwrap();
            assert!(r.schedule.eval.feasible);
            cluster = r.cluster;
            schedule = r.schedule;
        }
        assert_eq!(cluster.n_machines(), 3);
    }
}
