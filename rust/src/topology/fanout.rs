//! Shared eq.-6 shuffle fan-out primitives.
//!
//! Both execution substrates — the discrete-event simulator
//! ([`crate::simulator::event`]) and the threaded engine
//! ([`crate::engine`]) — propagate tuples along the DAG with the same
//! two pieces of deterministic routing state:
//!
//! * a **fractional-α accumulator** ([`AlphaAcc`]): each processed
//!   input tuple adds the edge's α (`rate_gain` ratio) to a carry and
//!   emits `floor(carry)` downstream tuples, so a non-integral α like
//!   1.5 alternates 1, 2, 1, 2, … and the long-run emission rate is
//!   exactly α × the input rate (eq. 6);
//! * a **shuffle-grouping cursor** ([`ShuffleCursor`]): emissions
//!   round-robin across the consumer component's task instances, the
//!   engine-default shuffle grouping of Storm.
//!
//! The two call sites used to carry independent copies of this logic;
//! they now share these types, and the unit tests below pin the exact
//! emission sequences both sites produced before the dedupe.

/// Fractional-α emission accumulator (eq. 6).
///
/// `step` is the per-tuple form both call sites historically used;
/// `step_n` is the batched form the ring dataplane uses, implemented
/// as `n` repeated steps so a batch of `n` tuples emits *bit-for-bit*
/// the same count as `n` individual tuples would (a single
/// `acc += alpha * n` would round differently).
#[derive(Debug, Clone, Copy, Default)]
pub struct AlphaAcc {
    acc: f64,
}

impl AlphaAcc {
    pub fn new() -> Self {
        AlphaAcc { acc: 0.0 }
    }

    /// Account one processed input tuple; returns how many tuples to
    /// emit downstream.
    #[inline]
    pub fn step(&mut self, alpha: f64) -> usize {
        self.acc += alpha;
        let emit = self.acc as usize;
        self.acc -= emit as f64;
        emit
    }

    /// Account `n` processed input tuples; returns the total number of
    /// tuples to emit downstream.  Identical to summing `n` calls to
    /// [`AlphaAcc::step`].
    #[inline]
    pub fn step_n(&mut self, alpha: f64, n: u64) -> u64 {
        let mut total = 0u64;
        for _ in 0..n {
            total += self.step(alpha) as u64;
        }
        total
    }
}

/// Round-robin shuffle-grouping cursor over a component's `n_inst`
/// task instances.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShuffleCursor {
    cursor: usize,
}

impl ShuffleCursor {
    pub fn new() -> Self {
        ShuffleCursor { cursor: 0 }
    }

    /// Pick the instance slot for the next emission.  `n_inst` must be
    /// non-zero (callers skip components with no placed instances).
    #[inline]
    pub fn next_slot(&mut self, n_inst: usize) -> usize {
        let slot = self.cursor % n_inst;
        self.cursor = self.cursor.wrapping_add(1);
        slot
    }

    /// Distribute `emit` consecutive emissions over `n_inst` instances,
    /// appending `(slot, count)` pairs to `out` (at most `n_inst`
    /// pairs, slots in cursor order).  Aggregates exactly what `emit`
    /// calls to [`ShuffleCursor::next_slot`] would route, advancing the
    /// cursor identically.
    pub fn split(&mut self, emit: u64, n_inst: usize, out: &mut Vec<(usize, u64)>) {
        let n = n_inst as u64;
        for k in 0..emit.min(n) {
            let slot = self.cursor.wrapping_add(k as usize) % n_inst;
            // emissions k, k+n, k+2n, … < emit land on this slot
            let count = (emit - k).div_ceil(n);
            out.push((slot, count));
        }
        self.cursor = self.cursor.wrapping_add(emit as usize);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The original `simulator/event.rs` fan-out, verbatim: per-task
    /// accumulator, cursors indexed by downstream *position*.
    fn sim_site_reference(alphas: &[f64], n_tuples: usize, insts: &[usize]) -> Vec<(usize, usize)> {
        let mut acc = 0.0f64;
        let mut cursors = vec![0usize; insts.len()];
        let mut seq = Vec::new();
        for _ in 0..n_tuples {
            acc += alphas[0];
            let emit = acc as usize;
            acc -= emit as f64;
            if emit > 0 {
                for di in 0..insts.len() {
                    for _ in 0..emit {
                        let n_inst = insts[di];
                        let slot = cursors[di] % n_inst;
                        cursors[di] = cursors[di].wrapping_add(1);
                        seq.push((di, slot));
                    }
                }
            }
        }
        seq
    }

    /// The original `engine/worker.rs` fan-out, verbatim: per-machine
    /// accumulator and cursors keyed by downstream component id.
    fn engine_site_reference(
        alphas: &[f64],
        n_tuples: usize,
        insts: &[usize],
    ) -> Vec<(usize, usize)> {
        let mut acc = 0.0f64;
        let mut cursors = vec![0usize; insts.len()];
        let mut seq = Vec::new();
        for _ in 0..n_tuples {
            acc += alphas[0];
            let emit = acc as usize;
            acc -= emit as f64;
            if emit > 0 {
                for (d, &n_inst) in insts.iter().enumerate() {
                    for _ in 0..emit {
                        if n_inst == 0 {
                            continue;
                        }
                        let slot = cursors[d] % n_inst;
                        cursors[d] = cursors[d].wrapping_add(1);
                        seq.push((d, slot));
                    }
                }
            }
        }
        seq
    }

    /// Drive the shared helper the way both refactored call sites do.
    fn helper_site(alphas: &[f64], n_tuples: usize, insts: &[usize]) -> Vec<(usize, usize)> {
        let mut acc = AlphaAcc::new();
        let mut cursors = vec![ShuffleCursor::new(); insts.len()];
        let mut seq = Vec::new();
        for _ in 0..n_tuples {
            let emit = acc.step(alphas[0]);
            if emit > 0 {
                for (d, &n_inst) in insts.iter().enumerate() {
                    for _ in 0..emit {
                        if n_inst == 0 {
                            continue;
                        }
                        seq.push((d, cursors[d].next_slot(n_inst)));
                    }
                }
            }
        }
        seq
    }

    #[test]
    fn both_call_sites_emit_identical_sequences() {
        // alphas the paper topologies actually use, plus awkward ones
        for &alpha in &[0.5, 1.0, 1.5, 2.0, 0.3, 1.0 / 3.0, 2.7] {
            for &insts in &[&[1usize, 1][..], &[2, 3][..], &[4, 1, 2][..]] {
                let a = [alpha];
                let sim = sim_site_reference(&a, 500, insts);
                let eng = engine_site_reference(&a, 500, insts);
                let shared = helper_site(&a, 500, insts);
                assert_eq!(sim, eng, "alpha={alpha} insts={insts:?}");
                assert_eq!(sim, shared, "alpha={alpha} insts={insts:?}");
            }
        }
    }

    #[test]
    fn step_n_equals_repeated_step() {
        let mut rng = Rng::new(0xFA11);
        for _ in 0..50 {
            let alpha = rng.f64() * 3.0;
            let n = (rng.f64() * 400.0) as u64 + 1;
            let mut a = AlphaAcc::new();
            let mut b = AlphaAcc::new();
            let batched = a.step_n(alpha, n);
            let mut singles = 0u64;
            for _ in 0..n {
                singles += b.step(alpha) as u64;
            }
            assert_eq!(batched, singles, "alpha={alpha} n={n}");
            assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "carry must match exactly");
        }
    }

    #[test]
    fn split_equals_repeated_next_slot() {
        let mut rng = Rng::new(0x5EED_1234);
        for _ in 0..200 {
            let n_inst = (rng.f64() * 7.0) as usize + 1;
            let emit = (rng.f64() * 50.0) as u64;
            let mut a = ShuffleCursor::new();
            let mut b = ShuffleCursor::new();
            // desync the cursors from zero first, identically
            let warm = (rng.f64() * 9.0) as usize;
            for _ in 0..warm {
                a.next_slot(n_inst);
                b.next_slot(n_inst);
            }
            let mut split = Vec::new();
            a.split(emit, n_inst, &mut split);
            let mut per_slot = vec![0u64; n_inst];
            for &(slot, count) in &split {
                per_slot[slot] += count;
            }
            let mut expect = vec![0u64; n_inst];
            let mut order = Vec::new();
            for _ in 0..emit {
                let s = b.next_slot(n_inst);
                expect[s] += 1;
                if !order.contains(&s) {
                    order.push(s);
                }
            }
            assert_eq!(per_slot, expect, "emit={emit} n_inst={n_inst}");
            assert_eq!(
                split.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
                order,
                "slot order must follow the cursor"
            );
            assert_eq!(a.cursor, b.cursor, "cursors must advance identically");
        }
    }

    #[test]
    fn integral_alpha_emits_exactly() {
        let mut acc = AlphaAcc::new();
        for _ in 0..100 {
            assert_eq!(acc.step(2.0), 2);
        }
        assert_eq!(acc.step_n(1.0, 64), 64);
    }
}
