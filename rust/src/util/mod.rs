//! In-tree utility layer.
//!
//! This image builds fully offline from a fixed vendor set (xla + anyhow
//! and their transitive deps); the usual ecosystem crates (serde, clap,
//! criterion, proptest, rand, tokio) are not available.  The pieces of
//! them this project needs are implemented here, small and tested:
//!
//! * [`json`]  — JSON parse/serialize (configs, `artifacts/dims.json`).
//! * [`cli`]   — flag/positional argument parsing for the launcher.
//! * [`rng`]   — SplitMix64 PRNG (deterministic sampling & workloads).
//! * [`bench`] — wall-clock benchmark harness used by `benches/*`.
//! * [`prop`]  — minimal property-testing loop (randomized inputs with
//!   seed reporting on failure).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
