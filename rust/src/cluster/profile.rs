//! Profile database: the paper's pre-process profiling data (§5.2).
//!
//! For every `(task_type, machine_type)` pair the DB holds
//!
//! * `e`   — average per-tuple execution cost, in **%·s/tuple**: one
//!   instance processing `IR` tuples/s occupies `e * IR` percent of the
//!   machine's CPU budget (paper eq. 5 first term; Table 3 values).
//! * `met` — miscellaneous execution time of Storm for the task on that
//!   machine, in percent (eq. 5 second term; a constant per pair).
//!
//! The units interpretation is documented in DESIGN.md §5: with Table 3's
//! `e = 0.1915` for highCompute on Machine 1, a single instance saturates
//! one worker at `(100 - MET) / 0.1915 ≈ 500` tuples/s — consistent with
//! the paper's Fig. 6 rate axis.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Cost of one task instance of some type on some machine type.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskProfile {
    /// Per-tuple execution cost, %·s/tuple.
    pub e: f64,
    /// Miscellaneous per-instance overhead, %.
    pub met: f64,
}

/// `(task_type, machine_type) -> TaskProfile` with helpful errors.
/// Ordered maps: `task_types` and coverage errors iterate the entries,
/// so their output order must not depend on hasher state.
#[derive(Debug, Clone, Default)]
pub struct ProfileDb {
    entries: BTreeMap<String, BTreeMap<String, TaskProfile>>,
}

impl ProfileDb {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, task_type: &str, machine_type: &str, p: TaskProfile) {
        self.entries
            .entry(task_type.to_string())
            .or_default()
            .insert(machine_type.to_string(), p);
    }

    pub fn get(&self, task_type: &str, machine_type: &str) -> Result<TaskProfile> {
        self.entries
            .get(task_type)
            .and_then(|m| m.get(machine_type))
            .copied()
            .ok_or_else(|| Error::MissingProfile {
                task_type: task_type.to_string(),
                machine_type: machine_type.to_string(),
            })
    }

    /// Predicted TCU (eq. 5) of one instance at input rate `ir` (tuple/s).
    pub fn tcu(&self, task_type: &str, machine_type: &str, ir: f64) -> Result<f64> {
        let p = self.get(task_type, machine_type)?;
        Ok(p.e * ir + p.met)
    }

    pub fn task_types(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.entries.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Verify the DB covers every `(component, machine type)` pair a
    /// topology/cluster combination will ask for.  On failure the error
    /// lists **every** missing pair (component with its task type ×
    /// machine type), not just the first, so a half-filled profile table
    /// is fixable in one round trip.
    pub fn check_coverage(
        &self,
        top: &crate::topology::Topology,
        cluster: &crate::cluster::Cluster,
    ) -> Result<()> {
        let mut missing: Vec<String> = Vec::new();
        for c in &top.components {
            for t in &cluster.types {
                if self.get(&c.task_type, &t.name).is_err() {
                    missing.push(format!("({} [task '{}'], {})", c.name, c.task_type, t.name));
                }
            }
        }
        if missing.is_empty() {
            Ok(())
        } else {
            Err(Error::Cluster(format!(
                "profile db misses {} (component, machine type) pair{}: {}",
                missing.len(),
                if missing.len() == 1 { "" } else { "s" },
                missing.join(", ")
            )))
        }
    }

    /// [`check_coverage`](Self::check_coverage) for a whole multi-tenant
    /// workload sharing this profile db: verify every tenant's
    /// `(component, machine type)` demand in **one pass**, reporting all
    /// missing `(tenant, component, machine type)` triples at once.
    /// Tenants sharing this db also share its gaps, so each missing
    /// `(task type, machine type)` pair is listed once with every
    /// affected `tenant/component` named — not repeated per tenant.
    pub fn check_coverage_many(
        &self,
        tenants: &[(&str, &crate::topology::Topology)],
        cluster: &crate::cluster::Cluster,
    ) -> Result<()> {
        // (task_type, machine_type) -> tenant/component demand sites
        let mut missing: Vec<((String, String), Vec<String>)> = Vec::new();
        for (tenant, top) in tenants {
            for c in &top.components {
                for t in &cluster.types {
                    if self.get(&c.task_type, &t.name).is_ok() {
                        continue;
                    }
                    let key = (c.task_type.clone(), t.name.clone());
                    let site = format!("{tenant}/{}", c.name);
                    match missing.iter_mut().find(|(k, _)| *k == key) {
                        Some((_, sites)) => {
                            if !sites.contains(&site) {
                                sites.push(site);
                            }
                        }
                        None => missing.push((key, vec![site])),
                    }
                }
            }
        }
        if missing.is_empty() {
            return Ok(());
        }
        let triples: usize = missing.iter().map(|(_, s)| s.len()).sum();
        let lines: Vec<String> = missing
            .iter()
            .map(|((tt, mt), sites)| format!("(task '{tt}', {mt}) wanted by {}", sites.join(", ")))
            .collect();
        Err(Error::Cluster(format!(
            "profile db misses {} (tenant, component, machine type) triple{} across {} pair{}: {}",
            triples,
            if triples == 1 { "" } else { "s" },
            missing.len(),
            if missing.len() == 1 { "" } else { "s" },
            lines.join("; ")
        )))
    }

    /// Per-machine expanded tables for the AOT scorer: `e_m[c][m]` and
    /// `met_m[c][m]` (the Rust side does the type gather so the kernel
    /// sees dense tables; see python/compile/kernels/score.py).
    pub fn expand(
        &self,
        top: &crate::topology::Topology,
        cluster: &crate::cluster::Cluster,
    ) -> Result<(Vec<Vec<f64>>, Vec<Vec<f64>>)> {
        let n = top.n_components();
        let m = cluster.n_machines();
        let mut e_m = vec![vec![0.0; m]; n];
        let mut met_m = vec![vec![0.0; m]; n];
        for (ci, comp) in top.components.iter().enumerate() {
            for (mi, mach) in cluster.machines.iter().enumerate() {
                let p = self.get(&comp.task_type, &cluster.types[mach.type_id].name)?;
                e_m[ci][mi] = p.e;
                met_m[ci][mi] = p.met;
            }
        }
        Ok((e_m, met_m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    #[test]
    fn insert_get_roundtrip() {
        let mut db = ProfileDb::new();
        db.insert("low", "fast", TaskProfile { e: 0.05, met: 2.0 });
        let p = db.get("low", "fast").unwrap();
        assert_eq!(p.e, 0.05);
        assert!(db.get("low", "slow").is_err());
    }

    #[test]
    fn tcu_is_linear() {
        let mut db = ProfileDb::new();
        db.insert("t", "m", TaskProfile { e: 0.1, met: 3.0 });
        assert!((db.tcu("t", "m", 0.0).unwrap() - 3.0).abs() < 1e-12);
        assert!((db.tcu("t", "m", 100.0).unwrap() - 13.0).abs() < 1e-12);
    }

    #[test]
    fn paper_profiles_cover_micro() {
        let (cluster, db) = presets::paper_cluster();
        for t in benchmarks::micro() {
            db.check_coverage(&t, &cluster).unwrap();
        }
    }

    #[test]
    fn coverage_error_lists_every_missing_pair() {
        let (cluster, full) = presets::paper_cluster();
        let top = benchmarks::linear();
        // rebuild the DB without highCompute anywhere and without
        // midCompute on the pentium type
        let mut db = ProfileDb::new();
        for tt in ["spout", "lowCompute", "midCompute"] {
            for mt in ["pentium", "core-i3", "core-i5"] {
                if tt == "midCompute" && mt == "pentium" {
                    continue;
                }
                db.insert(tt, mt, full.get(tt, mt).unwrap());
            }
        }
        let err = db.check_coverage(&top, &cluster).unwrap_err().to_string();
        // all four missing pairs appear in one message
        for pair in [
            "[task 'midCompute'], pentium",
            "[task 'highCompute'], pentium",
            "[task 'highCompute'], core-i3",
            "[task 'highCompute'], core-i5",
        ] {
            assert!(err.contains(pair), "missing pair '{pair}' not listed in: {err}");
        }
        assert!(err.contains("4 (component, machine type) pairs"), "{err}");
    }

    #[test]
    fn coverage_many_dedupes_across_tenants_sharing_the_db() {
        let (cluster, full) = presets::paper_cluster();
        // rebuild without highCompute anywhere: both tenants placing a
        // highCompute component hit the same gap
        let mut db = ProfileDb::new();
        for tt in ["spout", "lowCompute", "midCompute"] {
            for mt in ["pentium", "core-i3", "core-i5"] {
                db.insert(tt, mt, full.get(tt, mt).unwrap());
            }
        }
        let a = benchmarks::linear(); // component "high"
        let b = benchmarks::diamond(); // component "sink"
        let err = db
            .check_coverage_many(&[("search", &a), ("ads", &b)], &cluster)
            .unwrap_err()
            .to_string();
        // one line per missing (task type, machine type) pair, naming
        // every tenant/component that wants it
        for mt in ["pentium", "core-i3", "core-i5"] {
            assert!(err.contains(&format!("(task 'highCompute', {mt})")), "{err}");
        }
        assert!(err.contains("search/high"), "{err}");
        assert!(err.contains("ads/sink"), "{err}");
        // 2 tenants x 3 machine types = 6 triples over 3 pairs
        assert!(err.contains("6 (tenant, component, machine type) triples"), "{err}");
        assert!(err.contains("3 pairs"), "{err}");
        // full coverage passes in one call
        full.check_coverage_many(&[("search", &a), ("ads", &b)], &cluster).unwrap();
    }

    #[test]
    fn expand_shapes() {
        let (cluster, db) = presets::paper_cluster();
        let t = benchmarks::linear();
        let (e_m, met_m) = db.expand(&t, &cluster).unwrap();
        assert_eq!(e_m.len(), t.n_components());
        assert_eq!(e_m[0].len(), cluster.n_machines());
        assert_eq!(met_m.len(), t.n_components());
        // highCompute on the Pentium worker must match Table 3
        let hi = t.components.iter().position(|c| c.task_type == "highCompute").unwrap();
        let pentium = cluster
            .machines
            .iter()
            .position(|m| cluster.types[m.type_id].name == "pentium")
            .unwrap();
        assert!((e_m[hi][pentium] - 0.1915).abs() < 1e-12);
    }
}
