//! Integration tests for the `hstorm::check` invariant verifier.
//!
//! Three layers of evidence that the verifier is both *sound* (clean
//! schedules pass) and *sharp* (every corruption class is flagged with
//! its own diagnostic code):
//!
//! 1. every benchmark topology x registry policy combination validates
//!    clean and replays bit-for-bit;
//! 2. randomized `Constraints` (exclusions, pins, instance caps,
//!    headroom, reserved loads) pushed through every registry policy
//!    still validate clean — the verifier agrees with the schedulers on
//!    what the constraints mean;
//! 3. a mutation corpus: nine distinct corruptions of a known-good
//!    schedule, each flagged with a distinct `Violation::code()`, plus
//!    shape-mismatch and replay-divergence probes and a fleet-step
//!    corpus for the dirty-tenant re-plan invariants (clean residents
//!    never move, per-step migration budget respected).

use std::collections::BTreeSet;

use hstorm::check;
use hstorm::cluster::presets;
use hstorm::scheduler::{registry, Constraints, PolicyParams, Problem, Schedule, ScheduleRequest};
use hstorm::topology::benchmarks;
use hstorm::util::prop;

/// Policy tunables for these tests: the optimal search runs sampled and
/// the budgeted search policies (bnb/beam/portfolio) run under a small
/// deterministic candidate budget (so replay stays bit-identical) to
/// keep debug builds fast.
fn params() -> PolicyParams {
    let mut p = PolicyParams { sampled: Some((600, 7)), ..PolicyParams::default() };
    p.set("budget-candidates", "4000").unwrap();
    p
}

fn paper_problem(top: &hstorm::topology::Topology) -> Problem {
    let (cluster, db) = presets::paper_cluster();
    Problem::new(top, &cluster, &db).expect("paper presets build a valid problem")
}

#[test]
fn every_benchmark_policy_combination_validates_and_replays() {
    let req = ScheduleRequest::max_throughput();
    let params = params();
    for top in benchmarks::all() {
        let problem = paper_problem(&top);
        for name in registry::names() {
            let s = registry::create(name, &params)
                .expect("registry names construct")
                .schedule(&problem, &req)
                .unwrap_or_else(|e| panic!("{name} on {}: {e}", top.name));
            let mut report = check::validate(&problem, &req, &s).unwrap();
            report.absorb(check::validate_replay(&problem, &req, &s, &params).unwrap());
            assert!(report.passed(), "{} x {name}:\n{}", top.name, report.render());
        }
    }
}

#[test]
fn randomized_constraints_hold_across_all_policies() {
    let machines = ["pentium-0", "i3-0", "i5-0"];
    prop::check(
        "check-validates-constrained-schedules",
        prop::default_cases() / 4,
        |rng| {
            let tname = benchmarks::NAMES[rng.range(0, benchmarks::NAMES.len() - 1)];
            let top = benchmarks::by_name(tname).expect("NAMES entries resolve");
            let mut c = Constraints::new();
            let excluded = if rng.chance(0.4) { Some(rng.range(0, 2)) } else { None };
            if let Some(x) = excluded {
                c = c.exclude_machine(machines[x]);
            }
            if rng.chance(0.4) {
                // pin a random component to a nonempty subset of the
                // machines that remain available
                let comp = top.components[rng.range(0, top.n_components() - 1)].name.clone();
                let allowed: Vec<&str> = machines
                    .iter()
                    .enumerate()
                    .filter(|(m, _)| Some(*m) != excluded)
                    .map(|(_, name)| *name)
                    .collect();
                let keep = rng.range(1, allowed.len());
                c = c.pin_component(comp, allowed.into_iter().take(keep));
            }
            if rng.chance(0.5) {
                let comp = top.components[rng.range(0, top.n_components() - 1)].name.clone();
                c = c.max_instances(comp, rng.range(1, 3));
            }
            if rng.chance(0.5) {
                c = c.reserve_headroom(rng.range_f64(0.0, 12.0));
            }
            if rng.chance(0.4) {
                c = c.reserve_machine_load(machines[rng.range(0, 2)], rng.range_f64(0.0, 8.0));
            }
            (tname.to_string(), c)
        },
        |(tname, c)| {
            let top = benchmarks::by_name(tname).expect("name came from NAMES");
            let problem = paper_problem(&top);
            let req = ScheduleRequest::max_throughput().with_constraints(c.clone());
            let params = params();
            for name in registry::names() {
                let s = registry::create(name, &params)
                    .map_err(|e| e.to_string())?
                    .schedule(&problem, &req)
                    .map_err(|e| format!("{name}: schedule failed: {e}"))?;
                let report = check::validate(&problem, &req, &s).map_err(|e| e.to_string())?;
                if !report.passed() {
                    return Err(format!("{name} violated invariants:\n{}", report.render()));
                }
                let replay =
                    check::validate_replay(&problem, &req, &s, &params).map_err(|e| e.to_string())?;
                if !replay.passed() {
                    return Err(format!("{name} replay diverged:\n{}", replay.render()));
                }
            }
            Ok(())
        },
    );
}

/// One seeded corruption: schedule `linear` under `req`, apply `mutate`,
/// and expect `code` among the verifier's findings.
struct Mutation {
    name: &'static str,
    req: ScheduleRequest,
    mutate: fn(&Problem, &mut Schedule),
    code: &'static str,
}

fn corpus() -> Vec<Mutation> {
    // linear components: spout(0) low(1) mid(2) high(3);
    // paper machines: pentium-0(0) i3-0(1) i5-0(2)
    vec![
        Mutation {
            name: "drop-component",
            req: ScheduleRequest::max_throughput(),
            mutate: |_, s| {
                for m in 0..s.placement.n_machines() {
                    s.placement.x[0][m] = 0;
                }
            },
            code: "missing-component",
        },
        Mutation {
            name: "exceed-instance-cap",
            req: ScheduleRequest::max_throughput()
                .with_constraints(Constraints::new().max_instances("low", 1)),
            mutate: |_, s| s.placement.x[1][2] += 1,
            code: "instance-cap-exceeded",
        },
        Mutation {
            name: "place-on-excluded",
            req: ScheduleRequest::max_throughput()
                .with_constraints(Constraints::new().exclude_machine("i3-0")),
            mutate: |_, s| s.placement.x[0][1] += 1,
            code: "excluded-machine",
        },
        Mutation {
            name: "break-pin",
            req: ScheduleRequest::max_throughput()
                .with_constraints(Constraints::new().pin_component("spout", ["i5-0"])),
            mutate: |_, s| s.placement.x[0][0] += 1,
            code: "pin-violated",
        },
        Mutation {
            name: "inflate-rate",
            req: ScheduleRequest::max_throughput(),
            // keep the reported eval self-consistent at the inflated
            // rate, isolating the capacity violation
            mutate: |p, s| {
                s.rate *= 8.0;
                s.eval = p.evaluator().evaluate(&s.placement, s.rate).unwrap();
            },
            code: "overutilized",
        },
        Mutation {
            name: "poison-rate",
            req: ScheduleRequest::max_throughput(),
            mutate: |_, s| s.rate = f64::NAN,
            code: "rate-infeasible",
        },
        Mutation {
            name: "tamper-util",
            req: ScheduleRequest::max_throughput(),
            mutate: |_, s| s.eval.util[0] += 5.0,
            code: "util-mismatch",
        },
        Mutation {
            name: "flip-feasible",
            req: ScheduleRequest::max_throughput(),
            mutate: |_, s| s.eval.feasible = !s.eval.feasible,
            code: "feasible-flag-wrong",
        },
        Mutation {
            name: "negative-gap",
            req: ScheduleRequest::max_throughput(),
            // a bound below the returned rate implies a negative gap —
            // no search can legitimately certify this
            mutate: |_, s| s.provenance.optimality_gap = Some(-0.05),
            code: "gap-inconsistent",
        },
    ]
}

#[test]
fn mutation_corpus_is_fully_flagged_with_distinct_codes() {
    let corpus = corpus();
    let distinct: BTreeSet<&str> = corpus.iter().map(|m| m.code).collect();
    assert!(distinct.len() >= 6, "corpus must cover >= 6 distinct codes");
    assert_eq!(distinct.len(), corpus.len(), "every mutation expects its own code");

    let top = benchmarks::linear();
    let problem = paper_problem(&top);
    for mutation in &corpus {
        let mut s = registry::create("hetero", &params())
            .unwrap()
            .schedule(&problem, &mutation.req)
            .unwrap_or_else(|e| panic!("{}: schedule failed: {e}", mutation.name));
        let clean = check::validate(&problem, &mutation.req, &s).unwrap();
        assert!(
            clean.passed(),
            "{}: pre-mutation schedule dirty:\n{}",
            mutation.name,
            clean.render()
        );

        (mutation.mutate)(&problem, &mut s);
        let report = check::validate(&problem, &mutation.req, &s).unwrap();
        let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
        assert!(
            codes.contains(&mutation.code),
            "{}: expected '{}' among {:?}",
            mutation.name,
            mutation.code,
            codes
        );
    }
}

#[test]
fn schedule_for_the_wrong_problem_is_a_shape_mismatch() {
    let req = ScheduleRequest::max_throughput();
    let linear = paper_problem(&benchmarks::linear());
    let diamond = paper_problem(&benchmarks::diamond());
    let s = registry::create("hetero", &params()).unwrap().schedule(&linear, &req).unwrap();
    let report = check::validate(&diamond, &req, &s).unwrap();
    let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
    assert_eq!(codes, vec!["shape-mismatch"], "{}", report.render());
}

/// Fleet-step corpus: each corruption of a clean dirty-tenant re-plan
/// step is flagged with its own code — a clean resident whose
/// placement changed (`resident-moved`) and a step that started more
/// instances than the migration budget (`migration-budget-exceeded`).
#[test]
fn fleet_step_corpus_flags_resident_moves_and_budget_breaches() {
    use hstorm::predict::Placement;
    let tenants = vec!["t0".to_string(), "t1".to_string()];
    let mut resident = Placement::empty(2, 3);
    resident.x[0][0] = 1;
    resident.x[1][2] = 2;
    let mut dirty = Placement::empty(2, 3);
    dirty.x[0][1] = 1;
    dirty.x[1][1] = 1;
    let before = vec![resident.clone(), dirty.clone()];

    // clean step: only the dirty tenant moved, one start, budget 8
    let mut replanned = dirty.clone();
    replanned.x[0][1] = 0;
    replanned.x[0][0] = 1;
    let after = vec![resident.clone(), replanned.clone()];
    let report = check::validate_fleet(&tenants, &before, &after, &[false, true], 8);
    assert!(report.passed(), "clean step must pass:\n{}", report.render());

    // corruption 1: a non-dirty resident's placement changed
    let mut moved = resident.clone();
    moved.x[0][0] = 0;
    moved.x[0][2] = 1;
    let after = vec![moved, dirty.clone()];
    let report = check::validate_fleet(&tenants, &before, &after, &[false, true], 8);
    let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
    assert!(codes.contains(&"resident-moved"), "expected resident-moved among {codes:?}");

    // corruption 2: the dirty tenant started more instances than the
    // per-step migration budget allows
    let mut greedy = dirty.clone();
    greedy.x[1][0] = 4;
    let after = vec![resident.clone(), greedy];
    let report = check::validate_fleet(&tenants, &before, &after, &[false, true], 2);
    let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
    assert!(
        codes.contains(&"migration-budget-exceeded"),
        "expected migration-budget-exceeded among {codes:?}"
    );
    assert!(
        !codes.contains(&"resident-moved"),
        "budget breach must not implicate the clean resident: {codes:?}"
    );

    // a zero budget flags any started instance at all
    let after = vec![resident, replanned];
    let report = check::validate_fleet(&tenants, &before, &after, &[false, true], 0);
    let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
    assert_eq!(codes, vec!["migration-budget-exceeded"], "{}", report.render());
}

#[test]
fn moved_instance_diverges_replay() {
    let req = ScheduleRequest::max_throughput();
    let problem = paper_problem(&benchmarks::linear());
    let params = params();
    let mut s = registry::create("hetero", &params).unwrap().schedule(&problem, &req).unwrap();
    // move one instance of the sink component to a different machine
    let from = (0..s.placement.n_machines())
        .find(|&m| s.placement.x[3][m] > 0)
        .expect("sink is placed somewhere");
    let to = (from + 1) % s.placement.n_machines();
    s.placement.x[3][from] -= 1;
    s.placement.x[3][to] += 1;
    let report = check::validate_replay(&problem, &req, &s, &params).unwrap();
    let codes: Vec<&str> = report.violations.iter().map(|v| v.code()).collect();
    assert!(codes.contains(&"replay-diverged"), "{}", report.render());
}
