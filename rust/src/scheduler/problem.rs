//! The scheduling [`Problem`]: one validated (topology, cluster,
//! profiles) triple, owning the expensive derived state every policy
//! needs — the expanded [`Evaluator`] tables and, optionally, a
//! PJRT-backed batch scorer.
//!
//! Building a `Problem` validates the triple exactly once (topology
//! structure, cluster shape, profile coverage); every subsequent
//! [`Scheduler::schedule`](super::Scheduler::schedule) call reuses the
//! cached tables instead of re-expanding profiles — which is the whole
//! life of the online controller: many requests, one world.

use std::borrow::Cow;

use crate::cluster::profile::ProfileDb;
use crate::cluster::Cluster;
use crate::predict::Evaluator;
use crate::runtime::scorer::PlacementScorer;
use crate::topology::Topology;
use crate::{Error, Result};

use super::request::Constraints;

/// A validated scheduling problem with cached evaluation state.
pub struct Problem {
    top: Topology,
    cluster: Cluster,
    profiles: ProfileDb,
    evaluator: Evaluator,
    scorer: Option<Box<dyn PlacementScorer>>,
}

impl Problem {
    /// Validate the triple once and cache the expanded profile tables.
    pub fn new(top: &Topology, cluster: &Cluster, profiles: &ProfileDb) -> Result<Self> {
        // Evaluator::new validates topology + cluster + coverage.
        let evaluator = Evaluator::new(top, cluster, profiles)?;
        Ok(Problem {
            top: top.clone(),
            cluster: cluster.clone(),
            profiles: profiles.clone(),
            evaluator,
            scorer: None,
        })
    }

    /// Attach a placement scorer (typically the PJRT AOT scorer built by
    /// [`crate::runtime::scorer::PjRtScorer::new`]); schedulers that
    /// support batch scoring will use it instead of the native mirror.
    pub fn with_scorer(mut self, scorer: Box<dyn PlacementScorer>) -> Self {
        self.scorer = Some(scorer);
        self
    }

    pub fn topology(&self) -> &Topology {
        &self.top
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    pub fn profiles(&self) -> &ProfileDb {
        &self.profiles
    }

    /// The cached evaluation tables (unconstrained capacities).
    pub fn evaluator(&self) -> &Evaluator {
        &self.evaluator
    }

    /// The attached batch scorer, if any.
    pub fn scorer(&self) -> Option<&dyn PlacementScorer> {
        self.scorer.as_deref()
    }

    /// Name of the scoring backend requests will run through.
    pub fn scoring_backend(&self) -> &'static str {
        self.scorer.as_deref().map_or("native", |s| s.backend())
    }

    fn machine_index(&self, name: &str) -> Result<usize> {
        self.cluster
            .machines
            .iter()
            .position(|m| m.name == name)
            .ok_or_else(|| {
                Error::Schedule(format!(
                    "constraint references unknown machine '{name}' (cluster '{}' has: {})",
                    self.cluster.name,
                    self.cluster
                        .machines
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    fn component_index(&self, name: &str) -> Result<usize> {
        self.top
            .components
            .iter()
            .position(|c| c.name == name)
            .ok_or_else(|| {
                Error::Schedule(format!(
                    "constraint references unknown component '{name}' (topology '{}' has: {})",
                    self.top.name,
                    self.top
                        .components
                        .iter()
                        .map(|c| c.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// Resolve name-keyed [`Constraints`] into index form, rejecting
    /// unknown names, non-positive instance caps, out-of-range headroom,
    /// and constraint sets that leave some component with no allowed
    /// machine.
    pub fn resolve(&self, c: &Constraints) -> Result<ResolvedConstraints> {
        let n_comp = self.top.n_components();
        let n_machines = self.cluster.n_machines();
        let mut rc = ResolvedConstraints::unconstrained(n_comp, n_machines);

        if !(0.0..100.0).contains(&c.headroom_pct) {
            return Err(Error::Schedule(format!(
                "reserved headroom must be in [0, 100); got {}",
                c.headroom_pct
            )));
        }
        rc.headroom_pct = c.headroom_pct;

        for name in &c.excluded_machines {
            let m = self.machine_index(name)?;
            rc.excluded[m] = true;
        }
        if rc.excluded.iter().all(|&e| e) && n_machines > 0 {
            return Err(Error::Schedule("every machine is excluded".into()));
        }

        for (comp, machines) in &c.pins {
            let ci = self.component_index(comp)?;
            let mut allowed = vec![false; n_machines];
            for mname in machines {
                allowed[self.machine_index(mname)?] = true;
            }
            for (m, slot) in rc.pinned[ci].iter_mut().enumerate() {
                *slot = *slot && allowed[m];
            }
        }

        for (comp, n) in &c.max_instances {
            let ci = self.component_index(comp)?;
            if *n == 0 {
                return Err(Error::Schedule(format!(
                    "max_instances for component '{comp}' must be >= 1 (every \
                     component keeps an instance)"
                )));
            }
            rc.max_instances[ci] = rc.max_instances[ci].min(*n);
        }

        for (ci, comp) in self.top.components.iter().enumerate() {
            if (0..n_machines).all(|m| !rc.allows(ci, m)) {
                return Err(Error::Schedule(format!(
                    "constraints leave component '{}' with no allowed machine \
                     (pins ∩ non-excluded = ∅)",
                    comp.name
                )));
            }
        }
        Ok(rc)
    }

    /// The evaluator the request actually schedules against: capacities
    /// shrunk by the reserved headroom (excluded machines keep their
    /// budget — they simply host nothing, enforced by the search).
    /// Without headroom this borrows the cached tables; only a headroom
    /// request pays for a modified clone.
    pub fn constrained_evaluator(&self, rc: &ResolvedConstraints) -> Cow<'_, Evaluator> {
        if rc.headroom_pct <= 0.0 {
            return Cow::Borrowed(&self.evaluator);
        }
        let mut ev = self.evaluator.clone();
        for cap in &mut ev.cap {
            *cap = (*cap - rc.headroom_pct).max(0.0);
        }
        Cow::Owned(ev)
    }
}

/// [`Constraints`] resolved to component/machine indices.
#[derive(Debug, Clone)]
pub struct ResolvedConstraints {
    /// Machines that must host zero instances.
    pub excluded: Vec<bool>,
    /// Per component: machines its instances may use (`true` = allowed
    /// by pinning; exclusion is applied on top in [`Self::allows`]).
    pinned: Vec<Vec<bool>>,
    /// Per component: instance-count ceiling.
    pub max_instances: Vec<usize>,
    /// CPU percentage points reserved on every machine.
    pub headroom_pct: f64,
}

impl ResolvedConstraints {
    /// No restrictions: everything allowed, unbounded counts.
    pub fn unconstrained(n_comp: usize, n_machines: usize) -> Self {
        ResolvedConstraints {
            excluded: vec![false; n_machines],
            pinned: vec![vec![true; n_machines]; n_comp],
            max_instances: vec![usize::MAX; n_comp],
            headroom_pct: 0.0,
        }
    }

    /// May component `c` place an instance on machine `m`?
    #[inline]
    pub fn allows(&self, c: usize, m: usize) -> bool {
        !self.excluded[m] && self.pinned[c][m]
    }

    /// Indices of excluded machines (for reporting).
    pub fn excluded_indices(&self) -> Vec<usize> {
        self.excluded
            .iter()
            .enumerate()
            .filter_map(|(m, &e)| e.then_some(m))
            .collect()
    }

    /// True when the constraints restrict nothing.
    pub fn is_trivial(&self) -> bool {
        self.headroom_pct == 0.0
            && self.excluded.iter().all(|&e| !e)
            && self.pinned.iter().all(|row| row.iter().all(|&a| a))
            && self.max_instances.iter().all(|&n| n == usize::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    #[test]
    fn new_validates_and_caches() {
        let p = problem();
        assert_eq!(p.evaluator().n_components(), p.topology().n_components());
        assert_eq!(p.scoring_backend(), "native");
    }

    #[test]
    fn resolve_trivial() {
        let p = problem();
        let rc = p.resolve(&Constraints::new()).unwrap();
        assert!(rc.is_trivial());
        for c in 0..p.topology().n_components() {
            for m in 0..p.cluster().n_machines() {
                assert!(rc.allows(c, m));
            }
        }
    }

    #[test]
    fn resolve_exclusion_and_pins() {
        let p = problem();
        let rc = p
            .resolve(
                &Constraints::new()
                    .exclude_machine("i3-0")
                    .pin_component("spout", ["pentium-0", "i3-0"])
                    .max_instances("spout", 2),
            )
            .unwrap();
        assert!(!rc.is_trivial());
        let i3 = p.cluster().machines.iter().position(|m| m.name == "i3-0").unwrap();
        let pent = p.cluster().machines.iter().position(|m| m.name == "pentium-0").unwrap();
        let spout = p.topology().components.iter().position(|c| c.name == "spout").unwrap();
        assert!(rc.excluded[i3]);
        assert_eq!(rc.excluded_indices(), vec![i3]);
        // pinned to {pentium-0, i3-0}, but i3-0 is excluded
        assert!(rc.allows(spout, pent));
        assert!(!rc.allows(spout, i3));
        assert_eq!(rc.max_instances[spout], 2);
        // other components untouched by the pin
        for m in 0..p.cluster().n_machines() {
            if m != i3 {
                assert!(rc.allows(1, m));
            }
        }
    }

    #[test]
    fn resolve_rejects_unknown_names() {
        let p = problem();
        let err = p.resolve(&Constraints::new().exclude_machine("ghost")).unwrap_err();
        assert!(err.to_string().contains("ghost"), "{err}");
        assert!(err.to_string().contains("pentium-0"), "error should list valid machines: {err}");
        assert!(p.resolve(&Constraints::new().pin_component("nope", ["pentium-0"])).is_err());
        assert!(p.resolve(&Constraints::new().max_instances("spout", 0)).is_err());
        assert!(p.resolve(&Constraints::new().reserve_headroom(100.0)).is_err());
        assert!(p.resolve(&Constraints::new().reserve_headroom(-1.0)).is_err());
    }

    #[test]
    fn resolve_rejects_unsatisfiable_sets() {
        let p = problem();
        // pin a component onto an excluded machine only
        let c =
            Constraints::new().exclude_machine("pentium-0").pin_component("spout", ["pentium-0"]);
        assert!(p.resolve(&c).is_err());
        // exclude everything
        let c = Constraints::new().exclude_machines(["pentium-0", "i3-0", "i5-0"]);
        match p.resolve(&c) {
            Err(e) => assert!(e.to_string().contains("excluded"), "{e}"),
            Ok(_) => panic!("excluding every machine must be rejected"),
        }
    }

    #[test]
    fn constrained_evaluator_applies_headroom() {
        let p = problem();
        let rc = p.resolve(&Constraints::new().reserve_headroom(25.0)).unwrap();
        let ev = p.constrained_evaluator(&rc);
        assert!(matches!(ev, Cow::Owned(_)));
        for (m, cap) in ev.cap.iter().enumerate() {
            assert!((cap - (p.evaluator().cap[m] - 25.0)).abs() < 1e-12);
        }
        // trivial constraints share the cached tables, capacities intact
        let rc0 = p.resolve(&Constraints::new()).unwrap();
        let ev0 = p.constrained_evaluator(&rc0);
        assert!(matches!(ev0, Cow::Borrowed(_)));
        assert_eq!(ev0.cap, p.evaluator().cap);
    }
}
