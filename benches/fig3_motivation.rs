//! Bench: regenerate the paper's Fig.3-motivation table (fig3) and time it.
//! Run: cargo bench --bench fig3_motivation  [HSTORM_FAST=1 for quick mode]

use hstorm::experiments::fig3;
use hstorm::util::bench;

fn main() {
    let fast = std::env::var("HSTORM_FAST").is_ok();
    let (result, dt) = bench::time_once(|| fig3::run(fast).expect("fig3 runs"));
    println!("{}", result.render());
    println!("[fig3_motivation] regenerated in {dt:?} (fast={fast})");
}
