//! Simulated annealing over `O(1)`-amortized placement deltas.
//!
//! Local search on the placement itself: each step probes one random
//! move/add/remove through [`DeltaEval`](crate::predict::kernel::DeltaEval)
//! (which re-reads `R0*` off patched accumulators instead of
//! re-deriving the whole evaluation), accepts improvements always and
//! regressions with Boltzmann probability under a geometrically
//! cooling temperature, and restarts from the base placement a
//! configurable number of times.  All randomness flows from one
//! [`Rng`](crate::util::rng::Rng) seed, so a given configuration
//! replays bit-identically — `hstorm check`'s replay gate holds for
//! `anneal` exactly as for the deterministic policies.
//!
//! Moves are constraint-closed: targets must be allowed by the
//! resolved constraints, adds stop at the component cap, removes keep
//! every component populated.  Like beam search this is an incomplete
//! strategy — it reports no bound/gap of its own.

use std::time::Instant;

use super::super::problem::ResolvedConstraints;
use super::super::{
    apply_objective, Problem, Provenance, Schedule, ScheduleRequest, Scheduler, SearchBudget,
    Termination,
};
use super::{record_search_started, repair_warm_start, BudgetMeter};
use crate::predict::kernel::DeltaEval;
use crate::predict::{Evaluator, Placement};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Simulated-annealing policy (`anneal` in the registry).
#[derive(Debug, Clone)]
pub struct AnnealScheduler {
    /// Cap on instances a component may grow to (the add-move bound,
    /// intersected with the per-component constraint caps).
    pub max_instances_per_component: usize,
    /// Independent restarts from the base placement.
    pub restarts: usize,
    /// Annealing steps per restart.
    pub steps: usize,
    /// Root seed for the deterministic RNG.
    pub seed: u64,
    /// Default budget when the request leaves its budget unlimited.
    pub budget: SearchBudget,
}

impl Default for AnnealScheduler {
    fn default() -> Self {
        AnnealScheduler {
            max_instances_per_component: 3,
            restarts: 4,
            steps: 400,
            seed: 0xA11E_A1,
            budget: SearchBudget::unlimited(),
        }
    }
}

/// Outcome of the annealing runs (shared with the portfolio).
pub(crate) struct AnnealOutcome {
    /// Best placement seen and its rate (`None`: nothing feasible).
    pub(crate) best: Option<(Placement, f64)>,
    /// Probes charged (each probe is one candidate evaluation).
    pub(crate) evaluated: u64,
    pub(crate) stopped: bool,
}

/// Anneal from `base`, spending at most what `meter` affords.
pub(crate) fn run(
    ev: &Evaluator,
    rc: &ResolvedConstraints,
    base: &Placement,
    max_instances: usize,
    restarts: usize,
    steps: usize,
    seed: u64,
    meter: &mut BudgetMeter,
) -> Result<AnnealOutcome> {
    let n_comp = base.n_components();
    let n_m = base.n_machines();
    let mut out = AnnealOutcome { best: None, evaluated: 0, stopped: false };
    let mut consider = |p: Placement, r: f64, best: &mut Option<(Placement, f64)>| {
        if r > 0.0 && best.as_ref().map_or(true, |(_, br)| r > *br) {
            *best = Some((p, r));
        }
    };

    'restarts: for restart in 0..restarts.max(1) {
        // distinct, deterministic stream per restart
        let mut rng = Rng::new(seed ^ (restart as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut de = DeltaEval::new(ev, base)?;
        let mut cur = de.rate_or_zero();
        if !cur.is_finite() {
            cur = 0.0;
        }
        consider(de.placement(), cur, &mut out.best);
        // temperature as a fraction of the current value: accept a 5%
        // regression with probability 1/e at the start, cooling out
        let mut temp = 0.05 * cur.max(1.0);
        for _ in 0..steps {
            if !meter.try_charge() {
                out.stopped = true;
                break 'restarts;
            }
            out.evaluated += 1;
            let proposal = propose(&de, rc, max_instances, n_comp, n_m, &mut rng);
            let Some((kind, c, a, b)) = proposal else { continue };
            let r_new = match kind {
                Move::Shift => de.rate_with_move(c, a, b),
                Move::Add => de.rate_adding(c, a),
                Move::Remove => de.rate_removing(c, a),
            };
            let r_new = if r_new.is_finite() { r_new } else { 0.0 };
            let accept = r_new >= cur
                || (temp > 1e-12 && rng.chance(((r_new - cur) / temp).exp().min(1.0)));
            if accept {
                match kind {
                    Move::Shift => de.apply_move(c, a, b),
                    Move::Add => de.apply_add(c, a),
                    Move::Remove => de.apply_remove(c, a),
                }
                cur = r_new;
                if out.best.as_ref().map_or(true, |(_, br)| cur > *br) {
                    consider(de.placement(), cur, &mut out.best);
                }
            }
            temp *= 0.995;
        }
    }
    Ok(out)
}

#[derive(Clone, Copy)]
enum Move {
    /// Shift one instance of component `c` from machine `a` to `b`.
    Shift,
    /// Add one instance of `c` on machine `a`.
    Add,
    /// Remove one instance of `c` from machine `a`.
    Remove,
}

/// Draw one constraint-closed neighbor; `None` when the drawn kind has
/// no legal move for the drawn component (the step is just skipped —
/// skipping is itself deterministic).
fn propose(
    de: &DeltaEval,
    rc: &ResolvedConstraints,
    max_instances: usize,
    n_comp: usize,
    n_m: usize,
    rng: &mut Rng,
) -> Option<(Move, usize, usize, usize)> {
    let c = rng.range(0, n_comp - 1);
    let kind = rng.range(0, 3);
    let hosts: Vec<usize> = (0..n_m).filter(|&m| de.get(c, m) > 0).collect();
    match kind {
        // moves are drawn twice as often as grow/shrink
        0 | 1 => {
            let from = hosts[rng.range(0, hosts.len() - 1)];
            let targets: Vec<usize> =
                (0..n_m).filter(|&m| m != from && rc.allows(c, m)).collect();
            if targets.is_empty() {
                return None;
            }
            let to = targets[rng.range(0, targets.len() - 1)];
            Some((Move::Shift, c, from, to))
        }
        2 => {
            let cap = max_instances.min(rc.max_instances[c]);
            if (de.count(c) as usize) >= cap {
                return None;
            }
            let targets: Vec<usize> = (0..n_m).filter(|&m| rc.allows(c, m)).collect();
            if targets.is_empty() {
                return None;
            }
            let m = targets[rng.range(0, targets.len() - 1)];
            Some((Move::Add, c, m, 0))
        }
        _ => {
            if de.count(c) <= 1 {
                return None;
            }
            let m = hosts[rng.range(0, hosts.len() - 1)];
            Some((Move::Remove, c, m, 0))
        }
    }
}

/// The base placement annealing starts from: the repaired warm start
/// when the request carries one, otherwise the heterogeneous
/// heuristic's solution, otherwise one instance per component on its
/// first allowed machine.
pub(crate) fn base_placement(
    problem: &Problem,
    req: &ScheduleRequest,
    rc: &ResolvedConstraints,
) -> Result<Placement> {
    let n_comp = problem.topology().n_components();
    let n_m = problem.cluster().n_machines();
    if let Some(warm) = &req.warm_start {
        if let Some(fixed) = repair_warm_start(rc, warm, n_comp, n_m) {
            return Ok(fixed);
        }
    }
    let seed_req = ScheduleRequest::max_throughput().with_constraints(req.constraints.clone());
    if let Ok(h) = super::super::hetero::HeteroScheduler::default().schedule(problem, &seed_req) {
        return Ok(h.placement);
    }
    let mut p = Placement::empty(n_comp, n_m);
    for c in 0..n_comp {
        let m = (0..n_m)
            .find(|&m| rc.allows(c, m))
            .ok_or_else(|| Error::Schedule(format!("component {c} has no allowed machine")))?;
        p.x[c][m] = 1;
    }
    Ok(p)
}

impl Scheduler for AnnealScheduler {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn schedule(&self, problem: &Problem, req: &ScheduleRequest) -> Result<Schedule> {
        let started = Instant::now();
        let rc = problem.resolve(&req.constraints)?;
        let ev = problem.constrained_evaluator(&rc);
        let n_comp = problem.topology().n_components();
        let n_m = problem.cluster().n_machines();
        record_search_started(self.name(), n_comp, n_m);

        let base = base_placement(problem, req, &rc)?;
        let budget = if req.budget.is_unlimited() { self.budget } else { req.budget };
        let mut meter = BudgetMeter::new(&budget, n_m as u64);
        let out = run(
            &ev,
            &rc,
            &base,
            self.max_instances_per_component,
            self.restarts,
            self.steps,
            self.seed,
            &mut meter,
        )?;

        let (placement, _) = out
            .best
            .ok_or_else(|| Error::Schedule("no feasible placement found by annealing".into()))?;
        let mut evaluated = out.evaluated;
        let s = super::super::finish(&ev, placement)?;
        // rate is what annealing optimizes; the other objectives get
        // the same post-passes the heuristic policies use
        let mut s = apply_objective(&ev, &rc, &req.objective, s, usize::MAX, &mut evaluated)?;
        s.provenance = Provenance {
            policy: self.name().into(),
            objective: req.objective.describe(),
            placements_evaluated: evaluated,
            backend: "kernel".into(),
            wall: started.elapsed(),
            bound: None,
            optimality_gap: None,
            terminated: if out.stopped { Termination::Budget } else { Termination::Exhausted },
        };
        super::super::record_schedule_telemetry(&s, 0);
        super::super::debug_validate(problem, req, &s);
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::optimal::OptimalScheduler;
    use super::super::super::{Constraints, Problem, ScheduleRequest};
    use super::*;
    use crate::cluster::presets;
    use crate::topology::benchmarks;

    fn problem() -> Problem {
        let (cluster, db) = presets::paper_cluster();
        Problem::new(&benchmarks::linear(), &cluster, &db).unwrap()
    }

    /// Determinism: the seeded RNG makes runs bit-identical.
    #[test]
    fn anneal_is_deterministic() {
        let p = problem();
        let req = ScheduleRequest::max_throughput();
        let a = AnnealScheduler::default().schedule(&p, &req).unwrap();
        let b = AnnealScheduler::default().schedule(&p, &req).unwrap();
        assert_eq!(a.placement.x, b.placement.x);
        assert_eq!(a.rate.to_bits(), b.rate.to_bits());
    }

    /// A different seed is allowed to land elsewhere, but stays feasible
    /// and never below the heuristic base it started from.
    #[test]
    fn anneal_never_regresses_below_its_base() {
        let p = problem();
        let req = ScheduleRequest::max_throughput();
        let base = super::super::super::hetero::HeteroScheduler::default()
            .schedule(&p, &req)
            .unwrap();
        for seed in [1u64, 2, 3] {
            let s = AnnealScheduler { seed, ..Default::default() }.schedule(&p, &req).unwrap();
            assert!(
                s.rate + 1e-9 >= base.rate,
                "seed {seed}: anneal rate {} below base {}",
                s.rate,
                base.rate
            );
        }
    }

    /// Anneal lands within a few percent of the optimum on the micro
    /// space (it is a local search, not a certificate).
    #[test]
    fn anneal_close_to_optimum_on_micro_space() {
        let p = problem();
        let req = ScheduleRequest::max_throughput();
        let opt = OptimalScheduler { threads: 1, ..Default::default() }
            .schedule(&p, &req)
            .unwrap();
        let s = AnnealScheduler::default().schedule(&p, &req).unwrap();
        assert!(s.rate >= opt.rate * 0.95, "anneal {} vs optimum {}", s.rate, opt.rate);
    }

    /// Moves never step outside the resolved constraints.
    #[test]
    fn anneal_respects_exclusions() {
        let p = problem();
        let req = ScheduleRequest::max_throughput()
            .with_constraints(Constraints::new().exclude_machine("i3-0"));
        let s = AnnealScheduler::default().schedule(&p, &req).unwrap();
        for c in 0..p.topology().n_components() {
            assert_eq!(s.placement.x[c][1], 0, "instance left on excluded machine");
        }
    }

    /// The probe budget is honored.
    #[test]
    fn anneal_honors_budget() {
        let p = problem();
        let req = ScheduleRequest::max_throughput()
            .with_budget(crate::scheduler::SearchBudget::unlimited().with_max_candidates(50));
        let s = AnnealScheduler::default().schedule(&p, &req).unwrap();
        assert!(s.provenance.placements_evaluated <= 50);
        assert_eq!(s.provenance.terminated, Termination::Budget);
    }
}
