//! Acceptance suite for the multi-tenant refactor: a one-tenant
//! [`Workload`] is the degenerate case and must be **bit-compatible**
//! with the classic single-tenant [`Problem`] path — same placement,
//! same certified rate (within 1e-9) — through every workload
//! scheduling mode; and incremental-admission scoring through the
//! kernel's residual-capacity offsets must match a naive
//! merged-evaluator recompute within 1e-9.

use std::sync::Arc;

use hstorm::cluster::presets;
use hstorm::predict::kernel::{self, AccumState, Row};
use hstorm::scheduler::{
    registry, PolicyParams, Problem, Schedule, ScheduleRequest, Scheduler, TenantSchedule,
    Workload, WorkloadProblem,
};
use hstorm::topology::benchmarks;

fn policies() -> Vec<(&'static str, Box<dyn Scheduler>)> {
    // small instance bound keeps the optimal enumeration fast in debug
    let small = PolicyParams { max_instances_per_component: 2, ..Default::default() };
    vec![
        ("hetero", registry::create("hetero", &PolicyParams::default()).unwrap()),
        ("default", registry::create("default", &PolicyParams::default()).unwrap()),
        ("optimal", registry::create("optimal", &small).unwrap()),
    ]
}

/// Equivalence: single-tenant workload == Problem path, all 5
/// topologies x paper cluster x max-throughput, joint and incremental
/// (and the isolated baseline, which also degenerates) paths.
#[test]
fn single_tenant_workload_selects_the_identical_schedule() {
    let (cluster, db) = presets::paper_cluster();
    let shared = Arc::new(db.clone());
    let req = ScheduleRequest::max_throughput();
    for top in benchmarks::all() {
        for (name, policy) in policies() {
            let problem = Problem::new(&top, &cluster, &db).unwrap();
            let want = policy.schedule(&problem, &req).unwrap();

            let wp = WorkloadProblem::new(
                Workload::new("solo").tenant("only", top.clone(), shared.clone(), 1.0),
                &cluster,
            )
            .unwrap();
            let runs = [
                wp.schedule_joint(policy.as_ref(), &req).unwrap(),
                wp.schedule_incremental(policy.as_ref(), &req).unwrap(),
                wp.schedule_isolated(policy.as_ref(), &req).unwrap(),
            ];
            for ws in runs {
                assert_eq!(ws.tenants.len(), 1);
                let got = &ws.tenants[0].schedule;
                assert_eq!(
                    got.placement, want.placement,
                    "{}/{name}/{}: placements differ",
                    top.name,
                    ws.mode.name()
                );
                assert!(
                    (got.rate - want.rate).abs() < 1e-9,
                    "{}/{name}/{}: rate {} vs {}",
                    top.name,
                    ws.mode.name(),
                    got.rate,
                    want.rate
                );
                assert!(
                    (ws.scale - want.rate).abs() < 1e-9,
                    "{}/{name}/{}: scale {} vs rate {}",
                    top.name,
                    ws.mode.name(),
                    ws.scale,
                    want.rate
                );
                assert!(ws.denied.is_empty());
            }
        }
    }
}

/// A resident schedule pinned at a fraction of its certified rate (so
/// the residual deterministically has room for a second tenant).
fn resident_at(problem: &Problem, policy: &dyn Scheduler, frac: f64) -> Schedule {
    let s = policy.schedule(problem, &ScheduleRequest::max_throughput()).unwrap();
    let rate = s.rate * frac;
    let eval = problem.evaluator().evaluate(&s.placement, rate).unwrap();
    Schedule { placement: s.placement, rate, eval, provenance: s.provenance }
}

/// Acceptance: admission scoring through the kernel's residual-capacity
/// offsets (per-machine intercepts offset by resident load) matches a
/// naive merged-evaluator recompute within 1e-9.
#[test]
fn residual_admission_matches_naive_merged_recompute() {
    let (cluster, db) = presets::paper_cluster();
    let shared = Arc::new(db);
    let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
    let req = ScheduleRequest::max_throughput();
    let pairs = [
        (benchmarks::linear(), benchmarks::rolling_count()),
        (benchmarks::star(), benchmarks::unique_visitor()),
        (benchmarks::diamond(), benchmarks::rolling_count()),
    ];
    for (top_a, top_b) in pairs {
        let wp = WorkloadProblem::new(
            Workload::new("pair")
                .tenant("resident", top_a.clone(), shared.clone(), 1.0)
                .tenant("incoming", top_b.clone(), shared.clone(), 1.0),
            &cluster,
        )
        .unwrap();

        // resident runs at half its certified max: the residual has room
        let resident_problem = &wp.tenants()[0].problem;
        let resident_sched = resident_at(resident_problem, hetero.as_ref(), 0.5);
        let resident = TenantSchedule {
            tenant: "resident".into(),
            weight: 1.0,
            schedule: resident_sched,
        };

        let admitted =
            wp.admit(&[resident.clone()], 1, hetero.as_ref(), &req).unwrap_or_else(|e| {
                panic!("{}: admission must succeed at 50% residency: {e}", top_b.name)
            });

        // --- naive merged recompute: tenant b's slope/intercepts from its
        // own evaluator, capacities reduced by the resident's utilization
        let ev_a = resident_problem.evaluator();
        let resident_util =
            ev_a.evaluate(&resident.schedule.placement, resident.schedule.rate).unwrap().util;
        let ev_b = wp.tenants()[1].problem.evaluator();
        let p_b = &admitted.schedule.placement;
        let counts = p_b.counts();
        let mut naive = f64::INFINITY;
        for m in 0..ev_b.n_machines() {
            let mut a = 0.0f64;
            let mut b = 0.0f64;
            for c in 0..ev_b.n_components() {
                let k = p_b.x[c][m] as f64;
                if k > 0.0 {
                    a += k * ev_b.e_m[c][m] * ev_b.gains[c] / counts[c] as f64;
                    b += k * ev_b.met_m[c][m];
                }
            }
            if a > 0.0 {
                naive = naive.min((ev_b.cap[m] - resident_util[m] - b) / a);
            }
        }
        assert!(
            (admitted.schedule.rate - naive).abs() < 1e-9,
            "{}: admitted rate {} vs naive residual recompute {}",
            top_b.name,
            admitted.schedule.rate,
            naive
        );

        // --- and the kernel spelling: resident load as a fixed
        // intercept-offset row pushed into the accumulator
        let mut acc = AccumState::new(ev_b.n_machines());
        acc.push(&Row::fixed_load(&resident_util));
        for row in kernel::rows_of_placement(ev_b, p_b).iter().rev() {
            acc.push(row);
        }
        assert!(
            (acc.rate(&ev_b.cap) - naive).abs() < 1e-9,
            "{}: kernel offset rate {} vs naive {}",
            top_b.name,
            acc.rate(&ev_b.cap),
            naive
        );

        // the pair actually fits together: combined utilization at the
        // certified rates stays within every machine budget
        let combined = wp.combined_util(&[resident, admitted]).unwrap();
        for (m, u) in combined.iter().enumerate() {
            assert!(
                *u <= wp.cluster().machines[m].cap + 1e-6,
                "{}: machine {m} at {u}%",
                top_b.name
            );
        }
    }
}

/// Joint mode's combined utilization decomposes exactly into the
/// per-tenant evaluations the workload schedule reports.
#[test]
fn joint_util_decomposes_per_tenant() {
    let (cluster, db) = presets::paper_cluster();
    let shared = Arc::new(db);
    let hetero = registry::create("hetero", &PolicyParams::default()).unwrap();
    let wp = WorkloadProblem::new(
        Workload::new("duo")
            .tenant("a", benchmarks::linear(), shared.clone(), 1.0)
            .tenant("b", benchmarks::unique_visitor(), shared.clone(), 2.0),
        &cluster,
    )
    .unwrap();
    let ws = wp.schedule_joint(hetero.as_ref(), &ScheduleRequest::max_throughput()).unwrap();
    // sum of per-tenant utils == reported combined util
    let mut sum = vec![0.0f64; wp.cluster().n_machines()];
    for ts in &ws.tenants {
        for (m, u) in ts.schedule.eval.util.iter().enumerate() {
            sum[m] += u;
        }
    }
    for (m, (got, want)) in ws.util.iter().zip(&sum).enumerate() {
        assert!((got - want).abs() < 1e-9, "machine {m}: {got} vs {want}");
    }
    // and the merged problem certifies the same combined picture: the
    // merged evaluation at the shared scale matches the sum within fp
    // association error
    let merged_eval = wp
        .merged()
        .unwrap()
        .evaluator()
        .evaluate(&wp.merged_placement(&ws), ws.scale)
        .unwrap();
    for (m, (got, want)) in merged_eval.util.iter().zip(&sum).enumerate() {
        assert!((got - want).abs() < 1e-6, "machine {m}: merged {got} vs sum {want}");
    }
}
